# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")


def _fig4_cases(rows) -> dict:
    """Flatten bench_fig4_efficiency rows into the perf-gate JSON schema:
    one entry per (dataset, query, method) keyed ``fig4/<ds>/<q>/<m>``,
    holding the deterministic efficiency counters the CI gate compares."""
    cases = {}
    for ds_name, q, m, out in rows:
        cases[f"fig4/{ds_name}/{q}/{m}"] = {
            "oracle_calls": int(out["oracle_calls"]),
            "proxy_calls": int(out["proxy_calls"]),
            "tokens": int(out["tokens"]),
        }
    return cases


def _service_cases(rows) -> dict:
    """bench_service_throughput rows -> ``service/<ds>/<label>`` entries
    (per-query + total oracle calls of the concurrent workload, asserted
    identical to serial — so the gate covers the scheduler path too)."""
    return {f"service/{ds_name}/{label}": {
        "oracle_calls": int(out["oracle_calls"]),
        "proxy_calls": 0,
        "tokens": int(out["tokens"]),
    } for ds_name, label, out in rows}


def _stream_cases(rows) -> dict:
    """bench_stream_ingest rows -> ``stream/<ds>/<label>`` entries: total
    oracle calls of the standing-query run and its per-tick-refilter
    control (gating the incremental case keeps the dirty-cluster append
    path sublinear)."""
    return {f"stream/{ds_name}/{label}": {
        "oracle_calls": int(out["oracle_calls"]),
        "proxy_calls": 0,
        "tokens": int(out["tokens"]),
    } for ds_name, label, out in rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset sizes (slow on 1 CPU core)")
    ap.add_argument("--quick", action="store_true",
                    help="perf-smoke mode: only the Fig. 4 small cases, the "
                         "service-throughput workload, and the stream-ingest "
                         "workload (the CI perf gate; implies small sizes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the Fig. 4 / service call counters as JSON "
                         "(see benchmarks/check_regression.py)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig4,table2,table3,table4,table5,"
                         "fig6,appb,kernels,roofline,plan_order,api_overhead,"
                         "session_reuse,service,stream,sharded")
    args = ap.parse_args()
    if args.quick and args.full:
        ap.error("--quick and --full are mutually exclusive")
    small = not args.full
    only = set(args.only.split(",")) if args.only else None
    if args.quick:
        # api_overhead rides along for its internal contracts (traced vs
        # untraced bit-identity + <5% tracer overhead); it contributes no
        # JSON cases — wall-clock is not a deterministic gate signal
        quick_suites = {"fig4", "service", "stream", "api_overhead"}
        only = quick_suites if only is None else (only & quick_suites)
        if not only:
            # an empty set is falsy and would disable filtering entirely
            ap.error("--quick runs only the fig4/service/stream/api_overhead "
                     "suites; the given --only list excludes all of them")

    from benchmarks import (bench_fig2_distance, bench_fig4_efficiency,
                            bench_table2_quality, bench_table3_hyperparams,
                            bench_table4_recluster, bench_table5_theory,
                            bench_fig6_synthetic, bench_appb_backbones,
                            bench_kernels, bench_plan_order,
                            bench_api_overhead, bench_session_reuse,
                            bench_service_throughput, bench_sharded_round,
                            bench_stream_ingest, roofline_report)

    suites = [
        ("fig2", bench_fig2_distance), ("fig4", bench_fig4_efficiency),
        ("table2", bench_table2_quality), ("table3", bench_table3_hyperparams),
        ("table4", bench_table4_recluster), ("table5", bench_table5_theory),
        ("fig6", bench_fig6_synthetic), ("appb", bench_appb_backbones),
        ("kernels", bench_kernels), ("plan_order", bench_plan_order),
        ("api_overhead", bench_api_overhead),
        ("session_reuse", bench_session_reuse),
        ("service", bench_service_throughput),
        ("stream", bench_stream_ingest),
        ("sharded", bench_sharded_round),
        ("roofline", roofline_report),
    ]
    print("name,us_per_call,derived")
    json_cases: dict = {}
    failed = False
    for name, mod in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            ret = mod.main(small=small)
            if name == "fig4" and ret:
                json_cases.update(_fig4_cases(ret))
            if name == "service" and ret:
                json_cases.update(_service_cases(ret))
            if name == "stream" and ret:
                json_cases.update(_stream_cases(ret))
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness running
            failed = True
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}:{e}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "small": small, "cases": json_cases},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(json_cases)} cases to {args.json}",
              file=sys.stderr)
    if args.quick and (failed or not json_cases):
        sys.exit(1)  # the perf gate must not pass on an empty/broken run


if __name__ == "__main__":
    main()
