# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset sizes (slow on 1 CPU core)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig4,table2,table3,table4,table5,"
                         "fig6,appb,kernels,roofline,plan_order,api_overhead")
    args = ap.parse_args()
    small = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_fig2_distance, bench_fig4_efficiency,
                            bench_table2_quality, bench_table3_hyperparams,
                            bench_table4_recluster, bench_table5_theory,
                            bench_fig6_synthetic, bench_appb_backbones,
                            bench_kernels, bench_plan_order,
                            bench_api_overhead, roofline_report)

    suites = [
        ("fig2", bench_fig2_distance), ("fig4", bench_fig4_efficiency),
        ("table2", bench_table2_quality), ("table3", bench_table3_hyperparams),
        ("table4", bench_table4_recluster), ("table5", bench_table5_theory),
        ("fig6", bench_fig6_synthetic), ("appb", bench_appb_backbones),
        ("kernels", bench_kernels), ("plan_order", bench_plan_order),
        ("api_overhead", bench_api_overhead),
        ("roofline", roofline_report),
    ]
    print("name,us_per_call,derived")
    for name, mod in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod.main(small=small)
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness running
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
