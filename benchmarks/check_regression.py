#!/usr/bin/env python
"""CI perf gate: fail when oracle call counts regress vs the baseline.

Usage:
    python benchmarks/check_regression.py benchmarks/baseline.json \
        BENCH_pr.json [--tolerance 0.05]

Compares the ``oracle_calls`` counter of every baseline case against the PR
run (``benchmarks/run.py --quick --json BENCH_pr.json``) and exits non-zero
when any case grew by more than ``--tolerance`` (default 5%).  Token counts
are reported for context but do not gate (they track calls closely and
double-gating produces noisy duplicates).  Cases present in the PR run but
not in the baseline are listed as informational (new benchmarks start
gating once the baseline is refreshed).

Refreshing the baseline after an intentional efficiency change:
    PYTHONPATH=src python benchmarks/run.py --quick --json benchmarks/baseline.json
and commit the diff with a justification (docs/caching.md#ci-perf-gate).
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(baseline: dict, pr: dict, tolerance: float) -> int:
    base_cases = baseline.get("cases", {})
    pr_cases = pr.get("cases", {})
    if not base_cases:
        print("FAIL: baseline has no cases — refresh benchmarks/baseline.json")
        return 1
    failures = []
    width = max(len(k) for k in base_cases)
    print(f"{'case'.ljust(width)}  baseline       pr   delta")
    for key in sorted(base_cases):
        b = base_cases[key]["oracle_calls"]
        if key not in pr_cases:
            failures.append(f"{key}: missing from the PR run")
            print(f"{key.ljust(width)}  {b:8d}  MISSING")
            continue
        p = pr_cases[key]["oracle_calls"]
        delta = (p - b) / max(b, 1)
        flag = ""
        if p > b * (1.0 + tolerance):
            failures.append(
                f"{key}: oracle_calls {b} -> {p} ({delta:+.1%}, "
                f"tolerance {tolerance:.0%})")
            flag = "  << REGRESSION"
        print(f"{key.ljust(width)}  {b:8d}  {p:7d}  {delta:+6.1%}{flag}")
    for key in sorted(set(pr_cases) - set(base_cases)):
        print(f"{key.ljust(width)}  (new case — not gated until the "
              "baseline is refreshed)")
    if failures:
        print("\nFAIL: oracle call counts regressed:")
        for f in failures:
            print(f"  - {f}")
        print("If intentional, refresh the baseline:\n"
              "  PYTHONPATH=src python benchmarks/run.py --quick "
              "--json benchmarks/baseline.json")
        return 1
    print("\nOK: no oracle-call regressions "
          f"(tolerance {tolerance:.0%}, {len(base_cases)} cases)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("pr_run")
    ap.add_argument("--tolerance", type=float, default=0.05)
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.pr_run) as f:
        pr = json.load(f)
    sys.exit(compare(baseline, pr, args.tolerance))


if __name__ == "__main__":
    main()
