"""Plan-order benchmark: optimizer-chosen vs. naive predicate order.

For each Fig. 4 synthetic workload with >= 3 queries, build the 3-conjunct
expression ``q_a AND q_b AND q_c`` in its *worst* naive order (least
selective first) and compare three physical plans:

- ``naive``     — left-to-right cascade, no pilot (optimize=False);
- ``optimized`` — pilot-sampled, cost-ordered cascade (pilot calls counted
                  against it);
- ``flat``      — no cascade: every predicate over the full table, masks
                  ANDed afterwards (what PR 1's operator layer could do).

Emits oracle calls / tokens per plan plus the optimizer's own estimate of
the calls it saved (``PlanResult.est_calls_saved``).

Note the conjunctions land in the paper's rare-positive regime (~0.1-0.3%
truth selectivity, the CB-Q1 pathology): per-plan f1 is near zero for every
method — flat included — so the quality columns mainly confirm the plans
agree; the efficiency columns (calls, tokens) are the benchmark.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.core import CSVConfig, SemanticTable, SyntheticOracle
from repro.core.operators import accuracy_f1
from repro.data import make_dataset
from repro.plan import And, PlanExecutor, Pred

# (dataset, [queries, ordered least-selective-first], n)
CASES = [
    ("imdb_review", ["RV-Q1", "RV-Q2", "RV-Q3"], 20000),
    ("codebase", ["CB-Q2", "CB-Q3", "CB-Q1"], 9378),
    ("airdialogue", ["AD-Q1", "AD-Q3", "AD-Q2"], 20000),
]


def _expr(ds, queries, flip=0.02, seed=7):
    return And(*[Pred(q, SyntheticOracle(ds.labels[q], flip_prob=flip,
                                         seed=seed,
                                         token_lens=ds.token_lens))
                 for q in queries])


def _run(table, ds, queries, truth, optimize):
    t0 = time.time()
    r = PlanExecutor(table, cfg=CSVConfig(n_clusters=4, xi=0.005),
                     optimize=optimize).run(_expr(ds, queries))
    wall = time.time() - t0
    acc, f1 = accuracy_f1(r.mask, truth)
    return r, wall, acc, f1


def _run_flat(table, ds, queries, truth):
    t0 = time.time()
    calls = tokens = 0
    mask = None
    for q in queries:
        oracle = SyntheticOracle(ds.labels[q], flip_prob=0.02, seed=7,
                                 token_lens=ds.token_lens)
        fr = table.sem_filter(oracle, cfg=CSVConfig(n_clusters=4, xi=0.005))
        calls += fr.n_llm_calls
        tokens += fr.input_tokens + fr.output_tokens
        mask = fr.mask if mask is None else (mask & fr.mask)
    acc, f1 = accuracy_f1(mask, truth)
    return calls, tokens, time.time() - t0, acc, f1


def main(small: bool = False):
    rows = []
    for ds_name, queries, n in CASES[:1] if small else CASES:
        if small:
            n = min(n, 4000)
        ds = make_dataset(ds_name, n=n, seed=0)
        truth = ds.labels[queries[0]].copy()
        for q in queries[1:]:
            truth &= ds.labels[q]
        table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)

        r_naive, w_naive, acc_n, f1_n = _run(table, ds, queries, truth, False)
        r_opt, w_opt, acc_o, f1_o = _run(table, ds, queries, truth, True)
        flat_calls, flat_tokens, w_flat, acc_f, f1_f = _run_flat(
            table, ds, queries, truth)

        for plan, calls, tokens, wall, acc, f1, extra in [
            ("naive", r_naive.n_llm_calls,
             r_naive.input_tokens + r_naive.output_tokens, w_naive,
             acc_n, f1_n, f"order={'>'.join(r_naive.order)}"),
            ("optimized", r_opt.n_llm_calls,
             r_opt.input_tokens + r_opt.output_tokens, w_opt, acc_o, f1_o,
             f"order={'>'.join(r_opt.order)};pilot={r_opt.pilot_calls};"
             f"est_saved={r_opt.est_calls_saved:.0f}"),
            ("flat", flat_calls, flat_tokens, w_flat, acc_f, f1_f,
             "order=independent"),
        ]:
            us_per_call = wall / max(1, calls) * 1e6
            emit(f"plan_order/{ds_name}/{plan}", us_per_call,
                 f"oracle={calls};tokens={tokens};acc={acc:.4f};"
                 f"f1={f1:.4f};{extra}")
            rows.append((ds_name, plan, calls, tokens))
        saved = r_naive.n_llm_calls - r_opt.n_llm_calls
        emit(f"plan_order/{ds_name}/saving", 0.0,
             f"calls_saved_vs_naive={saved};"
             f"redux={r_naive.n_llm_calls / max(1, r_opt.n_llm_calls):.2f}x;"
             f"truth_sel={float(truth.mean()):.4f}")
    return rows


if __name__ == "__main__":
    main()
