"""Table 3 + Fig. 5 analogue: #clusters, sample ratio xi, lower bound lb."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, run_method
from repro.core import CSVConfig, SemanticTable
from repro.data import make_dataset


def main(small: bool = False):
    n = 4000 if small else 16000
    ds = make_dataset("imdb_review", n=n, seed=0)
    truth = ds.labels["RV-Q1"]
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    rows = []
    for method in ["csv", "csv-sim"]:
        for k in [2, 4, 8, 16]:
            out = run_method(table, truth, ds.token_lens, method,
                             cfg=CSVConfig(n_clusters=k))
            emit(f"table3/{method}/clusters={k}", 0.0,
                 f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
                 f"calls={out['oracle_calls']}")
            rows.append(("clusters", k, method, out))
        for xi in [0.005, 0.010, 0.015, 0.020, 0.025]:
            out = run_method(table, truth, ds.token_lens, method,
                             cfg=CSVConfig(n_clusters=4, xi=xi))
            emit(f"table3/{method}/xi={xi*1000:.0f}permil", 0.0,
                 f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
                 f"calls={out['oracle_calls']}")
            rows.append(("xi", xi, method, out))
        for lb in [0.10, 0.15, 0.20, 0.50]:
            out = run_method(table, truth, ds.token_lens, method,
                             cfg=CSVConfig(n_clusters=4, lb=lb))
            emit(f"table3/{method}/lb={lb}", 0.0,
                 f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
                 f"calls={out['oracle_calls']}")
            rows.append(("lb", lb, method, out))
    return rows


if __name__ == "__main__":
    main()
