"""Table 2 analogue: Accuracy and F1 of all methods across the 12 queries."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, run_method
from repro.core import CSVConfig, SemanticTable
from repro.data import make_dataset

QUERIES = [
    ("imdb_review", ["RV-Q1", "RV-Q2", "RV-Q3"], 12000),
    ("codebase", ["CB-Q1", "CB-Q2", "CB-Q3"], 9378),
    ("airdialogue", ["AD-Q1", "AD-Q2", "AD-Q3", "AD-Q4"], 12000),
    ("tc", ["TC"], 8000),
    ("fever", ["Fever"], 8000),
]


def main(small: bool = False):
    rows = []
    for ds_name, qs, n in QUERIES[:2] if small else QUERIES:
        if small:
            n = min(n, 3000)
        ds = make_dataset(ds_name, n=n, seed=0)
        table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
        for q in qs:
            truth = ds.labels[q]
            for m in ["reference", "lotus", "bargain", "csv", "csv-sim"]:
                out = run_method(table, truth, ds.token_lens, m,
                                 cfg=CSVConfig(n_clusters=4))
                emit(f"table2/{q}/{m}",
                     out["wall_s"] / max(1, out["oracle_calls"]) * 1e6,
                     f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
                     f"calls={out['oracle_calls']}")
                rows.append((q, m, out["acc"], out["f1"]))
    return rows


if __name__ == "__main__":
    main()
