"""Sharded-round overhead: shards=N vs the single-host round driver.

The sharded executor (repro.distributed.round) buys mesh-level
parallelism with two costs on one host: a strict-FIFO dispatcher thread
and per-shard vote dispatches instead of one segmented call.  This
bench pins both down on the Fig. 4 imdb case and asserts the contract
the speedup story rests on:

- masks, call counts, and cluster logs bit-identical at every shard
  count (the all-gather merge is invisible);
- per-round oracle batch sizes shrink ~1/shards (what each mesh host
  would actually pay);
- single-host overhead of sharding stays bounded (<2.5x wall on the
  small case — the dispatcher thread dominates at toy sizes).

Emitted per shard count: wall us/oracle-call plus the batch geometry.
"""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.core import CSVConfig, SyntheticOracle, semantic_filter
from repro.data import make_dataset

SHARD_COUNTS = (1, 2, 4)


def _run(ds, shards, xi):
    oracle = SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.02, seed=7,
                             token_lens=ds.token_lens)
    cfg = CSVConfig(n_clusters=4, xi=xi, shards=shards)
    t0 = time.time()
    r = semantic_filter(ds.embeddings, oracle, cfg)
    return r, time.time() - t0


def main(small: bool = True):
    n = 4000 if small else 20000
    ds = make_dataset("imdb_review", n=n, seed=0)
    xi = 0.005
    rows = []
    base, base_wall = _run(ds, 1, xi)
    for shards in SHARD_COUNTS:
        r, wall = _run(ds, shards, xi)
        assert (r.mask == base.mask).all(), f"shards={shards}: mask diverged"
        assert r.n_llm_calls == base.n_llm_calls, \
            f"shards={shards}: call counts diverged"
        assert r.cluster_log == base.cluster_log, \
            f"shards={shards}: cluster log diverged"
        batches = [b for rr in r.round_log for b in rr.oracle_batches]
        mean_batch = float(np.mean(batches)) if batches else 0.0
        emit(f"sharded/imdb/shards{shards}",
             wall / max(1, r.n_llm_calls) * 1e6,
             f"oracle={r.n_llm_calls};mean_batch={mean_batch:.0f};"
             f"rounds={len(r.round_log)};wall={wall:.2f}s")
        rows.append(("imdb_review", f"shards{shards}",
                     {"oracle_calls": int(r.n_llm_calls),
                      "tokens": int(r.input_tokens + r.output_tokens)}))
        if shards > 1:
            base_batches = [b for rr in base.round_log
                            for b in rr.oracle_batches]
            assert mean_batch <= float(np.mean(base_batches)), \
                "sharding did not shrink per-dispatch batches"
            assert wall <= max(base_wall, 1e-3) * 2.5 + 0.5, \
                f"shards={shards}: single-host overhead blew past 2.5x"
    return rows


if __name__ == "__main__":
    main(small="--full" not in sys.argv)
