"""Service throughput: cross-query oracle batching vs serial collects.

Workload: 5 concurrent queries over one shared table (the Fig. 4 imdb
case) — four distinct single-predicate filters plus a two-leaf cascade —
submitted through ``Session.submit`` under ``scheduler.holding()`` so the
whole burst merges from its first round.  The serial control collects the
same queries one at a time in a fresh session with identical oracles.

Asserted (the ISSUE-5 acceptance criteria):
- per-query masks and oracle call counts identical to serial;
- mean oracle batch size per merged invocation >= 1.5x the serial
  per-invocation mean.

Emitted: per-query call counts (the CI perf gate compares these against
benchmarks/baseline.json), total calls, and the batching ratio.
"""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset

POL = ExecutionPolicy(n_clusters=4, xi=0.005)

# (label, labels-key, oracle flip seed): distinct oracle objects per query
# so all five run fully overlapped (shared oracles would conflict-serialize)
PREDICATES = [("q0_pos", "RV-Q1", 7), ("q1_act", "RV-Q3", 8),
              ("q2_plot", "RV-Q2", 9), ("q3_pos2", "RV-Q1", 11)]
CASCADE = [("q4a_plot2", "RV-Q2", 12), ("q4b_act2", "RV-Q3", 13)]


def _queries(ds, handle):
    def oracle(key, seed):
        return SyntheticOracle(ds.labels[key], flip_prob=0.02, seed=seed,
                               token_lens=ds.token_lens)
    oracles = [oracle(k, s) for _, k, s in PREDICATES + CASCADE]
    qs = [handle.filter(o, name=label)
          for (label, _, _), o in zip(PREDICATES, oracles[:4])]
    qs.append(handle.filter(oracles[4], name=CASCADE[0][0])
              & handle.filter(oracles[5], name=CASCADE[1][0]))
    return qs, oracles


def main(small: bool = False):
    n = 4000 if small else 20000
    ds = make_dataset("imdb_review", n=n, seed=0)
    labels = [label for label, _, _ in PREDICATES] + ["q4_cascade"]

    # ---- serial control ------------------------------------------------
    s_serial = Session(policy=POL)
    qs, oracles = _queries(ds, s_serial.table(embeddings=ds.embeddings,
                                              name="reviews"))
    t0 = time.time()
    serial = [q.collect() for q in qs]
    serial_wall = time.time() - t0
    serial_batches = [b for o in oracles for b in o.stats.batch_sizes]

    # ---- concurrent service -------------------------------------------
    s_conc = Session(policy=POL)
    qc, _ = _queries(ds, s_conc.table(embeddings=ds.embeddings,
                                      name="reviews"))
    t0 = time.time()
    with s_conc.scheduler.holding():
        tickets = [s_conc.submit(q) for q in qc]
    conc = s_conc.gather(*tickets)
    conc_wall = time.time() - t0

    for label, rs, rc in zip(labels, serial, conc):
        assert (rc.mask == rs.mask).all(), f"{label}: masks diverged"
        assert rc.n_llm_calls == rs.n_llm_calls, f"{label}: call counts"
    merge = s_conc.scheduler.stats.merge
    serial_mean = float(np.mean(serial_batches))
    ratio = merge.mean_batch_size / serial_mean
    assert ratio >= 1.5, f"batching ratio {ratio:.2f} below the 1.5x floor"
    total = sum(r.n_llm_calls for r in serial)
    assert total == sum(r.n_llm_calls for r in conc)
    s_conc.close()

    rows = []
    for label, r in zip(labels, serial):
        emit(f"service/imdb/{label}",
             r.total_time_s / max(1, r.n_llm_calls) * 1e6,
             f"oracle={r.n_llm_calls};tokens={r.input_tokens + r.output_tokens}")
        rows.append(("imdb_review", label,
                     {"oracle_calls": int(r.n_llm_calls),
                      "tokens": int(r.input_tokens + r.output_tokens)}))
    tokens_total = sum(r.input_tokens + r.output_tokens for r in serial)
    emit("service/imdb/total", conc_wall / max(1, total) * 1e6,
         f"oracle={total};mean_batch_serial={serial_mean:.0f};"
         f"mean_batch_merged={merge.mean_batch_size:.0f};"
         f"ratio={ratio:.2f}x;merge_factor={merge.merge_factor:.1f};"
         f"invocations={merge.n_invocations};"
         f"wall_serial={serial_wall:.2f}s;wall_service={conc_wall:.2f}s")
    rows.append(("imdb_review", "total",
                 {"oracle_calls": int(total), "tokens": int(tokens_total)}))
    return rows


if __name__ == "__main__":
    main(small=True)
