"""Service throughput: cross-query oracle batching vs serial collects.

Workload: 5 concurrent queries over one shared table (the Fig. 4 imdb
case) — four distinct single-predicate filters plus a two-leaf cascade —
submitted through ``Session.submit`` under ``scheduler.holding()`` so the
whole burst merges from its first round.  The serial control collects the
same queries one at a time in a fresh session with identical oracles.

Asserted (the ISSUE-5 acceptance criteria):
- per-query masks and oracle call counts identical to serial;
- mean oracle batch size per merged invocation >= 1.5x the serial
  per-invocation mean.

Emitted: per-query call counts (the CI perf gate compares these against
benchmarks/baseline.json), total calls, and the batching ratio.
"""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset

POL = ExecutionPolicy(n_clusters=4, xi=0.005)

# (label, labels-key, oracle flip seed): distinct oracle objects per query
# so all five run fully overlapped (shared oracles would conflict-serialize)
PREDICATES = [("q0_pos", "RV-Q1", 7), ("q1_act", "RV-Q3", 8),
              ("q2_plot", "RV-Q2", 9), ("q3_pos2", "RV-Q1", 11)]
CASCADE = [("q4a_plot2", "RV-Q2", 12), ("q4b_act2", "RV-Q3", 13)]

ENGINE_PREDICATES = ["the review is positive",
                     "the review praises the acting",
                     "the review discusses the plot",
                     "the review would recommend the movie",
                     "the review complains about pacing"]


def _queries(ds, handle):
    def oracle(key, seed):
        return SyntheticOracle(ds.labels[key], flip_prob=0.02, seed=seed,
                               token_lens=ds.token_lens)
    oracles = [oracle(k, s) for _, k, s in PREDICATES + CASCADE]
    qs = [handle.filter(o, name=label)
          for (label, _, _), o in zip(PREDICATES, oracles[:4])]
    qs.append(handle.filter(oracles[4], name=CASCADE[0][0])
              & handle.filter(oracles[5], name=CASCADE[1][0]))
    return qs, oracles


def main(small: bool = False):
    n = 4000 if small else 20000
    ds = make_dataset("imdb_review", n=n, seed=0)
    labels = [label for label, _, _ in PREDICATES] + ["q4_cascade"]

    # ---- serial control ------------------------------------------------
    s_serial = Session(policy=POL)
    qs, oracles = _queries(ds, s_serial.table(embeddings=ds.embeddings,
                                              name="reviews"))
    t0 = time.time()
    serial = [q.collect() for q in qs]
    serial_wall = time.time() - t0
    serial_batches = [b for o in oracles for b in o.stats.batch_sizes]

    # ---- concurrent service -------------------------------------------
    s_conc = Session(policy=POL)
    qc, _ = _queries(ds, s_conc.table(embeddings=ds.embeddings,
                                      name="reviews"))
    t0 = time.time()
    with s_conc.scheduler.holding():
        tickets = [s_conc.submit(q) for q in qc]
    conc = s_conc.gather(*tickets)
    conc_wall = time.time() - t0

    for label, rs, rc in zip(labels, serial, conc):
        assert (rc.mask == rs.mask).all(), f"{label}: masks diverged"
        assert rc.n_llm_calls == rs.n_llm_calls, f"{label}: call counts"
    merge = s_conc.scheduler.stats.merge
    serial_mean = float(np.mean(serial_batches))
    ratio = merge.mean_batch_size / serial_mean
    assert ratio >= 1.5, f"batching ratio {ratio:.2f} below the 1.5x floor"
    total = sum(r.n_llm_calls for r in serial)
    assert total == sum(r.n_llm_calls for r in conc)
    s_conc.close()

    rows = []
    for label, r in zip(labels, serial):
        emit(f"service/imdb/{label}",
             r.total_time_s / max(1, r.n_llm_calls) * 1e6,
             f"oracle={r.n_llm_calls};tokens={r.input_tokens + r.output_tokens}")
        rows.append(("imdb_review", label,
                     {"oracle_calls": int(r.n_llm_calls),
                      "tokens": int(r.input_tokens + r.output_tokens)}))
    tokens_total = sum(r.input_tokens + r.output_tokens for r in serial)
    emit("service/imdb/total", conc_wall / max(1, total) * 1e6,
         f"oracle={total};mean_batch_serial={serial_mean:.0f};"
         f"mean_batch_merged={merge.mean_batch_size:.0f};"
         f"ratio={ratio:.2f}x;merge_factor={merge.merge_factor:.1f};"
         f"invocations={merge.n_invocations};"
         f"wall_serial={serial_wall:.2f}s;wall_service={conc_wall:.2f}s")
    rows.append(("imdb_review", "total",
                 {"oracle_calls": int(total), "tokens": int(tokens_total)}))
    rows.extend(engine_case(small))
    return rows


def engine_case(small: bool = False):
    """Engine-backed workload: 5 ModelOracles over one tiny-config engine.

    Measures the fused serving path itself — tokens/sec through the
    engine, wall-clock per tick, engine ``mean_batch_size``, and bucket
    ``fill_ratio`` — and asserts the ISSUE-6 criterion: cross-oracle
    packing grows mean prompts per engine invocation >= 2x over per-oracle
    dispatch (the PR-5 path, ``scheduler.pack = False``), with bit-identical
    masks and call counts.
    """
    import jax

    from repro.configs import smoke_config
    from repro.core.oracle import ModelOracle
    from repro.data.tokenizer import HashTokenizer
    from repro.models import lm
    from repro.serving import ServingEngine

    n = 120 if small else 240
    cfg = smoke_config("qwen1.5-0.5b")
    params = lm.init_params(cfg, jax.random.key(0))
    ds = make_dataset("imdb_review", n=n, seed=0)
    tok = HashTokenizer(cfg.vocab_size)
    # min_sample 8 keeps each query's per-round batch (~n_clusters * 8
    # prompts) well under max_batch, so the packed wave's gain is visible:
    # per-oracle dispatch leaves buckets 1/4 full, packing fills them
    pol = ExecutionPolicy(n_clusters=4, min_sample=8, pilot_size=8)

    def run(pack: bool):
        engine = ServingEngine(cfg, params, max_batch=128)
        sess = Session(policy=pol)
        handle = sess.table(embeddings=ds.embeddings, name="reviews")
        oracles = [ModelOracle(engine, tok, p, ds.texts)
                   for p in ENGINE_PREDICATES]
        qs = [handle.filter(o, name=f"e{i}")
              for i, o in enumerate(oracles)]
        sess.scheduler.pack = pack
        t0 = time.time()
        with sess.scheduler.holding():
            tickets = [sess.submit(q) for q in qs]
        res = sess.gather(*tickets)
        wall = time.time() - t0
        merge = sess.scheduler.stats.merge
        sess.close()
        return res, engine, merge, wall

    res_p, eng_p, merge_p, wall_p = run(pack=True)
    res_u, eng_u, merge_u, wall_u = run(pack=False)
    for label, rp, ru in zip(ENGINE_PREDICATES, res_p, res_u):
        assert (rp.mask == ru.mask).all(), f"{label}: masks diverged"
        assert rp.n_llm_calls == ru.n_llm_calls, f"{label}: call counts"
    ratio = eng_p.mean_batch_size / max(eng_u.mean_batch_size, 1e-9)
    assert ratio >= 2.0, (
        f"packed mean prompts/invocation {eng_p.mean_batch_size:.1f} vs "
        f"per-oracle {eng_u.mean_batch_size:.1f}: ratio {ratio:.2f} below "
        "the 2x floor")

    total = sum(r.n_llm_calls for r in res_p)
    tokens = merge_p.total_tokens
    tok_per_s = eng_p.stats["prefill_tokens"] / max(merge_p.total_wall_s,
                                                    1e-9)
    emit("service/engine/packed", wall_p / max(1, total) * 1e6,
         f"oracle={total};tokens={tokens};tokens_per_s={tok_per_s:.0f};"
         f"wall_per_tick={merge_p.mean_wall_s * 1e3:.1f}ms;"
         f"ticks={merge_p.n_invocations};"
         f"engine_mean_batch={eng_p.mean_batch_size:.1f};"
         f"fill_ratio={eng_p.batcher.fill_ratio:.2f};"
         f"truncated={eng_p.stats['truncated_prompts']};"
         f"pack_ratio={ratio:.2f}x;wall={wall_p:.2f}s")
    emit("service/engine/per_oracle", wall_u / max(1, total) * 1e6,
         f"oracle={total};"
         f"wall_per_tick={merge_u.mean_wall_s * 1e3:.1f}ms;"
         f"engine_mean_batch={eng_u.mean_batch_size:.1f};"
         f"fill_ratio={eng_u.batcher.fill_ratio:.2f};wall={wall_u:.2f}s")
    return [("imdb_review", "engine_packed",
             {"oracle_calls": int(total), "tokens": int(tokens)})]


if __name__ == "__main__":
    main(small=True)
