"""Fig. 4 analogue: # LLM calls, execution time, token usage per method."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, run_method
from repro.core import CSVConfig, SemanticTable
from repro.data import make_dataset

CASES = [("imdb_review", "RV-Q1", 20000), ("airdialogue", "AD-Q1", 20000),
         ("codebase", "CB-Q2", 9378), ("tc", "TC", 12000),
         ("fever", "Fever", 10000)]
METHODS = ["reference", "lotus", "bargain", "csv", "csv-sim"]


def main(small: bool = False):
    rows = []
    for ds_name, q, n in CASES[:2] if small else CASES:
        if small:
            n = min(n, 4000)
        ds = make_dataset(ds_name, n=n, seed=0)
        truth = ds.labels[q]
        table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
        ref_calls = None
        for m in METHODS:
            out = run_method(table, truth, ds.token_lens, m,
                             cfg=CSVConfig(n_clusters=4, xi=0.005))
            if m == "reference":
                ref_calls = out["oracle_calls"]
            red = ref_calls / max(1, out["oracle_calls"])
            us_per_call = out["wall_s"] / max(1, out["oracle_calls"]) * 1e6
            emit(f"fig4/{ds_name}/{q}/{m}", us_per_call,
                 f"oracle={out['oracle_calls']};proxy={out['proxy_calls']};"
                 f"tokens={out['tokens']};redux_vs_ref={red:.1f}x;"
                 f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
                 f"mean_batch={out['mean_oracle_batch']:.1f};"
                 f"invocations={out['oracle_invocations']}")
            rows.append((ds_name, q, m, out))
    return rows


if __name__ == "__main__":
    main()
