"""Roofline report generator: reads dry-run artifacts -> markdown tables.

Per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and HBM fit.  Used to produce
EXPERIMENTS.md §Dry-run / §Roofline and consumed by the perf loop.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.models.config import SHAPES

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"
HBM_PER_CHIP = 16e9  # v5e


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    """Analytic useful FLOPs per device: 6·N_active·tokens (train, fwd+bwd)
    or 2·N_active·tokens (inference fwd)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * n * tokens / chips


def load_cells(tag: str = "baseline"):
    cells = []
    for f in sorted(ART.glob(f"*__{tag}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def row(a):
    t = a["roofline_terms"]
    flops = (a.get("cost_expanded", {}).get("flops")
             or a["cost"].get("flops", 0.0))
    mf = model_flops_per_device(a["arch"], a["shape"], a["chips"])
    useful = mf / flops if flops else 0.0
    hbm = (a["memory"].get("temp_size_in_bytes", 0)
           + a["memory"].get("argument_size_in_bytes", 0))
    dominant = a["dominant"].replace("_s", "")
    # roofline fraction: useful-model-flops time over the dominant term —
    # how close the dominant resource is to pure useful work
    ideal_s = mf / 197e12
    frac = ideal_s / max(max(t["compute_s"], t["memory_s"],
                             t["collective_s"]), 1e-30)
    return {
        "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
        "compute_ms": t["compute_s"] * 1e3, "memory_ms": t["memory_s"] * 1e3,
        "collective_ms": t["collective_s"] * 1e3, "dominant": dominant,
        "useful_ratio": useful, "hbm_gb": hbm / 1e9,
        "fits": hbm <= HBM_PER_CHIP, "roofline_frac": frac,
        "tag": a.get("tag", "baseline"),
    }


def markdown(tag: str = "baseline", mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | comp ms | mem ms | coll ms | dominant | "
        "model/HLO flops | HBM GB | fits | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in load_cells(tag):
        if a.get("skipped_by_design"):
            if a["mesh"] == mesh:
                lines.append(f"| {a['arch']} | {a['shape']} | — | — | — | "
                             f"skip: {a['reason'][:40]} | — | — | — | — |")
            continue
        if not a.get("ok") or a["mesh"] != mesh:
            continue
        r = row(a)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']:.2f} | "
            f"{r['memory_ms']:.2f} | {r['collective_ms']:.2f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['hbm_gb']:.1f} | {'y' if r['fits'] else 'N'} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main(small: bool = False):
    for mesh in ["pod", "multipod"]:
        print(f"\n### mesh={mesh} (baseline)\n")
        print(markdown("baseline", mesh))


if __name__ == "__main__":
    main()
