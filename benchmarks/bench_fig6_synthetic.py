"""Fig. 6 analogue: synthetic predicate suite across type x difficulty.

Explicit predicates = lexically anchored (hybrid BM25+embedding distance);
Interpretive = pure-embedding semantics; Hybrid = both.  Difficulty scales
selectivity down and label-boundary noise up.
"""
from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit, run_method
from repro.core import CSVConfig, SemanticTable
from repro.core.bm25 import hybrid_features
from repro.data import make_dataset

DIFF = {"easy": (None, 0.99), "moderate": (0.25, 0.95), "hard": (0.06, 0.9)}


def main(small: bool = False):
    rows = []
    types = ["explicit", "interpretive"] if small else \
        ["explicit", "interpretive", "hybrid"]
    n_queries = 2 if small else 5
    n = 2500 if small else 8000
    for dsname in (["imdb_review"] if small else ["imdb_review", "tc"]):
        for qtype in types:
            lam = 0.4 if qtype in ("explicit", "hybrid") else 1.0
            for diff, (sel, purity) in DIFF.items():
                accs, f1s, calls = [], [], []
                for qi in range(n_queries):
                    ds = make_dataset(dsname, n=n, seed=100 + qi,
                                      purity=purity, selectivity=sel)
                    truth = ds.labels[list(ds.labels)[0]]
                    feats = hybrid_features(ds.embeddings, ds.texts, lam=lam)
                    table = SemanticTable(texts=ds.texts, embeddings=feats)
                    out = run_method(table, truth, ds.token_lens, "csv",
                                     cfg=CSVConfig(n_clusters=4))
                    accs.append(out["acc"])
                    f1s.append(out["f1"])
                    calls.append(out["oracle_calls"])
                emit(f"fig6/{dsname}/{qtype}/{diff}", 0.0,
                     f"acc_med={np.median(accs):.4f};f1_med={np.median(f1s):.4f};"
                     f"calls_med={np.median(calls):.0f};n_queries={n_queries}")
                rows.append((dsname, qtype, diff, accs, f1s, calls))
    return rows


if __name__ == "__main__":
    main()
