"""Table 4 analogue: re-clustering ablation (w/ RC vs w/o RC: lb=ub=0.5)."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, run_method
from repro.core import CSVConfig, SemanticTable
from repro.data import make_dataset

CASES = [("imdb_review", "RV-Q1"), ("imdb_review", "RV-Q3"),
         ("codebase", "CB-Q1"), ("codebase", "CB-Q2"), ("tc", "TC")]


def main(small: bool = False):
    rows = []
    for ds_name, q in CASES[:2] if small else CASES:
        n = 3000 if small else 10000
        ds = make_dataset(ds_name, n=n, seed=0)
        truth = ds.labels[q]
        table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
        with_rc = run_method(table, truth, ds.token_lens, "csv",
                             cfg=CSVConfig(n_clusters=4, lb=0.15))
        no_rc = run_method(table, truth, ds.token_lens, "csv",
                           cfg=CSVConfig(n_clusters=4, lb=0.5, ub=0.5,
                                         max_recluster=0))
        r = with_rc["result"]
        rc_frac = r.recluster_time_s / max(r.total_time_s, 1e-9) * 100
        emit(f"table4/{q}/with_rc", 0.0,
             f"acc={with_rc['acc']:.4f};f1={with_rc['f1']:.4f};"
             f"calls={with_rc['oracle_calls']};rc_time_pct={rc_frac:.2f}")
        emit(f"table4/{q}/no_rc", 0.0,
             f"acc={no_rc['acc']:.4f};f1={no_rc['f1']:.4f};"
             f"calls={no_rc['oracle_calls']}")
        rows.append((q, with_rc, no_rc, rc_frac))
    return rows


if __name__ == "__main__":
    main()
