"""Session-level multi-query optimization: warm vs cold oracle spend.

Scenario (Fig. 4 small-case data, imdb RV-Q3/RV-Q1): a session filters the
same table twice —

    q1 = t.filter(A).collect()            # cold: full CSV run
    q2 = (t.filter(A) & t.filter(B)).collect()

Warm session: q2 replays A's memoized decisions at zero oracle cost, skips
A's pilot probe, and runs B only on A's survivors.  The cold control runs
q2 in a fresh session.  A third collect of A alone replays entirely
(0 calls).  The embedding-cache column counts rows pushed through the
embedder when the table is registered from texts: the warm session embeds
once for both queries; a per-query cold workflow embeds per session.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset

COLD = ExecutionPolicy(n_clusters=4, xi=0.005,
                       reuse_memo=False, reuse_stats=False)
WARM = ExecutionPolicy(n_clusters=4, xi=0.005)


def _oracles(ds):
    # flip=0 keeps the oracle deterministic so warm/cold masks are directly
    # comparable (stochastic oracles agree only in expectation; see
    # docs/caching.md)
    return (SyntheticOracle(ds.labels["RV-Q3"], flip_prob=0.0, seed=7,
                            token_lens=ds.token_lens),
            SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.0, seed=7,
                            token_lens=ds.token_lens))


def main(small: bool = False):
    n = 4000 if small else 20000
    ds = make_dataset("imdb_review", n=n, seed=0)
    rows = []

    # ---- warm session: q1 then q2, shared memo --------------------------
    oA, oB = _oracles(ds)
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    t0 = time.time()
    r1 = t.filter(oA, name="A").collect(WARM)
    rw = (t.filter(oA, name="A") & t.filter(oB, name="B")).collect(WARM)
    replay = t.filter(oA, name="A").collect(WARM)
    warm_wall = time.time() - t0
    warm_total = r1.n_llm_calls + rw.n_llm_calls + replay.n_llm_calls

    # ---- cold control: each query in a fresh session --------------------
    cA1, _ = _oracles(ds)
    cA2, cB2 = _oracles(ds)
    cA3, _ = _oracles(ds)
    t0 = time.time()
    c1 = Session().table(embeddings=ds.embeddings).filter(
        cA1, name="A").collect(COLD)
    tc = Session().table(embeddings=ds.embeddings)
    c2 = (tc.filter(cA2, name="A") & tc.filter(cB2, name="B")).collect(COLD)
    c3 = Session().table(embeddings=ds.embeddings).filter(
        cA3, name="A").collect(COLD)
    cold_wall = time.time() - t0
    cold_total = c1.n_llm_calls + c2.n_llm_calls + c3.n_llm_calls

    assert replay.n_llm_calls == 0 and replay.n_replayed == n, \
        "warm replay must spend zero oracle calls"
    assert (replay.mask == r1.mask).all(), "replay must be bit-identical"
    assert rw.n_llm_calls < c2.n_llm_calls, \
        "warm composed query must beat the cold control"
    assert warm_total < cold_total

    emit("session_reuse/imdb/warm_total",
         warm_wall / max(1, warm_total) * 1e6,
         f"oracle={warm_total};q1={r1.n_llm_calls};q2={rw.n_llm_calls};"
         f"replay={replay.n_llm_calls};q2_pilot={rw.pilot_calls};"
         f"replayed_rows={rw.n_replayed + replay.n_replayed}")
    emit("session_reuse/imdb/cold_total",
         cold_wall / max(1, cold_total) * 1e6,
         f"oracle={cold_total};q1={c1.n_llm_calls};q2={c2.n_llm_calls};"
         f"q3={c3.n_llm_calls};q2_pilot={c2.pilot_calls}")
    emit("session_reuse/imdb/savings", 0.0,
         f"saved={cold_total - warm_total};"
         f"redux={cold_total / max(1, warm_total):.2f}x;"
         f"mask_equal={bool((rw.mask == c2.mask).all())}")

    # ---- embedding cache: rows pushed through the embedder --------------
    counter = {"rows": 0}
    # the cache hands the embedder only its missing subset, so the stub
    # must return the row MATCHING each requested text (first occurrence
    # for duplicates — consistent with content-hash semantics)
    row_of = {}
    for i, txt in enumerate(ds.texts):
        row_of.setdefault(txt, i)

    def embedder(texts):
        counter["rows"] += len(texts)
        return ds.embeddings[[row_of[t] for t in texts]]

    warm_sess = Session(embedder=embedder)
    ht = warm_sess.table(texts=ds.texts)
    _ = ht.embeddings
    warm_rows = counter["rows"]
    _ = warm_sess.table(texts=ds.texts[: n // 2], name="sub").embeddings
    warm_rows2 = counter["rows"] - warm_rows
    counter["rows"] = 0
    _ = Session(embedder=embedder).table(texts=ds.texts).embeddings
    _ = Session(embedder=embedder).table(
        texts=ds.texts[: n // 2]).embeddings
    cold_rows = counter["rows"]
    uniq = len(set(ds.texts))  # unique payloads (duplicates embed once)
    emit("session_reuse/imdb/embed_rows", 0.0,
         f"warm={warm_rows + warm_rows2};cold={cold_rows};unique={uniq};"
         f"warm_second_table={warm_rows2}")
    assert warm_rows2 == 0, "overlapping rows must not re-embed"

    rows.append(("imdb_review", warm_total, cold_total))
    return rows


if __name__ == "__main__":
    main(small=True)
