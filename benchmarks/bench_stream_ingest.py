"""Stream ingestion: standing-query ticks vs full re-filtering per tick.

Workload: one deterministic synthetic stream (the Fig. 4 imdb rows
arriving in fixed per-tick batches) watched by two standing queries
through ``repro.stream.StreamWatcher`` — every tick coalesced-appends the
arrivals and re-votes only the touched clusters, pushing newly-matching
rows to an in-memory sink.  The control re-filters the whole table from
scratch at every tick with a fresh session (what a linear-invocation
deployment without standing queries would pay).

Asserted (the ISSUE-8 acceptance criteria):
- per-tick oracle cost is sublinear: the incremental run's total is
  < 0.5x the per-tick-refilter control's total;
- steady-state ticks pay for their own rows, not the table;
- sinks receive exactly the final matching row set, zero duplicates.

Emitted: total incremental vs control oracle calls (the CI perf gate
compares these against benchmarks/baseline.json) and per-tick means.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset
from repro.stream import CallbackSink, RateBudget, StreamWatcher, SyntheticSource

POL = ExecutionPolicy(n_clusters=4, xi=0.005)
QUERIES = [("q0_pos", "RV-Q1", 7), ("q1_act", "RV-Q3", 8)]


def _oracles(ds):
    return {name: SyntheticOracle(ds.labels[key], flip_prob=0.0, seed=seed,
                                  token_lens=ds.token_lens)
            for name, key, seed in QUERIES}


def main(small: bool = False):
    n = 600 if small else 3000
    per_tick = 60 if small else 150
    ds = make_dataset("imdb_review", n=n, seed=0)

    # ---- incremental: standing queries over the stream -----------------
    sess = Session(policy=POL)
    for name, oracle in _oracles(ds).items():
        sess.register_oracle(name, oracle)
    watcher = StreamWatcher(sess, table_name="feed")
    watcher.add_source(
        SyntheticSource("feed0", texts=list(ds.texts),
                        embeddings=ds.embeddings,
                        arrive_per_tick=per_tick, seed=3),
        RateBudget(rows_per_tick=per_tick))
    events = {name: [] for name, _, _ in QUERIES}
    for name, _, _ in QUERIES:
        watcher.register(name, sink=CallbackSink(
            (lambda L: lambda ev: L.append(ev))(events[name])))
    t0 = time.time()
    summaries = watcher.run()
    inc_wall = time.time() - t0
    inc_calls = [s["oracle_calls"] for s in summaries]
    inc_total = sum(inc_calls)
    tokens = sum(sess.oracle(name).stats.input_tokens
                 + sess.oracle(name).stats.output_tokens
                 for name, _, _ in QUERIES)
    n_ticks = len(summaries)
    # steady state: a tick pays for its own rows across both queries
    assert all(c <= per_tick * len(QUERIES) for c in inc_calls[1:]), inc_calls
    # delivery contract: zero duplicate notifications, and every row the
    # final filter matches was notified once per distinct content (the
    # delta engine dedups content-identical rows).  Rows whose undecided
    # cluster vote flips as clusters grow may be notified then drop out of
    # the final mask — approximation noise, bounded tightly.
    from repro.stream.delta import row_key
    final = {name: sess["feed"].filter(name).collect() for name, _, _ in QUERIES}
    for name, _, _ in QUERIES:
        rows = [e["row"] for e in events[name]]
        assert len(rows) == len(set(rows)), f"{name}: duplicate notification"
        keys = set(e["key"] for e in events[name])
        final_rows = [int(i) for i in final[name].mask.nonzero()[0]]
        silent = [i for i in final_rows if row_key(ds.texts[i], None) not in keys]
        assert not silent, f"{name}: {len(silent)} matches never notified"
        extra = set(rows) - set(final_rows)
        assert len(extra) <= max(2, 0.05 * len(rows)), \
            f"{name}: {len(extra)} vote-flip notifications beyond bound"
    sess.close()

    # ---- control: re-filter the whole prefix from scratch every tick ---
    t0 = time.time()
    full_total = 0
    for t in range(1, n_ticks + 1):
        n_t = min(n, per_tick * t)
        ctl = Session(policy=POL)
        for name, oracle in _oracles(ds).items():
            ctl.register_oracle(name, oracle)
        h = ctl.table(embeddings=ds.embeddings[:n_t], name="feed")
        full_total += sum(h.filter(name).collect().n_llm_calls
                          for name, _, _ in QUERIES)
        ctl.close()
    full_wall = time.time() - t0
    assert inc_total < 0.5 * full_total, (
        f"incremental {inc_total} calls not sublinear vs per-tick "
        f"re-filter {full_total}")

    n_notified = sum(len(v) for v in events.values())
    emit("stream/imdb/incremental", inc_wall / max(1, inc_total) * 1e6,
         f"oracle={inc_total};ticks={n_ticks};rows={n};"
         f"mean_per_tick={inc_total / max(1, n_ticks):.0f};"
         f"notified={n_notified};wall={inc_wall:.2f}s")
    emit("stream/imdb/full_refilter", full_wall / max(1, full_total) * 1e6,
         f"oracle={full_total};ticks={n_ticks};"
         f"mean_per_tick={full_total / max(1, n_ticks):.0f};"
         f"ratio={full_total / max(1, inc_total):.1f}x;wall={full_wall:.2f}s")
    return [("imdb_review", "incremental",
             {"oracle_calls": int(inc_total), "tokens": int(tokens)}),
            ("imdb_review", "full_refilter",
             {"oracle_calls": int(full_total), "tokens": 0})]


if __name__ == "__main__":
    main(small=True)
