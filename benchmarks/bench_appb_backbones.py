"""Appendix B analogue: sensitivity to embedding model and LLM backbone.

Embedding swap = re-embedding with different encoder quality (dim/noise);
LLM swap = oracle flip-probability levels (8B/70B/GPT-4o accuracy tiers).
"""
from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit, run_method
from repro.core import CSVConfig, SemanticTable
from repro.data import make_dataset

EMBEDDERS = {"e5-large": (64, 0.35), "bge-large": (64, 0.5),
             "qwen-0.6b": (32, 0.6)}
BACKBONES = {"llama3-8b": 0.05, "llama3-70b": 0.02, "gpt-4o": 0.01}


def main(small: bool = False):
    rows = []
    n = 3000 if small else 10000
    for emb_name, (dim, noise) in EMBEDDERS.items():
        ds = make_dataset("imdb_review", n=n, seed=0, dim=dim, noise=noise)
        truth = ds.labels["RV-Q1"]
        table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
        out = run_method(table, truth, ds.token_lens, "csv",
                         cfg=CSVConfig(n_clusters=4))
        emit(f"appb/embedder/{emb_name}", 0.0,
             f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
             f"calls={out['oracle_calls']}")
        rows.append(("embedder", emb_name, out))
    ds = make_dataset("imdb_review", n=n, seed=0)
    truth = ds.labels["RV-Q1"]
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    for bb, flip in BACKBONES.items():
        out = run_method(table, truth, ds.token_lens, "csv", flip=flip,
                         cfg=CSVConfig(n_clusters=4))
        emit(f"appb/backbone/{bb}", 0.0,
             f"acc={out['acc']:.4f};f1={out['f1']:.4f};"
             f"calls={out['oracle_calls']}")
        rows.append(("backbone", bb, out))
    return rows


if __name__ == "__main__":
    main()
