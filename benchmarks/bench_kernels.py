"""Kernel micro-benchmarks: wall time of the dispatch ops on this backend
plus analytic arithmetic-intensity / roofline placement for the TPU target."""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.kernels.kmeans.ops import assign_clusters
from repro.kernels.simvote.ops import simvote_scores
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import decode_attention
from repro.utils.timing import time_jax

PEAK_FLOPS, HBM_BW = 197e12, 819e9


def _roofline_note(flops, bytes_):
    ai = flops / max(bytes_, 1)
    knee = PEAK_FLOPS / HBM_BW  # ~240 flops/byte on v5e
    bound = "compute" if ai > knee else "memory"
    return f"arith_intensity={ai:.1f};v5e_bound={bound}"


def main(small: bool = False):
    n, d, k = (2000, 64, 8) if small else (20000, 256, 16)
    x = jax.random.normal(jax.random.key(0), (n, d))
    c = jax.random.normal(jax.random.key(1), (k, d))
    t = time_jax(lambda: jax.block_until_ready(assign_clusters(x, c)))
    fl, by = 2 * n * d * k, 4 * (n * d + k * d + n)
    emit("kernels/kmeans_assign", t / n * 1e6, _roofline_note(fl, by))

    m = 128
    s = jax.random.normal(jax.random.key(2), (m, d))
    y = (jax.random.uniform(jax.random.key(3), (m,)) > 0.5).astype(jnp.float32)
    t = time_jax(lambda: jax.block_until_ready(simvote_scores(x, s, y, 1.0)))
    fl, by = 2 * n * m * d, 4 * (n * d + m * d + 2 * n)
    emit("kernels/simvote", t / n * 1e6, _roofline_note(fl, by))

    B, H, KV, S, hd = (1, 4, 2, 512, 64) if small else (2, 8, 2, 2048, 128)
    q = jax.random.normal(jax.random.key(4), (B, H, S, hd), jnp.float32)
    kk = jax.random.normal(jax.random.key(5), (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(6), (B, KV, S, hd), jnp.float32)
    t = time_jax(lambda: jax.block_until_ready(
        flash_attention(q, kk, v, causal=True)))
    fl = 2 * B * H * S * S * hd  # qk + pv
    by = 2 * B * (H + 2 * KV) * S * hd
    emit("kernels/flash_attention", t / (B * S) * 1e6, _roofline_note(fl, by))

    L = 4096 if small else 32768
    q1 = jax.random.normal(jax.random.key(7), (B, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.key(8), (B, KV, L, hd), jnp.float32)
    vc = jax.random.normal(jax.random.key(9), (B, KV, L, hd), jnp.float32)
    lens = jnp.full((B,), L, jnp.int32)
    t = time_jax(lambda: jax.block_until_ready(
        decode_attention(q1, kc, vc, lens)))
    fl = 2 * B * H * L * hd * 2
    by = 2 * B * 2 * KV * L * hd
    emit("kernels/decode_attention", t / B * 1e6, _roofline_note(fl, by))


if __name__ == "__main__":
    main()
