"""Lazy-API overhead: Session/Query vs. direct ``semantic_filter``.

ISSUE 3 satellite: the declarative layer must add ZERO extra oracle calls
and negligible wall-clock overhead.  Both paths run the Fig. 4 small cases
with identical seeds and a pre-warmed clustering cache, so the measured
difference is exactly the query-building + plan-lowering + result-wrapping
cost of ``repro.api``.

ISSUE 7 satellite: the tracing layer rides the same harness.  A third
timed path runs the API query under a recording ``Tracer`` — the
synthetic oracle is the worst case for tracer overhead (no model compute
to hide behind).  Contract: tracer-disabled (default ``NullTracer``) is
the already-measured api path; tracer-enabled must stay within ~5% of it
on these cases, with bit-identical masks and call counts.

ISSUE 10 satellite: a fourth path runs traced WITH the audit knobs
present but ``audit_rate=0`` (the default) — the shipped configuration
for a monitored deployment that has not opted into auditing.  Contract:
bit-identical masks and call counts vs. the plain api path, <5% wall
overhead vs. the traced path (the audit gate is one float compare).
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.api import ExecutionPolicy, Session
from repro.core import CSVConfig, SemanticTable, SyntheticOracle
from repro.core.csv_filter import semantic_filter
from repro.data import make_dataset
from repro.obs import MetricsRegistry, Tracer, use_tracer

CASES = [("imdb_review", "RV-Q1", 20000), ("airdialogue", "AD-Q1", 20000)]


def main(small: bool = False):
    rows = []
    for ds_name, q, n in CASES:
        if small:
            n = min(n, 4000)
        ds = make_dataset(ds_name, n=n, seed=0)
        truth = ds.labels[q]
        cfg = CSVConfig(n_clusters=4, xi=0.005)
        policy = ExecutionPolicy.from_csv_config(cfg)

        # pre-warm clustering on both paths so the delta is pure API overhead
        table = SemanticTable(embeddings=ds.embeddings)
        assign = table.precluster(cfg.n_clusters, cfg.seed)
        sess = Session()
        handle = sess.table(table=table, name=ds_name)
        handle.precluster(cfg.n_clusters, cfg.seed)

        # untimed warm-up: JIT-compile the kmeans/voting kernels so neither
        # timed path pays one-off compilation
        semantic_filter(ds.embeddings,
                        SyntheticOracle(truth, flip_prob=0.02, seed=7,
                                        token_lens=ds.token_lens),
                        cfg, precomputed_assign=assign)

        def fresh_oracle():
            # a fresh oracle per repetition: same seed => identical work,
            # and no cross-rep memo hits that would shortcut the driver
            return SyntheticOracle(truth, flip_prob=0.02, seed=7,
                                   token_lens=ds.token_lens)

        def best_of(run, reps=5):
            best, result = float("inf"), None
            for _ in range(reps):
                t0 = time.time()
                r = run(fresh_oracle())
                best = min(best, time.time() - t0)
                result = r
            return best, result

        # best-of-N per path: single runs are ~10 ms, dominated by scheduler
        # noise; the minimum isolates the deterministic work
        wall_direct, r_direct = best_of(
            lambda o: semantic_filter(ds.embeddings, o, cfg,
                                      precomputed_assign=assign))
        wall_api, r_api = best_of(
            lambda o: handle.filter(o, name=q, policy=policy).collect())

        def traced_collect(o):
            # fresh tracer per rep: a recording tracer accumulates spans,
            # so reuse would measure list growth, not steady-state cost
            with use_tracer(Tracer(metrics=MetricsRegistry())):
                return handle.filter(o, name=q, policy=policy).collect()

        wall_traced, r_traced = best_of(traced_collect)

        # audit knobs present, rate 0: the monitored-but-unaudited config
        audit_off_policy = policy.replace(audit_rate=0.0, audit_seed=1)

        def traced_audit_off(o):
            with use_tracer(Tracer(metrics=MetricsRegistry())):
                return handle.filter(o, name=q,
                                     policy=audit_off_policy).collect()

        wall_audit_off, r_audit_off = best_of(traced_audit_off)

        identical = bool((r_api.mask == r_direct.mask).all())
        extra_calls = r_api.n_llm_calls - r_direct.n_llm_calls
        overhead_s = wall_api - wall_direct
        overhead_pct = overhead_s / max(wall_direct, 1e-9) * 100
        # ISSUE 7: tracing must observe, never perturb
        assert bool((r_traced.mask == r_api.mask).all()), \
            f"{ds_name}/{q}: traced run changed the mask"
        assert r_traced.n_llm_calls == r_api.n_llm_calls, \
            (f"{ds_name}/{q}: traced run changed call count "
             f"({r_traced.n_llm_calls} vs {r_api.n_llm_calls})")
        trace_pct = (wall_traced - wall_api) / max(wall_api, 1e-9) * 100
        # ISSUE 10: audit-off must be invisible — identical work, and the
        # rate gate costs nothing measurable on top of tracing
        assert bool((r_audit_off.mask == r_api.mask).all()), \
            f"{ds_name}/{q}: audit-off run changed the mask"
        assert r_audit_off.n_llm_calls == r_api.n_llm_calls, \
            (f"{ds_name}/{q}: audit-off run changed call count "
             f"({r_audit_off.n_llm_calls} vs {r_api.n_llm_calls})")
        audit_off_pct = ((wall_audit_off - wall_traced)
                         / max(wall_traced, 1e-9) * 100)
        emit(f"api_overhead/{ds_name}/{q}",
             wall_api / max(1, r_api.n_llm_calls) * 1e6,
             f"direct_s={wall_direct:.3f};api_s={wall_api:.3f};"
             f"overhead_ms={overhead_s*1e3:.1f};overhead_pct={overhead_pct:.1f};"
             f"extra_oracle_calls={extra_calls};identical_mask={identical};"
             f"traced_s={wall_traced:.3f};trace_overhead_pct={trace_pct:.1f};"
             f"audit_off_s={wall_audit_off:.3f};"
             f"audit_off_pct={audit_off_pct:.1f}")
        rows.append((ds_name, q, wall_direct, wall_api, extra_calls,
                     identical, wall_traced, wall_audit_off))
    return rows


if __name__ == "__main__":
    main(small=True)
