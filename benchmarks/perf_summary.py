"""Before/after perf comparison across dry-run tags -> markdown for
EXPERIMENTS.md §Perf."""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline_report import ART, model_flops_per_device


def load(tag):
    out = {}
    for f in sorted(ART.glob(f"*__{tag}.json")):
        a = json.loads(f.read_text())
        if a.get("ok") and not a.get("skipped_by_design"):
            out[(a["arch"], a["shape"], a["mesh"])] = a
    return out


def fmt(a):
    t = a["roofline_terms"]
    temp = a["memory"].get("temp_size_in_bytes", 0) / 1e9
    mf = model_flops_per_device(a["arch"], a["shape"], a["chips"])
    dom_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
    frac = (mf / 197e12) / max(dom_s, 1e-30)
    return t, temp, frac


def compare(base_tag="baseline2", opt_tag="opt", mesh="pod"):
    base, opt = load(base_tag), load(opt_tag)
    lines = [
        "| arch | shape | term | before | after | Δ |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        if key not in opt or key[2] != mesh:
            continue
        (tb, mb, fb), (to, mo, fo) = fmt(base[key]), fmt(opt[key])
        for term, label in [("compute_s", "compute"), ("memory_s", "memory"),
                            ("collective_s", "collective")]:
            b, o = tb[term] * 1e3, to[term] * 1e3
            if b < 0.05 and o < 0.05:
                continue
            d = (b - o) / b * 100 if b else 0.0
            lines.append(f"| {key[0]} | {key[1]} | {label} | {b:.1f} ms | "
                         f"{o:.1f} ms | {d:+.0f}% |")
        lines.append(f"| {key[0]} | {key[1]} | HBM temp | {mb:.1f} GB | "
                     f"{mo:.1f} GB | {(mb-mo)/mb*100 if mb else 0:+.0f}% |")
        lines.append(f"| {key[0]} | {key[1]} | roofline frac | {fb:.3f} | "
                     f"{fo:.3f} | x{fo/max(fb,1e-9):.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(compare())
