"""Fig. 2 analogue: label-agreement probability vs embedding distance."""
from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.data import make_dataset

CASES = [("imdb_review", "RV-Q1"), ("imdb_review", "RV-Q2"),
         ("imdb_review", "RV-Q3"), ("codebase", "CB-Q1"),
         ("codebase", "CB-Q2"), ("tc", "TC")]


def main(small: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    for ds_name, q in CASES[:3] if small else CASES:
        ds = make_dataset(ds_name, n=3000 if small else 8000, seed=0)
        lab = ds.labels[q]
        n = len(lab)
        i = rng.integers(0, n, 60000)
        j = rng.integers(0, n, 60000)
        d = np.linalg.norm(ds.embeddings[i] - ds.embeddings[j], axis=1)
        agree = (lab[i] == lab[j]).astype(float)
        bins = np.quantile(d, np.linspace(0, 1, 11))
        means = []
        for b in range(10):
            m = (d >= bins[b]) & (d < bins[b + 1] + 1e-9)
            means.append(float(agree[m].mean()) if m.any() else float("nan"))
        slope = means[0] - means[-1]
        emit(f"fig2/{q}", 0.0,
             "agree_by_decile=" + "|".join(f"{v:.3f}" for v in means)
             + f";near_minus_far={slope:.3f}")
        rows.append((q, means, slope))
        if q in ("RV-Q1", "CB-Q2", "TC"):  # primary (balanced) predicates
            assert slope > 0, f"{q}: agreement must decay with distance"
    return rows


if __name__ == "__main__":
    main()
