"""Shared benchmark plumbing: datasets, oracles, method runners, CSV output."""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import (CSVConfig, SemanticTable, SyntheticOracle, ProxyModel,
                        reference_filter)
from repro.core.operators import accuracy_f1
from repro.data import make_dataset

# "pricing" for derived cost metrics: oracle-vs-proxy relative cost (the
# paper uses LLaMA-8B oracle vs 3B proxy => ~2.7x weight per call)
ORACLE_COST, PROXY_COST = 1.0, 0.375


def run_method(table, truth, token_lens, method, flip=0.02, cfg=None,
               proxy_kw=None, seed=7, **kw):
    oracle = SyntheticOracle(truth, flip_prob=flip, seed=seed,
                             token_lens=token_lens)
    t0 = time.time()
    if method == "reference":
        r = reference_filter(len(truth), oracle)
    elif method in ("lotus", "bargain"):
        proxy = ProxyModel(truth, token_lens=token_lens,
                           **(proxy_kw or dict(quality=0.8, center=0.82,
                                               concentration=0.15)))
        r = table.sem_filter(oracle, method=method, proxy=proxy, **kw)
    else:
        r = table.sem_filter(oracle, method=method, cfg=cfg, **kw)
    wall = time.time() - t0
    acc, f1 = accuracy_f1(r.mask, truth)
    oracle_calls = getattr(r, "n_llm_calls", getattr(r, "n_oracle_calls", 0))
    proxy_calls = getattr(r, "n_proxy_calls", 0)
    return {
        "method": method, "acc": acc, "f1": f1,
        "oracle_calls": oracle_calls, "proxy_calls": proxy_calls,
        "weighted_calls": oracle_calls * ORACLE_COST + proxy_calls * PROXY_COST,
        "tokens": getattr(r, "input_tokens", 0) + getattr(r, "output_tokens", 0),
        "wall_s": wall,
        # serving-side efficiency: tuples per model invocation.  The round
        # executor submits cross-cluster round batches, so this grows from
        # ~per-cluster sample size to the full-round aggregate.
        "mean_oracle_batch": oracle.stats.mean_batch_size,
        "oracle_invocations": len(oracle.stats.batch_sizes),
        "result": r,
    }


def emit(name: str, us_per_call: float, derived: str):
    """Scaffold contract: ``name,us_per_call,derived`` CSV line."""
    print(f"{name},{us_per_call:.3f},{derived}")
