"""Shared benchmark plumbing: datasets, oracles, method runners, CSV output."""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle, ProxyModel
from repro.core.operators import accuracy_f1
from repro.data import make_dataset

# "pricing" for derived cost metrics: oracle-vs-proxy relative cost (the
# paper uses LLaMA-8B oracle vs 3B proxy => ~2.7x weight per call)
ORACLE_COST, PROXY_COST = 1.0, 0.375


def run_method(table, truth, token_lens, method, flip=0.02, cfg=None,
               proxy_kw=None, seed=7, **kw):
    """One method run via the canonical ``repro.api`` session layer
    (bit-identical to the legacy ``sem_filter`` dispatch — tests/test_api.py).
    ``table`` may be a ``SemanticTable`` (wrapped) or a ``TableHandle``."""
    oracle = SyntheticOracle(truth, flip_prob=flip, seed=seed,
                             token_lens=token_lens)
    proxy = None
    if method in ("lotus", "bargain"):
        proxy = ProxyModel(truth, token_lens=token_lens,
                           **(proxy_kw or dict(quality=0.8, center=0.82,
                                               concentration=0.15)))
    policy = ExecutionPolicy.from_csv_config(cfg, method=method,
                                             baseline=dict(kw)) \
        if cfg is not None else ExecutionPolicy(method=method,
                                                baseline=dict(kw))
    handle = table if hasattr(table, "session") else Session().table(table=table)
    t0 = time.time()
    qr = handle.filter(oracle, name="bench", proxy=proxy,
                       policy=policy).collect()
    wall = time.time() - t0
    acc, f1 = accuracy_f1(qr.mask, truth)
    # per-predicate FilterResult for csv paths (recluster/round detail);
    # BaselineResult otherwise
    r = (qr.raw.results["bench"] if qr.kind == "filter" else qr.raw)
    return {
        "method": method, "acc": acc, "f1": f1,
        "oracle_calls": qr.n_llm_calls, "proxy_calls": qr.n_proxy_calls,
        "weighted_calls": (qr.n_llm_calls * ORACLE_COST
                          + qr.n_proxy_calls * PROXY_COST),
        "tokens": qr.input_tokens + qr.output_tokens,
        "wall_s": wall,
        # serving-side efficiency: tuples per model invocation.  The round
        # executor submits cross-cluster round batches, so this grows from
        # ~per-cluster sample size to the full-round aggregate.
        "mean_oracle_batch": oracle.stats.mean_batch_size,
        "oracle_invocations": len(oracle.stats.batch_sizes),
        "result": r,
    }


def emit(name: str, us_per_call: float, derived: str):
    """Scaffold contract: ``name,us_per_call,derived`` CSV line."""
    print(f"{name},{us_per_call:.3f},{derived}")
