"""Table 5 analogue: theory-vs-practice gap on one representative cluster.

For each error tolerance eps: compute the required xi from Theorems 3.3/3.6,
sample at that ratio, and report Est. (fraction of committed votes agreeing
with the LLM label) and Err. (|sample mean - population mean|)."""
from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, "src")

from benchmarks.common import emit
from repro.core import theory
from repro.core.oracle import SyntheticOracle
from repro.data import make_dataset


def main(small: bool = False):
    n = 4000 if small else 14608  # paper's representative cluster size
    ds = make_dataset("imdb_review", n=2 * n, seed=0)
    # pick the largest pure topic as "the representative cluster"
    from collections import Counter
    top = Counter(ds.topics.tolist()).most_common(1)[0][0]
    members = np.nonzero(ds.topics == top)[0][:n]
    oracle = SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.02, seed=7)
    x = oracle(members).astype(float)  # LLM labels of the cluster
    mu = x.mean()
    conf = max(mu, 1 - mu)
    sigma2 = mu * (1 - mu)
    rng = np.random.default_rng(0)
    rows = []
    for eps in [0.10, 0.15, 0.20, 0.25, 0.30]:
        for vote, xi_fn in [("uni", theory.xi_for_epsilon_univote),
                            ("sim", lambda e, s: theory.xi_for_epsilon_simvote(
                                e, s, v=2.0))]:
            xi = xi_fn(eps, sigma2)
            k = max(2, int(np.ceil(xi * len(members))))
            ests, errs = [], []
            for _ in range(30):
                idx = rng.choice(len(members), size=k, replace=False)
                score = x[idx].mean()
                vote_label = score >= 0.5
                est = (x == vote_label).mean() if vote_label else (x == 0).mean()
                ests.append(max(est, 1 - est))
                errs.append(abs(score - mu))
            emit(f"table5/{vote}/eps={eps:.2f}", 0.0,
                 f"xi_permil={xi*1000:.1f};est={np.mean(ests):.4f};"
                 f"err={np.mean(errs):.4f};cluster_conf={conf:.4f}")
            rows.append((vote, eps, xi, float(np.mean(ests)),
                         float(np.mean(errs))))
    return rows


if __name__ == "__main__":
    main()
