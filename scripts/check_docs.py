#!/usr/bin/env python
"""Docs honesty gate: every link and path named in the docs must exist.

Scans ``docs/*.md`` and ``ROADMAP.md`` for

- relative markdown links  — ``[text](other.md)``, ``[x](../benchmarks/...)``
  must resolve from the referencing file's directory (fragments ignored);
- repo file paths          — ``src/repro/...``, ``tests/...``,
  ``benchmarks/...``, ``examples/...``, ``scripts/...``, ``docs/...`` and
  the ``launch/<file>`` shorthand (→ ``src/repro/launch/<file>``) must
  name an existing file or directory;
- dotted module paths      — ``repro.service.log`` must import from
  ``src/`` as a module/package, allowing one trailing attribute segment
  (``repro.plan.cost.est_oracle_calls`` checks ``repro/plan/cost.py``).

Exit 1 with one line per dangling reference.  CI runs this as the
``docs-check`` job; run locally with ``python scripts/check_docs.py``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(
    r"\b((?:src|tests|benchmarks|examples|scripts|docs|launch)/"
    r"[A-Za-z0-9_\-./]*[A-Za-z0-9_\-])")
MODULE_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+\b")


def _iter_sources():
    yield ROOT / "ROADMAP.md"
    yield from sorted((ROOT / "docs").glob("*.md"))


def check_links(src: pathlib.Path, text: str, errors: list) -> None:
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (src.parent / rel).exists():
            errors.append(f"{src.relative_to(ROOT)}: broken link ({target})")


def check_paths(src: pathlib.Path, text: str, errors: list) -> None:
    for m in PATH_RE.finditer(text):
        path = m.group(1)
        if path.startswith("launch/"):
            path = "src/repro/" + path
        if not (ROOT / path).exists():
            errors.append(
                f"{src.relative_to(ROOT)}: dangling path ({m.group(1)})")


def check_modules(src: pathlib.Path, text: str, errors: list) -> None:
    for m in MODULE_RE.finditer(text):
        parts = m.group(0).split(".")
        # allow one trailing attribute: repro.plan.cost.est_oracle_calls
        for trim in (parts, parts[:-1]):
            if trim == ["repro"]:
                continue
            base = ROOT / "src" / pathlib.Path(*trim)
            if base.with_suffix(".py").exists() or \
                    (base / "__init__.py").exists():
                break
        else:
            errors.append(
                f"{src.relative_to(ROOT)}: unresolvable module "
                f"({m.group(0)})")


def main() -> int:
    errors: list = []
    n_files = 0
    for src in _iter_sources():
        if not src.exists():
            errors.append(f"missing source file: {src}")
            continue
        n_files += 1
        text = src.read_text()
        check_links(src, text, errors)
        check_paths(src, text, errors)
        check_modules(src, text, errors)
    # docs/README.md must link every sibling document
    readme = (ROOT / "docs" / "README.md").read_text()
    for doc in sorted((ROOT / "docs").glob("*.md")):
        if doc.name != "README.md" and f"({doc.name})" not in readme:
            errors.append(f"docs/README.md: does not link {doc.name}")
    for e in errors:
        print(f"docs-check: {e}")
    print(f"docs-check: {n_files} files scanned, "
          f"{len(errors)} dangling reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
