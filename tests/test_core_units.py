"""Unit tests: voting, clustering, baselines, bm25, oracle, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import kmeans, kmeans_predict, minibatch_kmeans_update
from repro.core.voting import uni_vote, sim_vote
from repro.core.bm25 import bm25_vectors, hybrid_features
from repro.core.oracle import SyntheticOracle, ProxyModel
from repro.data import make_dataset, HashTokenizer, PackedLoader


# ------------------------------------------------------------------ voting
def test_uni_vote_cases():
    hi = uni_vote(np.ones(10), 5, 0.15, 0.85)
    assert len(hi.decided_true) == 5 and len(hi.undetermined) == 0
    lo = uni_vote(np.zeros(10), 5, 0.15, 0.85)
    assert len(lo.decided_false) == 5
    mid = uni_vote(np.array([1, 0, 1, 0]), 5, 0.15, 0.85)
    assert len(mid.undetermined) == 5
    # empty sample = no evidence: undetermined, never a silent False vote
    none = uni_vote(np.zeros(0), 5, 0.15, 0.85)
    assert len(none.undetermined) == 5 and len(none.decided_false) == 0


def test_sim_vote_prefers_near_neighbors():
    """A tuple near positive samples scores higher than one near negatives."""
    s = np.array([[0, 0], [10, 10]], np.float32)
    y = np.array([1.0, 0.0])
    x = np.array([[0.5, 0.5], [9.5, 9.5]], np.float32)
    vr = sim_vote(x, s, y, lb=0.3, ub=0.7, bandwidth=2.0)
    assert vr.scores[0] > 0.7 and vr.scores[1] < 0.3
    assert 0 in vr.decided_true and 1 in vr.decided_false


def test_sim_vote_uniform_when_equidistant():
    s = np.array([[1, 0], [-1, 0]], np.float32)
    y = np.array([1.0, 0.0])
    x = np.array([[0, 5]], np.float32)
    vr = sim_vote(x, s, y, lb=0.15, ub=0.85, bandwidth=1.0)
    assert vr.scores[0] == pytest.approx(0.5, abs=1e-5)


# ---------------------------------------------------------------- clustering
def test_kmeans_recovers_separated_clusters():
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0], [20, 0], [0, 20]], np.float32)
    pts = np.concatenate([c + rng.normal(0, 0.5, (50, 2)) for c in centers])
    cents, assign, inertia = kmeans(jax.random.key(0),
                                    jnp.asarray(pts, jnp.float32), 3)
    assign = np.asarray(assign)
    # each true cluster maps to exactly one label
    for i in range(3):
        assert len(np.unique(assign[i * 50:(i + 1) * 50])) == 1
    assert float(inertia) < 150 * 1.0


def test_kmeans_predict_matches_train_assign():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(200, 8)), jnp.float32)
    cents, assign, _ = kmeans(jax.random.key(1), x, 4)
    assert (np.asarray(kmeans_predict(x, cents)) == np.asarray(assign)).all()


def test_minibatch_update_moves_centroids_toward_batch():
    cents = jnp.zeros((2, 2), jnp.float32).at[1].set(100.0)
    counts = jnp.ones((2,), jnp.float32)
    batch = jnp.asarray([[4.0, 4.0], [6.0, 6.0]], jnp.float32)
    new, counts = minibatch_kmeans_update(cents, counts, batch)
    assert float(jnp.linalg.norm(new[0] - 5.0)) < float(jnp.linalg.norm(cents[0] - 5.0))


# ------------------------------------------------------------------- oracle
def test_oracle_memoization_and_flips():
    labels = np.array([True] * 50 + [False] * 50)
    o = SyntheticOracle(labels, flip_prob=0.0, seed=0)
    out = o(np.arange(100))
    assert (out == labels).all()
    o(np.arange(100))
    assert o.stats.n_calls == 100 and o.stats.n_cached == 100

    o2 = SyntheticOracle(labels, flip_prob=1.0, seed=0)
    assert (o2(np.arange(100)) == ~labels).all()


def test_proxy_concentration_controls_score_spread():
    labels = np.random.default_rng(0).random(2000) < 0.5
    wide = ProxyModel(labels, concentration=1.0, seed=1)
    narrow = ProxyModel(labels, concentration=0.1, center=0.82, seed=1)
    assert np.std(narrow.scores) < np.std(wide.scores) / 3
    assert 0.75 < narrow.scores.mean() < 0.9  # Fig. 1(a) band


# --------------------------------------------------------------------- bm25
def test_bm25_separates_vocabularies():
    a = ["python code compiler"] * 3
    b = ["sunny weather garden"] * 3
    vecs = bm25_vectors(a + b, dim=64)
    sims_within = vecs[0] @ vecs[1]
    sims_across = vecs[0] @ vecs[4]
    assert sims_within > sims_across


def test_hybrid_features_shapes():
    emb = np.random.default_rng(0).normal(size=(10, 16)).astype(np.float32)
    texts = [f"doc {i} python code" for i in range(10)]
    assert hybrid_features(emb, texts, lam=1.0).shape == (10, 16)
    assert hybrid_features(emb, texts, lam=0.4, bm25_dim=32).shape == (10, 48)


# --------------------------------------------------------------------- data
def test_datasets_have_declared_selectivity():
    ds = make_dataset("codebase", n=5000, seed=0)
    assert abs(ds.selectivity["CB-Q1"] - 0.033) < 0.02
    ds2 = make_dataset("airdialogue", n=5000, seed=0)
    assert abs(ds2.selectivity["AD-Q2"] - 0.0146) < 0.02


def test_distance_label_agreement_decays():
    """Fig. 2: closer pairs agree more often."""
    ds = make_dataset("imdb_review", n=2000, seed=0)
    rng = np.random.default_rng(0)
    i = rng.integers(0, 2000, 4000)
    j = rng.integers(0, 2000, 4000)
    d = np.linalg.norm(ds.embeddings[i] - ds.embeddings[j], axis=1)
    agree = ds.labels["RV-Q1"][i] == ds.labels["RV-Q1"][j]
    near = agree[d < np.quantile(d, 0.2)].mean()
    far = agree[d > np.quantile(d, 0.8)].mean()
    assert near > far + 0.1


def test_tokenizer_stable_and_bounded():
    tok = HashTokenizer(1024)
    ids = tok.encode("Hello world, hello WORLD!")
    assert ids == tok.encode("Hello world, hello WORLD!")
    assert all(0 <= i < 1024 for i in ids)
    assert tok.token_id("yes") == 3 and tok.token_id("no") == 4


def test_loader_deterministic_restart():
    docs = [[i, i + 1, i + 2] for i in range(200)]
    ld = PackedLoader(docs, batch=2, seq=8, seed=0)
    b5 = ld.batch_at(5)
    ld2 = PackedLoader(docs, batch=2, seq=8, seed=0)
    b5b = ld2.batch_at(5)
    assert (b5["tokens"] == b5b["tokens"]).all()
    assert b5["tokens"].shape == (2, 8)
    # targets are tokens shifted by one
    assert (b5["tokens"][:, 1:] == b5["targets"][:, :-1]).all()
