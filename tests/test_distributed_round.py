"""Sharded rounds + dispatch coordinator: the bit-identity contracts.

Sharding a round (repro.distributed.round) and merging several
schedulers into one dispatch lane (repro.distributed.coordinator) are
physical knobs: the Fig. 4 filter cases must produce byte-identical
masks, call counts, and cluster logs at any shard count, and a
kill-mid-run restart through the append-only log must replay at ~0
oracle calls.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.core import CSVConfig, SyntheticOracle, semantic_filter
from repro.data import make_dataset
from repro.distributed import DispatchCoordinator, shard_clusters
from repro.service import FilterService

N = 3000


@pytest.fixture(scope="module")
def ds():
    return make_dataset("imdb_review", n=N, seed=0)


def _run(ds, shards, vote="uni", query="RV-Q1", xi=0.005):
    oracle = SyntheticOracle(ds.labels[query], flip_prob=0.02, seed=7,
                             token_lens=ds.token_lens)
    cfg = CSVConfig(n_clusters=4, xi=xi, vote=vote, shards=shards)
    return semantic_filter(ds.embeddings, oracle, cfg), oracle


# ------------------------------------------------------------ bit-identity
@pytest.mark.parametrize("vote", ["uni", "sim"])
@pytest.mark.parametrize("shards", [2, 3, 5])
def test_sharded_round_bit_identical(ds, vote, shards):
    """Fig. 4 cases: any shard count == single-host, byte for byte."""
    r1, o1 = _run(ds, 1, vote)
    rs, os_ = _run(ds, shards, vote)
    assert (r1.mask == rs.mask).all()
    assert r1.n_llm_calls == rs.n_llm_calls
    assert r1.cluster_log == rs.cluster_log
    assert r1.n_voted == rs.n_voted and r1.n_fallback == rs.n_fallback
    assert r1.recluster_rounds == rs.recluster_rounds
    # the oracle consumed the identical flip stream: per-id memo equal
    assert o1.memo_snapshot() == os_.memo_snapshot()
    # each round actually split: one oracle batch per (non-empty) shard,
    # and the shard batches concatenate to the single-host batch
    for rr1, rrs in zip(r1.round_log, rs.round_log):
        assert rrs.shards >= 1 and rrs.shards <= shards
        assert sum(rrs.oracle_batches) == sum(rr1.oracle_batches)
    assert any(rr.shards > 1 for rr in rs.round_log)


def test_sharded_round_through_policy(ds):
    """ExecutionPolicy(shards=N) flows through Session.collect()."""
    def collect(shards):
        sess = Session(policy=ExecutionPolicy(n_clusters=4, xi=0.005,
                                              shards=shards))
        t = sess.table(embeddings=ds.embeddings, name="reviews")
        o = SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.02, seed=7,
                            token_lens=ds.token_lens)
        r = t.filter(o, name="q").collect()
        sess.close()
        return r

    r1, r3 = collect(1), collect(3)
    assert (r1.mask == r3.mask).all()
    assert r1.n_llm_calls == r3.n_llm_calls


def test_shards_validation():
    with pytest.raises(ValueError, match="shards"):
        ExecutionPolicy(shards=0)
    with pytest.raises(ValueError, match="executor"):
        ExecutionPolicy(shards=2, executor="sequential")
    with pytest.raises(ValueError, match="executor"):
        semantic_filter(np.zeros((4, 2), np.float32),
                        SyntheticOracle(np.zeros(4, bool)),
                        CSVConfig(shards=2, executor="sequential"))


def test_shard_clusters_contiguous_and_balanced():
    @dataclasses.dataclass
    class _CP:
        n_sample: int

    clusters = [_CP(n) for n in (5, 5, 5, 50, 5, 5, 5, 5, 50, 5)]
    shards = shard_clusters(clusters, 3)
    # partition: contiguous, complete, order-preserving
    flat = [cp for s in shards for cp in s]
    assert flat == clusters
    assert 1 < len(shards) <= 3
    # more shards than clusters degrades gracefully to one each
    tiny = shard_clusters(clusters[:2], 8)
    assert [cp for s in tiny for cp in s] == clusters[:2]
    # single shard passes through
    assert shard_clusters(clusters, 1) == [clusters]


# ------------------------------------------------------------- coordinator
def test_coordinator_merges_lanes_bit_identically(ds):
    """Several schedulers feeding ONE dispatch lane: same masks as
    serial collect, lanes accounted, detach on session close."""
    def serial(query):
        sess = Session(policy=ExecutionPolicy(n_clusters=4, xi=0.005))
        t = sess.table(embeddings=ds.embeddings, name="reviews")
        o = SyntheticOracle(ds.labels[query], flip_prob=0.02, seed=7,
                            token_lens=ds.token_lens)
        r = t.filter(o, name="q").collect()
        sess.close()
        return r

    coord = DispatchCoordinator()
    try:
        sessions, tickets, want = [], [], []
        for query in ("RV-Q1", "RV-Q3"):
            sess = Session(policy=ExecutionPolicy(n_clusters=4, xi=0.005),
                           coordinator=coord)
            t = sess.table(embeddings=ds.embeddings, name="reviews")
            o = SyntheticOracle(ds.labels[query], flip_prob=0.02, seed=7,
                                token_lens=ds.token_lens)
            with sess.scheduler.holding():
                tickets.append(sess.scheduler.submit(
                    t.filter(o, name="q")))
            sessions.append(sess)
            want.append(serial(query))
        got = [tk.result() for tk in tickets]
        for r, w in zip(got, want):
            assert (r.mask == w.mask).all()
            assert r.n_llm_calls == w.n_llm_calls
        assert coord.n_attached == 2
        stats = coord.stats()
        assert len(stats) == 2
        assert all(ls.n_waves > 0 for ls in stats.values())
        for sess in sessions:
            sess.close()
        assert coord.n_attached == 0
    finally:
        coord.close()


def test_coordinator_lane_rejects_use_after_close():
    coord = DispatchCoordinator()
    try:
        lane = coord.attach(label="x")
        lane.close()
        lane.close()  # idempotent
        with pytest.raises(RuntimeError):
            lane.submit_call(lambda: None)
    finally:
        coord.close()


# --------------------------------------------------- kill-mid-run restart
def test_kill_mid_run_restart_replays_from_log(ds, tmp_path):
    """Crash after some queries completed: restart = snapshot-load +
    log-tail replay, and the completed work replays at ~0 oracle calls
    without re-running k-means."""
    def build():
        sess = Session(policy=ExecutionPolicy(
            n_clusters=4, xi=0.005, shards=2, log_dir=str(tmp_path),
            log_compact_records=4))   # low threshold: force a compaction
        t = sess.table(embeddings=ds.embeddings, name="reviews")
        sess.register_oracle("A", SyntheticOracle(
            ds.labels["RV-Q1"], flip_prob=0.02, seed=7,
            token_lens=ds.token_lens))
        sess.register_oracle("B", SyntheticOracle(
            ds.labels["RV-Q3"], flip_prob=0.02, seed=7,
            token_lens=ds.token_lens))
        svc = FilterService(sess)
        svc.register_tenant("t0", sess.policy)
        return sess, t, svc

    sess1, t1, svc1 = build()
    rep0 = svc1.restore()          # fresh dir: nothing to replay
    assert rep0 is None
    (rA,) = svc1.gather(svc1.submit("t0", t1.filter("A")))
    (rB,) = svc1.gather(svc1.submit("t0", t1.filter("B")))
    assert svc1.log._gen >= 1      # thresholds forced >= 1 compaction
    svc1.log.abandon()             # kill -9: no close, no final snapshot
    sess1.close()

    sess2, t2, svc2 = build()
    rep = svc2.restore()
    assert rep is not None and rep.n_dropped == 0
    assert rep.snapshot is not None       # restart went through a snapshot
    # the precluster replayed from snapshot/log — no k-means refit needed
    assert sess2._assign_cache or t2._table._assign_cache
    (r2A,) = svc2.gather(svc2.submit("t0", t2.filter("A")))
    (r2B,) = svc2.gather(svc2.submit("t0", t2.filter("B")))
    assert (r2A.mask == rA.mask).all() and (r2B.mask == rB.mask).all()
    assert r2A.n_llm_calls == 0 and r2B.n_llm_calls == 0
    assert sess2.stats.n_calls == 0
    svc2.close()
