"""Oracle dispatchers and per-node stats scoping (repro.core.oracle)."""
import numpy as np
import pytest

from repro.core import (AsyncOracleDispatcher, SyncOracleDispatcher,
                        SyntheticOracle)


class _ExplodingOracle:
    def __call__(self, ids):
        raise ValueError("backend down")


class _RecordingOracle:
    def __init__(self):
        self.batches = []

    def __call__(self, ids):
        ids = np.asarray(ids)
        self.batches.append(ids.copy())
        return ids % 2 == 0


@pytest.mark.parametrize("dispatcher_cls",
                         [SyncOracleDispatcher, AsyncOracleDispatcher])
def test_exception_propagates_through_result(dispatcher_cls):
    """A failing oracle must surface at .result(), not hang or vanish."""
    d = dispatcher_cls(_ExplodingOracle())
    try:
        fut = d.submit(np.array([1, 2, 3]))
        with pytest.raises(ValueError, match="backend down"):
            fut.result()
    finally:
        d.close()


@pytest.mark.parametrize("dispatcher_cls",
                         [SyncOracleDispatcher, AsyncOracleDispatcher])
def test_close_is_idempotent(dispatcher_cls):
    d = dispatcher_cls(_RecordingOracle())
    assert d.submit(np.array([1])).result() is not None
    d.close()
    d.close()  # second close must be a no-op, not an error


def test_async_dispatch_is_fifo():
    """Strict submission-order evaluation is the executor's bit-identity
    contract (memo + flip-stream order)."""
    oracle = _RecordingOracle()
    d = AsyncOracleDispatcher(oracle)
    try:
        batches = [np.arange(i * 10, i * 10 + 5) for i in range(6)]
        futs = [d.submit(b) for b in batches]
        for b, f in zip(batches, futs):
            assert (f.result() == (b % 2 == 0)).all()
    finally:
        d.close()
    assert [b[0] for b in oracle.batches] == [0, 10, 20, 30, 40, 50]


def test_exception_does_not_poison_later_submissions():
    ok = _RecordingOracle()

    class Flaky:
        def __init__(self):
            self.n = 0

        def __call__(self, ids):
            self.n += 1
            if self.n == 1:
                raise RuntimeError("transient")
            return ok(ids)

    d = AsyncOracleDispatcher(Flaky())
    try:
        bad = d.submit(np.array([1]))
        good = d.submit(np.array([2]))
        with pytest.raises(RuntimeError):
            bad.result()
        assert (good.result() == np.array([True])).all()
    finally:
        d.close()


def test_stats_scope_isolates_per_node_accounting():
    labels = np.zeros(100, dtype=bool)
    oracle = SyntheticOracle(labels, token_lens=np.full(100, 10))
    oracle(np.arange(10))  # prior traffic from another plan node
    with oracle.scope() as sc:
        oracle(np.arange(5, 15))  # 5 memo hits (5..9) + 5 fresh (10..14)
    assert sc.delta.n_calls == 5
    assert sc.delta.n_cached == 5
    assert sc.delta.input_tokens == 50
    assert sc.delta.batch_sizes == [5]
    # the scope is a view on deltas; lifetime stats are untouched
    assert oracle.stats.n_calls == 15


def test_stats_scope_fills_delta_on_exception():
    oracle = SyntheticOracle(np.zeros(10, dtype=bool))
    with pytest.raises(RuntimeError):
        with oracle.scope() as sc:
            oracle(np.arange(4))
            raise RuntimeError("node failed")
    assert sc.delta is not None and sc.delta.n_calls == 4
