"""Fused one-engine serving path: cross-oracle packing, tick pipelining,
token_ids fast path, and Pallas attention as wired into the model layers.

All kernel checks run in interpret mode so the exact serving code path is
validated on CPU; bit-identity checks use exact equality (verified stable
on the XLA CPU backend: last-position logits are invariant to batch
composition and right-padding under causal masking).
"""
import jax
import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.configs import smoke_config
from repro.core.oracle import ModelOracle, SyntheticOracle, evaluate_packed
from repro.data import make_dataset
from repro.data.tokenizer import HashTokenizer
from repro.models import lm
from repro.serving import BucketBatcher, ServingEngine
from repro.serving.batcher import DispatchMergeStats


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config("qwen1.5-0.5b")
    params = lm.init_params(cfg, jax.random.key(0))
    return cfg, params


# ---------------------------------------------------------------- kernels


def test_attention_apply_flash_parity(tiny_model):
    """attn_impl="flash" (Pallas, interpret on CPU) and "flash-ref" match
    the plain path through the full forward."""
    cfg, params = tiny_model
    tok = HashTokenizer(cfg.vocab_size)
    toks = np.stack([tok.encode("some words repeated here " * 8)[:32],
                     tok.encode("another test prompt entirely " * 8)[:32]])
    ref, _ = lm.forward(cfg.replace(attn_impl="plain"), params, toks)
    for impl in ("flash", "flash-ref"):
        got, _ = lm.forward(cfg.replace(attn_impl=impl), params, toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_attention_decode_flash_parity(tiny_model):
    """Greedy decode through attention_decode is identical across the jnp
    path, the Pallas decode kernel (interpret), and its jnp oracle."""
    cfg, params = tiny_model
    tok = HashTokenizer(cfg.vocab_size)
    prompts = [tok.encode("tell me a story about"),
               tok.encode("the quick brown fox jumps over")]
    outs = {}
    for impl in ("plain", "flash", "flash-ref"):
        eng = ServingEngine(cfg.replace(attn_impl=impl), params, max_batch=4)
        outs[impl] = eng.generate(prompts, max_new=6)
    assert outs["flash"] == outs["plain"]
    assert outs["flash-ref"] == outs["plain"]


# ------------------------------------------------------- token_ids fast path


def test_token_ids_fast_path_equivalence(tiny_model):
    cfg, params = tiny_model
    tok = HashTokenizer(cfg.vocab_size)
    prompts = [tok.encode(t) for t in
               ["a b c", "longer prompt with more words in it", "x y",
                "medium sized prompt here"]]
    yes, no = tok.token_id("yes"), tok.token_id("no")
    eng = ServingEngine(cfg, params, max_batch=2)
    full = eng.first_token_logits(prompts)[:, [yes, no]]
    # shared (T,) ids: bit-identical to the full-vocab gather
    sel = eng.first_token_logits(prompts, token_ids=[yes, no])
    assert np.array_equal(sel, full)
    # per-prompt (B, T) ids: same values within einsum-order tolerance
    per = eng.first_token_logits(
        prompts, token_ids=np.tile([yes, no], (len(prompts), 1)))
    np.testing.assert_allclose(per, full, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- packed waves


def _mk_oracles(engine, tok, texts):
    return [ModelOracle(engine, tok, pred, texts) for pred in
            ("the text is positive", "the text mentions acting",
             "the text discusses plot")]


def test_packed_wave_bit_identity(tiny_model):
    """evaluate_packed == per-oracle dispatch: labels, memo, stats — and
    the packed pair logits are bit-identical to per-oracle fast-path
    logits (right-padding/batch composition does not perturb them)."""
    cfg, params = tiny_model
    tok = HashTokenizer(cfg.vocab_size)
    texts = [f"sample review {i} with a few extra words of padding "
             f"{'great' if i % 2 else 'awful'}" for i in range(10)]
    ids = np.arange(10)

    e_solo = ServingEngine(cfg, params, max_batch=32)
    solo = _mk_oracles(e_solo, tok, texts)
    ctrl = [o(ids) for o in solo]

    e_pack = ServingEngine(cfg, params, max_batch=32)
    packed = _mk_oracles(e_pack, tok, texts)
    outs, info = evaluate_packed([(o, ids) for o in packed])
    for a, b in zip(ctrl, outs):
        assert np.array_equal(a, b)
    for a, b in zip(solo, packed):
        assert a.stats.n_calls == b.stats.n_calls
        assert a.stats.batch_sizes == b.stats.batch_sizes
        assert a.memo_snapshot() == b.memo_snapshot()
    assert info["tokens"] > 0
    # packed: 30 prompts in one bucketed wave -> fewer engine invocations
    assert e_pack.stats["batches"] < e_solo.stats["batches"]
    assert e_pack.mean_batch_size > e_solo.mean_batch_size

    # raw logits bit-identity, packed wave vs per-oracle calls
    p_all = [p for o in packed for p in o.pack_prompts(ids)]
    t_all = np.concatenate([o.pack_token_ids(len(ids)) for o in packed])
    wave = ServingEngine(cfg, params, max_batch=32).first_token_logits(
        p_all, token_ids=t_all)
    per = np.concatenate([
        ServingEngine(cfg, params, max_batch=32).first_token_logits(
            o.pack_prompts(ids), token_ids=o.pack_token_ids(len(ids)))
        for o in packed])
    assert np.array_equal(wave, per)


def test_packed_wave_duplicate_oracle_and_synthetic():
    """A duplicated oracle defers to a follow-up pass (memo-consistent);
    non-packable oracles evaluate inline, in request order."""
    labels = np.arange(20) % 2 == 0
    o1 = SyntheticOracle(labels, flip_prob=0.0)
    o2 = SyntheticOracle(~labels, flip_prob=0.0)
    reqs = [(o1, np.arange(5)), (o2, np.arange(10)),
            (o1, np.arange(3, 8))]
    outs, info = evaluate_packed(reqs)
    assert np.array_equal(outs[0], labels[:5])
    assert np.array_equal(outs[1], ~labels[:10])
    assert np.array_equal(outs[2], labels[3:8])
    # second o1 request re-used memo for ids 3..4
    assert o1.stats.n_cached == 2
    assert info["tokens"] > 0


# ------------------------------------------------- service-level assertions


def _model_workload(cfg, params, n=36, max_batch=64):
    ds = make_dataset("imdb_review", n=n, seed=0)
    tok = HashTokenizer(cfg.vocab_size)
    engine = ServingEngine(cfg, params, max_batch=max_batch)
    sess = Session(policy=ExecutionPolicy(n_clusters=2, min_sample=8,
                                          pilot_size=6))
    handle = sess.table(embeddings=ds.embeddings, name="reviews")
    oracles = _mk_oracles(engine, tok, ds.texts)
    qs = [handle.filter(o, name=f"p{i}") for i, o in enumerate(oracles)]
    return sess, handle, qs, oracles, engine


def test_multi_oracle_service_one_invocation_per_tick(tiny_model):
    """The acceptance criterion: one engine invocation per (tick,
    length-bucket) across ALL oracles sharing the engine, with masks and
    call counts bit-identical to serial collects."""
    cfg, params = tiny_model

    # serial control: fresh engine + session, collect one at a time
    sess_s, _, qs_s, oracles_s, _ = _model_workload(cfg, params)
    serial = [q.collect() for q in qs_s]

    # concurrent packed service
    sess_c, _, qs_c, oracles_c, engine = _model_workload(cfg, params)
    with sess_c.scheduler.holding():
        tickets = [sess_c.submit(q) for q in qs_c]
    conc = sess_c.gather(*tickets)
    merge = sess_c.scheduler.stats.merge

    for rs, rc in zip(serial, conc):
        assert (rc.mask == rs.mask).all()
        assert rc.n_llm_calls == rs.n_llm_calls
    for a, b in zip(oracles_s, oracles_c):
        assert a.stats.n_calls == b.stats.n_calls
        assert a.stats.batch_sizes == b.stats.batch_sizes

    # every wave fits max_batch, so each (tick, length-bucket) is exactly
    # one engine invocation; with the short imdb prompts each wave lands
    # in at most 2 buckets
    assert merge.n_invocations <= engine.stats["batches"]
    assert engine.stats["batches"] <= 2 * merge.n_invocations
    assert merge.total_wall_s > 0 and merge.total_tokens > 0
    sess_c.close()

    # per-oracle dispatch control (PR-5 behavior): pack disabled
    sess_u, _, qs_u, _, engine_u = _model_workload(cfg, params)
    sess_u.scheduler.pack = False
    with sess_u.scheduler.holding():
        tickets = [sess_u.submit(q) for q in qs_u]
    unpacked = sess_u.gather(*tickets)
    for rs, ru in zip(serial, unpacked):
        assert (ru.mask == rs.mask).all()
        assert ru.n_llm_calls == rs.n_llm_calls
    # packing grows mean prompts per engine invocation >= 2x
    assert engine.mean_batch_size >= 2 * engine_u.mean_batch_size
    sess_u.close()


def test_pipelined_tick_bit_identity():
    """pipeline_depth > 1 at the service layer changes only scheduling:
    masks and call counts stay bit-identical to depth 1."""
    ds = make_dataset("imdb_review", n=400, seed=0)

    def run(depth):
        pol = ExecutionPolicy(n_clusters=4, xi=0.005, pipeline_depth=depth)
        sess = Session(policy=pol)
        handle = sess.table(embeddings=ds.embeddings, name="reviews")
        oracles = [SyntheticOracle(ds.labels[k], flip_prob=0.02, seed=s,
                                   token_lens=ds.token_lens)
                   for k, s in (("RV-Q1", 7), ("RV-Q2", 8), ("RV-Q3", 9))]
        qs = [handle.filter(o, name=f"p{i}")
              for i, o in enumerate(oracles)]
        assert sess.scheduler.pipeline_depth == depth
        with sess.scheduler.holding():
            tickets = [sess.submit(q) for q in qs]
        res = sess.gather(*tickets)
        stats = sess.scheduler.stats
        sess.close()
        return res, stats

    r1, s1 = run(1)
    r2, s2 = run(2)
    for a, b in zip(r1, r2):
        assert (a.mask == b.mask).all()
        assert a.n_llm_calls == b.n_llm_calls
    # same ids drained overall, split across more (smaller) waves
    assert s1.merge.total_ids == s2.merge.total_ids
    assert s2.merge.n_invocations >= s1.merge.n_invocations


# ----------------------------------------------------- truncation visibility


def test_truncation_stats_surface(tiny_model):
    b = BucketBatcher(max_batch=4, max_bucket=32)
    b.plan([[1] * 40, [2] * 10, [3] * 64])
    assert b.stats["truncated_prompts"] == 2
    assert b.stats["truncated_tokens"] == (40 - 32) + (64 - 32)

    cfg, params = tiny_model
    eng = ServingEngine(cfg, params, max_batch=4)
    eng.batcher.max_bucket = 32
    eng.first_token_logits([[1] * 50, [2] * 10])
    assert eng.stats["truncated_prompts"] == 1
    assert eng.stats["truncated_tokens"] == 18

    m = DispatchMergeStats()
    m.record([4, 4], wall_s=0.5, tokens=100, truncated=1)
    m.record([2], wall_s=0.25, tokens=40)
    assert m.n_truncated == 1
    assert m.total_tokens == 140
    assert m.mean_wall_s == pytest.approx(0.375)
    assert m.tokens_per_s == pytest.approx(140 / 0.75)
