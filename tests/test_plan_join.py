"""CSV-backed semantic join: correctness, sublinearity, refinement."""
import numpy as np
import pytest

from repro.core import SemanticTable, SyntheticOracle
from repro.data import make_dataset
from repro.plan import JoinConfig, pair_ids, sem_join


def _sides(nl=80, nr=60, n_topics=4):
    dl = make_dataset("imdb_review", n=nl, seed=1, n_topics=n_topics)
    dr = make_dataset("imdb_review", n=nr, seed=2, n_topics=n_topics)
    return dl, dr


def _pair_oracle(truth, flip=0.0, seed=3):
    return SyntheticOracle(truth.ravel(), flip_prob=flip, seed=seed)


def test_join_exact_when_blocks_exhausted():
    """Blocks small enough that every pair is sampled: the join is the
    exact cross product filter."""
    dl, dr = _sides(nl=20, nr=20)
    truth = (dl.topics[:, None] % 2) == (dr.topics[None, :] % 2)
    oracle = _pair_oracle(truth)
    r = sem_join(dl.embeddings, dr.embeddings, oracle,
                 JoinConfig(n_clusters_left=4, n_clusters_right=4))
    assert (r.pair_mask == truth).all()
    assert r.pair_mask.shape == (20, 20)
    assert set(map(tuple, r.pairs)) == set(map(tuple, np.argwhere(truth)))


def test_join_sublinear_in_pairs():
    """Topic-separable pair predicate: voting decides most blocks from a
    ~101-pair sample each, far below the |L| x |R| reference cost."""
    dl, dr = _sides(nl=400, nr=300)
    truth = (dl.topics[:, None] % 2) == (dr.topics[None, :] % 2)
    oracle = _pair_oracle(truth)
    r = sem_join(dl.embeddings, dr.embeddings, oracle,
                 JoinConfig(n_clusters_left=4, n_clusters_right=4))
    n_pairs = truth.size
    acc = float(np.mean(r.pair_mask == truth))
    assert acc >= 0.95
    assert r.n_llm_calls < 0.25 * n_pairs
    assert r.n_voted > 0.5 * n_pairs
    # accounting: every pair was sampled, voted, or fell back
    sampled = sum(rr.n_sampled for rr in r.round_log)
    assert sampled + r.n_voted + r.n_fallback == n_pairs


def test_join_refines_impure_blocks_to_exact_fallback():
    """A checkerboard predicate is invisible to clustering: every block
    votes undetermined, refinement splits until the fallback decides each
    pair directly — slow but exact (flip 0)."""
    dl, dr = _sides(nl=40, nr=40)
    ii = np.arange(40)
    truth = ((ii[:, None] + ii[None, :]) % 2).astype(bool)
    oracle = _pair_oracle(truth)
    r = sem_join(dl.embeddings, dr.embeddings, oracle,
                 JoinConfig(n_clusters_left=2, n_clusters_right=2,
                            max_refine=2))
    assert (r.pair_mask == truth).all()
    assert r.refine_rounds >= 1
    assert r.n_fallback > 0


def test_join_sim_vote_path():
    dl, dr = _sides(nl=60, nr=60)
    truth = (dl.topics[:, None] % 2) == (dr.topics[None, :] % 2)
    oracle = _pair_oracle(truth)
    r = sem_join(dl.embeddings, dr.embeddings, oracle,
                 JoinConfig(n_clusters_left=3, n_clusters_right=3,
                            vote="sim"))
    assert r.pair_mask.shape == truth.shape
    assert float(np.mean(r.pair_mask == truth)) >= 0.85


def test_table_api_reuses_precluster_and_is_deterministic():
    dl, dr = _sides(nl=90, nr=70)
    truth = (dl.topics[:, None] % 2) == (dr.topics[None, :] % 2)
    tl = SemanticTable(texts=dl.texts, embeddings=dl.embeddings)
    tr = SemanticTable(texts=dr.texts, embeddings=dr.embeddings)
    cfg = JoinConfig(n_clusters_left=4, n_clusters_right=4)
    r1 = tl.sem_join(tr, _pair_oracle(truth, flip=0.02), cfg=cfg)
    assert (cfg.n_clusters_left, cfg.seed) in tl._assign_cache
    assert (cfg.n_clusters_right, cfg.seed) in tr._assign_cache
    r2 = tl.sem_join(tr, _pair_oracle(truth, flip=0.02), cfg=cfg)
    assert (r1.pair_mask == r2.pair_mask).all()  # same seed, same decisions
    assert r1.n_llm_calls == r2.n_llm_calls


def test_pair_ids_roundtrip():
    i = np.array([0, 1, 2])
    j = np.array([5, 0, 3])
    pid = pair_ids(i, j, n_right=7)
    assert (pid // 7 == i).all() and (pid % 7 == j).all()
    assert pid.dtype == np.int64
