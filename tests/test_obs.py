"""Unified tracing & metrics layer (ISSUE 7 acceptance criteria).

Hard contracts:
1. a traced scheduler run over concurrent queries produces spans nesting
   query -> plan_node -> round -> {plan, oracle -> dispatch_wave, vote,
   partition} with unique stable ids and resolvable parents (including
   the explicit cross-thread dispatch_wave edge);
2. tracing is observation-only: a run with the default NullTracer is
   bit-identical (masks AND per-query oracle call counts) to the same
   run under a recording Tracer;
3. the Perfetto export is valid Chrome trace-event JSON whose slices
   preserve the span hierarchy;
4. histograms are bounded: 10k observations grow no state beyond the
   fixed bucket counts;
5. legacy stats objects surface through ``MetricsRegistry.sync_from``
   under the unified naming scheme;
6. result ``round_log``s are per-run (the mutable-default regression).
"""
import json

import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.obs import (DEFAULT_BOUNDS, Histogram, MetricsRegistry, Tracer,
                       get_tracer, registry_to_prometheus, spans_to_perfetto,
                       use_tracer, write_run_profile)

N = 600
POL = ExecutionPolicy(n_clusters=4, xi=0.005)


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("imdb_review", n=N, seed=0)


def _oracle(ds, q="RV-Q1", flip=0.02, seed=7):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=seed,
                           token_lens=ds.token_lens)


def _run_concurrent(ds):
    """3 concurrent queries (2 leaves + 1 cascade) through the scheduler."""
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    qs = [t.filter(_oracle(ds, "RV-Q1"), name="A"),
          t.filter(_oracle(ds, "RV-Q3"), name="B"),
          t.filter(_oracle(ds, "RV-Q1", seed=11), name="C")
          & t.filter(_oracle(ds, "RV-Q3", seed=12), name="D")]
    with sess.scheduler.holding():
        tickets = [sess.submit(q) for q in qs]
    return sess.gather(*tickets)


@pytest.fixture(scope="module")
def traced(ds):
    tr = Tracer(metrics=MetricsRegistry())
    with use_tracer(tr):
        results = _run_concurrent(ds)
    return tr, results


# ------------------------------------------------------- span structure
def test_span_ids_unique_and_parents_resolve(traced):
    tr, _ = traced
    spans = tr.spans()
    ids = [s.span_id for s in spans]
    assert len(ids) == len(set(ids))
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        assert s.parent_id is None or s.parent_id in by_id
        assert s.t1 is not None and s.t1 >= s.t0


def test_spans_nest_query_to_dispatch_wave(traced):
    tr, _ = traced
    spans = tr.spans()
    by_id = {s.span_id: s for s in spans}

    def chain(s):
        kinds = []
        while s is not None:
            kinds.append(s.kind)
            s = by_id.get(s.parent_id)
        return tuple(reversed(kinds))

    kinds = {s.kind for s in spans}
    assert {"query", "plan_node", "round", "plan", "oracle", "vote",
            "dispatch_wave"} <= kinds
    # 3 submitted queries -> 3 query roots, each a root span
    roots = [s for s in spans if s.kind == "query"]
    assert len(roots) == 3 and all(s.parent_id is None for s in roots)
    # every dispatch_wave hangs off an oracle span inside a round of a
    # plan_node of a query — the full ISSUE-7 chain, crossing from the
    # task thread to the dispatch lane thread via the explicit edge
    waves = [s for s in spans if s.kind == "dispatch_wave"]
    assert waves
    for w in waves:
        assert chain(w) == ("query", "plan_node", "round", "oracle",
                            "dispatch_wave")
    # rounds carry executor + counters once closed
    rounds = [s for s in spans if s.kind == "round"]
    assert all("n_sampled" in r.attrs for r in rounds)


def test_metrics_registry_unified_names(traced, ds):
    tr, results = traced
    snap = tr.metrics.snapshot()
    assert snap["oracle.calls"] == sum(r.n_llm_calls for r in results)
    assert snap["query.collects"] == 3
    assert snap["driver.rounds"] >= 1
    assert snap["round.wall_s"]["count"] == snap["driver.rounds"]
    assert snap["service.ticks"] >= 1
    prom = registry_to_prometheus(tr.metrics)
    assert "oracle_calls" in prom and "service_wave_wall_s_bucket" in prom


def test_profile_reports_est_vs_observed(traced):
    _, results = traced
    txt = results[2].profile()
    assert "QueryProfile" in txt
    for name in ("C", "D"):
        assert any(ln.strip().startswith(name) for ln in txt.splitlines())
    assert "est" in txt and "sel=" in txt


# ------------------------------------------------ observation-only check
def test_disabled_tracer_bit_identical(ds, traced):
    _, with_trace = traced
    assert not get_tracer().enabled  # default NullTracer outside use_tracer
    plain = _run_concurrent(ds)
    for a, b in zip(plain, with_trace):
        np.testing.assert_array_equal(a.mask, b.mask)
        assert a.n_llm_calls == b.n_llm_calls
        assert a.n_replayed == b.n_replayed


# ------------------------------------------------------------- exporters
def test_perfetto_export_valid_json(traced, tmp_path):
    tr, _ = traced
    doc = json.loads(json.dumps(spans_to_perfetto(tr.spans(), tr.epoch_mono)))
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(slices) == len(tr.spans())
    for e in slices:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert {"pid", "tid", "name", "cat"} <= e.keys()
    # thread metadata events name every referenced track
    named = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert {e["tid"] for e in slices} <= named
    files = write_run_profile(tmp_path, tr, tr.metrics)
    for f in ("spans.jsonl", "trace.json", "ticks.jsonl", "metrics.prom",
              "metrics.json"):
        assert (tmp_path / f).stat().st_size > 0
    assert int(files["ticks"]) >= 1


# ------------------------------------------------------ bounded histogram
def test_histogram_memory_bounded_under_10k():
    h = Histogram("test.wall_s", bounds=DEFAULT_BOUNDS)
    n_buckets = len(h.counts)
    rng = np.random.default_rng(0)
    for x in rng.exponential(0.1, size=10_000):
        h.observe(float(x))
    assert h.count == 10_000
    assert len(h.counts) == n_buckets          # no per-sample state
    assert sum(h.counts) == 10_000
    assert h.min <= h.mean <= h.max


def test_sync_from_legacy_stats():
    from repro.core.oracle import OracleStats
    from repro.serving.batcher import DispatchMergeStats
    st = OracleStats()
    st.n_calls, st.input_tokens, st.output_tokens = 42, 1000, 42
    dm = DispatchMergeStats()
    dm.record([8, 8], wall_s=0.5, tokens=640)
    reg = MetricsRegistry()
    reg.sync_from(st, dm)
    snap = reg.snapshot()
    assert snap["oracle.calls"] == 42
    assert snap["oracle.input_tokens"] == 1000
    assert snap["service.merged_ids"] == 16
    assert snap["service.merge_factor"] == 2.0
    # sync is idempotent — counters SET to the view, not re-added
    reg.sync_from(st, dm)
    assert reg.snapshot()["oracle.calls"] == 42


# ------------------------------------- mutable-default round_log regression
def test_round_logs_not_shared_between_runs(ds):
    from repro.core.csv_filter import FilterResult
    from repro.plan.join import JoinResult
    kw = dict(n_llm_calls=0, input_tokens=0, output_tokens=0, n_voted=0,
              n_fallback=0, total_time_s=0.0)
    f1 = FilterResult(mask=np.zeros(1, bool), recluster_rounds=0,
                      recluster_time_s=0.0, cluster_log=[], xi_used=0.0, **kw)
    f2 = FilterResult(mask=np.zeros(1, bool), recluster_rounds=0,
                      recluster_time_s=0.0, cluster_log=[], xi_used=0.0, **kw)
    j1 = JoinResult(pair_mask=np.zeros((1, 1), bool), refine_rounds=0, **kw)
    j2 = JoinResult(pair_mask=np.zeros((1, 1), bool), refine_rounds=0, **kw)
    for a, b in ((f1, f2), (j1, j2)):
        a.round_log.append("sentinel")
        assert b.round_log == []
        assert a.round_log is not b.round_log
    # end-to-end: two back-to-back driver runs keep disjoint logs
    from repro.core import CSVConfig, SemanticTable
    t = SemanticTable(texts=[""] * 200, embeddings=ds.embeddings[:200])
    cfg = CSVConfig(n_clusters=4)
    r1 = t.sem_filter(_oracle(ds), cfg=cfg)
    r2 = t.sem_filter(_oracle(ds), cfg=cfg)
    assert r1.round_log is not r2.round_log
    assert r1.oracle_batch_sizes is not r2.oracle_batch_sizes
