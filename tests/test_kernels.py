"""Per-kernel interpret-mode validation: shape/dtype sweeps vs pure-jnp refs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kmeans.kernel import assign_clusters_pallas
from repro.kernels.kmeans.ref import assign_clusters_ref
from repro.kernels.simvote.kernel import (simvote_scores_pallas,
                                          simvote_scores_segmented_pallas)
from repro.kernels.simvote.ref import (simvote_scores_ref,
                                       simvote_scores_segmented_ref)
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@pytest.mark.parametrize("n,d,k", [(100, 16, 3), (257, 64, 8), (512, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign(n, d, k, dtype):
    x = jax.random.normal(jax.random.key(n), (n, d), dtype)
    c = jax.random.normal(jax.random.key(d), (k, d), dtype)
    a1, d1 = assign_clusters_pallas(x, c, block_n=128, interpret=True)
    a2, d2 = assign_clusters_ref(x, c)
    assert (np.asarray(a1) == np.asarray(a2)).mean() > 0.999  # bf16 ties
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("n,m,d", [(64, 16, 8), (300, 77, 32), (500, 128, 64)])
def test_simvote(n, m, d):
    x = jax.random.normal(jax.random.key(n), (n, d))
    s = jax.random.normal(jax.random.key(m), (m, d))
    y = (jax.random.uniform(jax.random.key(d), (m,)) > 0.5).astype(jnp.float32)
    s1 = simvote_scores_pallas(x, s, y, 1.1, block_n=64, block_m=32,
                               interpret=True)
    s2 = simvote_scores_ref(x, s, y, 1.1)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-6)
    assert (np.asarray(s1) >= 0).all() and (np.asarray(s1) <= 1 + 1e-6).all()


@pytest.mark.parametrize("counts,ms", [([70, 3, 129, 40], [5, 17, 33, 2]),
                                       ([1, 256], [40, 1]),
                                       ([300], [64])])
def test_simvote_segmented(counts, ms):
    """One launch over ragged clusters == per-cluster reference scoring."""
    rng = np.random.default_rng(sum(counts))
    d, c = 16, len(counts)
    max_m = max(ms)
    s_pad = np.zeros((c, max_m, d), np.float32)
    y_pad = -np.ones((c, max_m), np.float32)
    taus = rng.uniform(0.5, 2.0, c)
    xs, per = [], []
    for i in range(c):
        x = rng.normal(size=(counts[i], d)).astype(np.float32)
        s = rng.normal(size=(ms[i], d)).astype(np.float32)
        y = (rng.random(ms[i]) < 0.5).astype(np.float32)
        xs.append(x)
        s_pad[i, :ms[i]] = s
        y_pad[i, :ms[i]] = y
        per.append(np.asarray(simvote_scores_ref(
            jnp.asarray(x), jnp.asarray(s), jnp.asarray(y), float(taus[i]))))
    x_all = jnp.asarray(np.concatenate(xs))
    ref = np.asarray(simvote_scores_segmented_ref(
        x_all, np.asarray(counts), jnp.asarray(s_pad), jnp.asarray(y_pad),
        taus))
    pal = np.asarray(simvote_scores_segmented_pallas(
        x_all, np.asarray(counts), jnp.asarray(s_pad), jnp.asarray(y_pad),
        taus, block_n=64, block_m=16, interpret=True))
    np.testing.assert_allclose(ref, np.concatenate(per), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(pal, np.concatenate(per), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,H,KV,S,hd", [(1, 4, 4, 128, 64), (2, 8, 2, 256, 64),
                                         (1, 4, 1, 128, 128)])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, KV, S, hd, window, dtype):
    q = jax.random.normal(jax.random.key(0), (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (B, KV, S, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (B, KV, S, hd), dtype)
    o1 = flash_attention_pallas(q, k, v, causal=True, window=window,
                                block_q=64, block_k=64, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,KV,L,hd", [(2, 4, 2, 128, 64), (3, 8, 2, 300, 64),
                                         (1, 4, 4, 77, 128)])
def test_decode_attention(B, H, KV, L, hd):
    q = jax.random.normal(jax.random.key(3), (B, H, hd))
    k = jax.random.normal(jax.random.key(4), (B, KV, L, hd))
    v = jax.random.normal(jax.random.key(5), (B, KV, L, hd))
    lens = jnp.asarray(np.random.default_rng(B).integers(1, L + 1, B))
    o1 = decode_attention_pallas(q, k, v, lens, block_l=64, interpret=True)
    o2 = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)
