"""Session-level multi-query optimization (ISSUE 4 acceptance criteria).

Hard contracts:
1. warm replay — re-running the same ``.filter().collect()`` (same oracle
   object, same semantic config) on an unchanged table spends ZERO oracle
   calls and returns a bit-identical mask;
2. with an EMPTY memo the reuse path is bit-identical to a cold session
   (reuse never changes behavior until there is something to reuse);
3. a later query over a table the session has already filtered spends
   zero re-embedding and strictly fewer total oracle calls than a cold
   session (memoized decisions replay; memoized pilot/observed
   selectivities replace fresh probes);
4. ``append()``/``update()`` invalidate exactly the touched clusters: the
   next collect re-votes only those, replaying every clean-cluster row;
5. two Sessions never share embedding-cache state unless explicitly wired
   (``Session(embedding_cache=shared)``).
"""
import numpy as np
import pytest

from repro.api import EmbeddingCache, ExecutionPolicy, OracleBudgetError, Session
from repro.core import SyntheticOracle

N = 1200
COLD = ExecutionPolicy(n_clusters=4, reuse_memo=False, reuse_stats=False)


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("imdb_review", n=N, seed=0)


def _oracle(ds, q="RV-Q1", flip=0.02):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=7,
                           token_lens=ds.token_lens)


# ---------------------------------------------------------------- blobs
def _blobs(n_per=300, k=4, seed=0):
    """k well-separated clusters -> k-means recovers them exactly, so the
    dirty-cluster arithmetic below is deterministic."""
    rng = np.random.default_rng(seed)
    centers = np.eye(k, 3 if k <= 3 else k, dtype=np.float32) * 10.0
    emb = np.concatenate([
        centers[i] + rng.normal(0, 0.5, (n_per, centers.shape[1]))
        .astype(np.float32) for i in range(k)])
    labels = np.concatenate([np.full(n_per, bool(i % 2 == 0))
                             for i in range(k)])
    return centers, emb, labels


# ------------------------------------------------------------ warm replay
def test_warm_replay_zero_calls_bit_identical(ds):
    sess = Session()
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    o = _oracle(ds)
    r1 = t.filter(o, name="A").collect()
    assert r1.n_llm_calls > 0 and r1.n_replayed == 0
    calls_after_cold = o.stats.n_calls
    # a NEW query object, even anonymously named: same oracle => replay
    r2 = t.filter(o).collect()
    assert r2.n_llm_calls == 0 and r2.pilot_calls == 0
    assert r2.n_replayed == N
    assert (r2.mask == r1.mask).all()
    assert o.stats.n_calls == calls_after_cold  # oracle untouched
    assert sess.stats.n_calls == r1.n_llm_calls


def test_empty_memo_bit_identical_to_cold(ds):
    """Criterion: bit-identity to a cold run whenever the memo is empty."""
    warm_sess = Session()
    rw = warm_sess.table(embeddings=ds.embeddings).filter(
        _oracle(ds), name="A").collect()
    cold_sess = Session()
    rc = cold_sess.table(embeddings=ds.embeddings).filter(
        _oracle(ds), name="A").collect(COLD)
    assert (rw.mask == rc.mask).all()
    assert rw.n_llm_calls == rc.n_llm_calls
    assert rw.n_replayed == rc.n_replayed == 0


def test_replay_requires_matching_semantics(ds):
    """A different xi (or vote) is a different sampling process: decisions
    must NOT replay across it."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    o = _oracle(ds)
    t.filter(o, name="A").collect(ExecutionPolicy(xi=0.005))
    r = t.filter(o, name="A").collect(ExecutionPolicy(xi=0.02))
    assert r.n_replayed == 0
    # ...but the bit-identical executor variants DO replay across each other
    r2 = t.filter(o, name="A").collect(
        ExecutionPolicy(xi=0.005, executor="sequential"))
    assert r2.n_replayed == N and r2.n_llm_calls == 0


def test_two_sessions_do_not_share_memo(ds):
    o = _oracle(ds)
    s1 = Session()
    r1 = s1.table(embeddings=ds.embeddings).filter(o).collect()
    s2 = Session()
    r2 = s2.table(embeddings=ds.embeddings).filter(o).collect()
    assert r1.n_replayed == 0 and r2.n_replayed == 0


# ----------------------------------------- cross-query planning reuse
def test_second_query_fewer_calls_than_cold_session(ds):
    """Criterion: after filtering A, a composed (A & B) query with a new
    predicate B replays A's decisions and skips A's pilot — strictly fewer
    total calls than a cold session, same mask.

    flip=0 keeps the oracle deterministic: the cold control consumes its
    flip stream in a different order (pilot before cascade), so with a
    stochastic oracle the masks would agree only in expectation (see
    docs/caching.md)."""
    def oracles():
        return (_oracle(ds, "RV-Q3", flip=0.0),
                _oracle(ds, "RV-Q1", flip=0.0))

    # warm: A alone first, then A & B in the same session
    oA, oB = oracles()
    warm = Session()
    t = warm.table(embeddings=ds.embeddings)
    rA = t.filter(oA, name="A").collect()
    rw = (t.filter(oA, name="A") & t.filter(oB, name="B")).collect()
    # cold control: the same composed query in a fresh session
    cA, cB = oracles()
    cold = Session()
    tc = cold.table(embeddings=ds.embeddings)
    rc = (tc.filter(cA, name="A") & tc.filter(cB, name="B")).collect(COLD)

    assert rw.n_replayed == N                  # A replayed in full
    assert rw.pilot_calls < rc.pilot_calls     # A's probe skipped
    assert rw.n_llm_calls < rc.n_llm_calls     # strictly fewer total calls
    # RV-Q3 is the more selective conjunct, so the cold optimizer also runs
    # A first: both cascades evaluate B on the same survivors => bit-equal
    assert rc.order == ["A", "B"] and rw.order == ["A", "B"]
    assert (rw.mask == rc.mask).all()
    assert rA.n_llm_calls > 0


def test_pilot_memo_reused_across_queries(ds):
    """A leaf piloted by one query is not re-probed by the next (same table
    version, seed, pilot_size) — the second query reports only the fresh
    leaves' pilot calls."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    oA, oB, oC = (_oracle(ds, "RV-Q1"), _oracle(ds, "RV-Q2"),
                  _oracle(ds, "RV-Q3"))
    r1 = (t.filter(oA, name="A") & t.filter(oB, name="B")).collect()
    assert r1.pilot_calls > 0
    r2 = (t.filter(oA, name="A") & t.filter(oC, name="C")).collect()
    # A is replayable + piloted; only C pays a probe
    assert 0 < r2.pilot_calls <= r1.pilot_calls // 2


def test_reuse_knobs_disable_reuse(ds):
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    o = _oracle(ds)
    r1 = t.filter(o, name="A").collect()
    r2 = t.filter(o, name="A").collect(COLD)
    assert r2.n_replayed == 0
    # same oracle object: the ORACLE memo still dedups ids, so the re-run
    # spends no new calls — but it goes through the full driver
    assert (r2.mask == r1.mask).all()


def test_reuse_off_collect_not_polluted_by_warm_explain(ds):
    """Review regression: explain() under a reuse-enabled policy caches
    pilot stats with memo-derived (replayable, cost-0) leaves; a later
    reuse-DISABLED collect of the same query object must not plan with
    them — it must order and spend exactly like a cold session."""
    oA, oB = _oracle(ds, "RV-Q3", flip=0.0), _oracle(ds, "RV-Q1", flip=0.0)
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    t.filter(oA, name="A").collect()         # warm the memo for A
    q = t.filter(oA, name="A") & t.filter(oB, name="B")
    q.explain()                              # reuse-enabled planning
    warm_key = [k for k in q._pilot_cache if k[2] or k[3]]
    assert warm_key and q._pilot_cache[warm_key[0]]["A"].replayable
    r = q.collect(COLD)
    # the cold collect planned from its OWN cache entry, with no
    # memo-derived (replayable / observed) statistics
    cold_stats = q._pilot_cache[
        (COLD.seed, COLD.pilot_size, False, False, 0)]
    assert not any(ps.replayable for ps in cold_stats.values())
    assert all(ps.source == "pilot" for ps in cold_stats.values())
    assert r.n_replayed == 0
    # note: oracle-level memoization (a separate, always-on layer) still
    # dedups ids for the warm oracle, so call COUNTS legitimately differ
    # from a fresh session; the plan and the mask must not
    cold = Session()
    tc = cold.table(embeddings=ds.embeddings)
    rc = (tc.filter(_oracle(ds, "RV-Q3", flip=0.0), name="A")
          & tc.filter(_oracle(ds, "RV-Q1", flip=0.0), name="B")).collect(COLD)
    assert r.order == rc.order
    assert (r.mask == rc.mask).all()


def test_budget_accepts_warm_replay(ds):
    """Memo accounting in max_oracle_calls: a budget a cold run would blow
    passes once the decisions are memoized."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    o = _oracle(ds)
    tight = ExecutionPolicy(max_oracle_calls=5)
    with pytest.raises(OracleBudgetError):
        t.filter(o, name="A").collect(tight)
    assert o.stats.n_calls == 0     # the guard is closed-form
    r1 = t.filter(o, name="A").collect()
    r2 = t.filter(o, name="A").collect(tight)
    assert r2.n_llm_calls == 0 and (r2.mask == r1.mask).all()


# ------------------------------------------------- incremental mutation
def test_append_revotes_only_touched_clusters():
    centers, emb, labels = _blobs()
    rng = np.random.default_rng(99)
    new = centers[0] + rng.normal(0, 0.5, (50, centers.shape[1])).astype(
        np.float32)
    oracle = SyntheticOracle(np.concatenate([labels, np.ones(50, bool)]))
    sess = Session()
    t = sess.table(embeddings=emb, name="blobs")
    pol = ExecutionPolicy(n_clusters=4)
    r1 = t.filter(oracle, name="p").collect(pol)
    assert t.version == 0

    t.append(embeddings=new)
    assert t.version == 1 and len(t) == len(emb) + 50

    assign = sess._assign_cache[("blobs", 4, 0)]
    assert len(assign) == len(t)            # patched, not invalidated
    dirty_clusters = np.unique(assign[len(emb):])
    clean_rows = ~np.isin(assign, dirty_clusters)
    assert 0 < dirty_clusters.size < 4      # blobs well separated

    r2 = t.filter(oracle, name="p").collect(pol)
    # exactly the clean-cluster rows replay; only dirty clusters re-vote
    assert r2.n_replayed == int(clean_rows.sum())
    assert 0 < r2.n_llm_calls < r1.n_llm_calls
    old_clean = clean_rows[:len(emb)]
    assert (r2.mask[:len(emb)][old_clean] == r1.mask[old_clean]).all()
    assert r2.mask[len(emb):].all()         # appended rows decided (True)
    # memo upgraded: a third collect is a full zero-cost replay again
    r3 = t.filter(oracle).collect(pol)
    assert r3.n_llm_calls == 0 and r3.n_replayed == len(t)
    assert (r3.mask == r2.mask).all()


def test_update_invalidates_touched_clusters_and_oracle_memo():
    centers, emb, labels = _blobs()
    oracle = SyntheticOracle(labels.copy())
    sess = Session()
    t = sess.table(embeddings=emb, name="blobs")
    pol = ExecutionPolicy(n_clusters=4)
    r1 = t.filter(oracle, name="p").collect(pol)

    # move 10 rows of blob 1 (label False) into blob 2 (label True): both
    # their content and their truth change
    rng = np.random.default_rng(3)
    upd = np.arange(300, 310)
    oracle.labels[upd] = True
    moved = centers[2] + rng.normal(0, 0.5, (10, centers.shape[1])).astype(
        np.float32)
    t.update(upd, embeddings=moved)
    assert t.version == 1
    assert not any(int(i) in oracle._memo for i in upd)  # stale ids dropped

    # clean set per the handle's dirty bookkeeping: exactly the clusters
    # untouched by the update (the moved rows' old cluster + new cluster
    # are dirty at version 1)
    assign = sess._assign_cache[("blobs", 4, 0)]
    clean_rows = (t._dirty[(4, 0)] <= 0)[assign]
    assert 0 < clean_rows.sum() < len(t)

    r2 = t.filter(oracle, name="p").collect(pol)
    assert r2.n_replayed == int(clean_rows.sum()) < len(t)
    assert (r2.mask[clean_rows] == r1.mask[clean_rows]).all()
    assert r2.mask[upd].all()               # updated rows re-decided True
    assert 0 < r2.n_llm_calls < r1.n_llm_calls


def test_update_invalidates_oracle_memo_even_without_reuse():
    """Review regression: an oracle only ever used under a reuse-disabled
    policy must still get its stale per-id memo entries dropped by
    update() — otherwise a later collect silently serves pre-update
    decisions for changed rows."""
    centers, emb, labels = _blobs()
    oracle = SyntheticOracle(labels.copy())
    sess = Session()
    t = sess.table(embeddings=emb, name="blobs")
    pol = COLD
    r1 = t.filter(oracle, name="p").collect(pol)
    upd = np.arange(0, 5)          # blob 0, label True -> flip to False
    oracle.labels[upd] = False
    t.update(upd, embeddings=np.tile(centers[1], (len(upd), 1)) + 0.1)
    assert not any(int(i) in oracle._memo for i in upd)
    r2 = t.filter(oracle, name="p").collect(pol)
    assert not r2.mask[upd].any()  # re-decided from the NEW labels
    assert r1.mask[upd].all()


def test_mutation_argument_validation():
    _, emb, labels = _blobs(n_per=50)
    sess = Session()
    t = sess.table(embeddings=emb, name="b")
    with pytest.raises(ValueError, match="ids but"):
        t.update([1, 2, 3], embeddings=emb[:1])
    with pytest.raises(TypeError, match="append needs"):
        t.append()
    lazy = Session(embedder=lambda ts: np.zeros((len(ts), 4), np.float32))
    lt = lazy.table(texts=["a", "b"])
    with pytest.raises(ValueError, match="still lazy"):
        lt.append(texts=["c"], embeddings=np.zeros((1, 4), np.float32))
    # a failed append must not leave the table partially mutated
    assert len(lt) == 2 and lt.version == 0
    tx = Session(embedder=lambda ts: np.zeros((len(ts), 4), np.float32))
    th = tx.table(texts=["a", "b"])
    _ = th.embeddings  # materialize
    with pytest.raises(ValueError, match="texts but"):
        th.append(texts=["c", "d"], embeddings=np.zeros((1, 4), np.float32))
    assert len(th) == 2 and len(th.embeddings) == 2


def test_update_validation_leaves_table_unmutated():
    """Review regression: a failed update must not leave new texts against
    old embeddings, and updating embeddings on a still-lazy table must
    raise instead of silently no-oping (while paying invalidation)."""
    sess = Session(embedder=lambda ts: np.zeros((len(ts), 4), np.float32))
    t = sess.table(texts=["a", "b", "c"])
    _ = t.embeddings
    with pytest.raises(ValueError, match="ids but"):
        t.update([0, 1], texts=["x", "y"],
                 embeddings=np.zeros((3, 4), np.float32))
    assert t._table.texts == ["a", "b", "c"] and t.version == 0
    lazy = Session().table(texts=["a", "b"],
                           embedder=lambda ts: np.zeros((len(ts), 4),
                                                        np.float32))
    with pytest.raises(ValueError, match="still lazy"):
        lazy.update([0], embeddings=np.zeros((1, 4), np.float32))
    assert lazy.version == 0


def test_mutation_invalidates_stale_pilot_cache(ds):
    """Review regression: a query object planned before append() must not
    reuse its pre-mutation pilot statistics afterwards."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings, name="r")
    oA, oB = _oracle(ds, "RV-Q3"), _oracle(ds, "RV-Q1")
    q = t.filter(oA, name="A") & t.filter(oB, name="B")
    q.explain()
    n_keys = len(q._pilot_cache)
    t.append(embeddings=ds.embeddings[:3])
    q.explain()
    assert len(q._pilot_cache) == n_keys + 1  # fresh entry, new version


def test_update_does_not_invalidate_other_tables_oracles():
    """Review regression: tuple ids are plain ints — updating table A must
    not drop a B-only oracle's memo entries for the same numeric ids."""
    _, emb, labels = _blobs(n_per=50)
    sess = Session()
    a = sess.table(embeddings=emb, name="a")
    b = sess.table(embeddings=emb.copy(), name="b")
    ob = SyntheticOracle(labels.copy())
    b.filter(ob, name="pb").collect(ExecutionPolicy(n_clusters=4))
    memo_before = len(ob._memo)
    assert memo_before > 0
    a.update([0, 1], embeddings=emb[10:12] + 0.1)
    assert len(ob._memo) == memo_before  # untouched: ob never ran on "a"


def test_append_routes_through_session_cache_for_wrapped_tables():
    """Review regression: a pre-built SemanticTable wrapped via table=
    carries a RAW embedder (Session.table only wraps embedders it
    constructs with) — the mutation path must still route embedding
    through the session's cache."""
    from repro.core import SemanticTable
    counter = {"rows": 0}
    st = SemanticTable(texts=[f"r{i}" for i in range(5)],
                       embedder=_counting_embedder(counter))
    sess = Session()
    t = sess.table(table=st)
    _ = t.embeddings                 # materialize through the raw embedder
    assert counter["rows"] == 5
    t.append(texts=["dup", "dup"])
    assert counter["rows"] == 6      # duplicate content embedded once
    assert sess.embedding_cache.encoded_rows == 1
    assert len(t) == 7


def test_append_rejects_texts_on_embeddings_only_table():
    """Appending texts to a table that can't store them must raise, not
    silently orphan the payloads."""
    sess = Session(embedder=lambda ts: np.zeros((len(ts), 4), np.float32))
    t = sess.table(embeddings=np.zeros((5, 4), np.float32))
    with pytest.raises(ValueError, match="no texts"):
        t.append(texts=["a"])
    assert len(t) == 5 and t.version == 0


def test_cascade_runs_do_not_record_marginal_selectivity(ds):
    """Review regression: B's pass rate measured on A's survivors is
    conditional — it must not be stored as B's observed (marginal)
    selectivity for later orderings."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    oA, oB = _oracle(ds, "RV-Q3"), _oracle(ds, "RV-Q1")
    (t.filter(oA, name="A") & t.filter(oB, name="B")).collect()
    sels = sess.memo._selectivity
    assert (t.name, id(oA)) in sels          # A ran on the full table
    assert (t.name, id(oB)) not in sels      # B ran on a subset only


def test_mutation_clears_join_pair_oracle_memo():
    """Review regression: pair oracles memoize by pair id
    ``i * len(right) + j`` — mutating either side must clear their memo
    (per-id invalidation cannot be mapped across the reindexing)."""
    _, emb, labels = _blobs(n_per=40)
    sess = Session()
    a = sess.table(embeddings=emb[:60], name="a")
    b = sess.table(embeddings=emb[:50], name="b")
    pair_truth = np.outer(labels[:60], labels[:50]).ravel()
    jo = SyntheticOracle(pair_truth)
    a.join(b, jo).collect()
    assert len(jo._memo) > 0
    a.update([0], embeddings=emb[100:101])
    assert len(jo._memo) == 0  # cleared outright, not per-id
    # growing the RIGHT side reindexes every pair id: also cleared
    jo2 = SyntheticOracle(pair_truth)
    a.join(b, jo2).collect()
    assert len(jo2._memo) > 0
    b.append(embeddings=emb[120:121])
    assert len(jo2._memo) == 0


def test_append_rejects_wrong_dimension_before_mutating():
    _, emb, labels = _blobs(n_per=40)
    sess = Session()
    t = sess.table(embeddings=emb, name="t")
    with pytest.raises(ValueError, match="shape"):
        t.append(embeddings=np.zeros((2, emb.shape[1] + 3), np.float32))
    assert len(t) == len(emb) and t.version == 0
    t.append(embeddings=np.zeros((0, emb.shape[1]), np.float32))
    assert t.version == 0  # empty append is a no-op, not an invalidation


# ------------------------------------------------------ embedding cache
def _counting_embedder(counter):
    def embed(texts):
        counter["rows"] += len(texts)
        rng = np.random.default_rng(0)
        out = np.stack([
            rng.normal(size=8).astype(np.float32) * 0 +
            np.frombuffer(t.encode("utf-8").ljust(8)[:8], np.uint8)
            .astype(np.float32) for t in texts])
        return out
    return embed


def test_embedding_cache_embeds_only_new_rows():
    counter = {"rows": 0}
    texts = [f"tuple number {i}" for i in range(60)]
    sess = Session(embedder=_counting_embedder(counter))
    t1 = sess.table(texts=texts)
    _ = t1.embeddings
    assert counter["rows"] == 60
    # overlapping table: only the 20 new rows hit the embedder
    t2 = sess.table(texts=texts[:40] + [f"fresh {i}" for i in range(20)])
    _ = t2.embeddings
    assert counter["rows"] == 80
    # append through the handle embeds only the appended rows
    t1.append(texts=[f"appended {i}" for i in range(5)])
    assert counter["rows"] == 85 and len(t1) == 65
    assert sess.embedding_cache.hits >= 40


def test_embedding_cache_not_shared_across_sessions_unless_wired():
    counter = {"rows": 0}
    texts = [f"tuple number {i}" for i in range(30)]
    s1 = Session(embedder=_counting_embedder(counter))
    _ = s1.table(texts=texts).embeddings
    s2 = Session(embedder=_counting_embedder(counter))
    _ = s2.table(texts=texts).embeddings
    assert counter["rows"] == 60            # isolated by default

    shared = EmbeddingCache()
    s3 = Session(embedder=_counting_embedder(counter),
                 embedding_cache=shared)
    _ = s3.table(texts=texts).embeddings
    s4 = Session(embedder=_counting_embedder(counter),
                 embedding_cache=shared)
    _ = s4.table(texts=texts).embeddings
    assert counter["rows"] == 90            # explicit wiring shares
    assert shared.hits == 30
