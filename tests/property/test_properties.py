"""Property-based tests (seeded random sweeps; `hypothesis` is not available
in the offline image, so each property is exercised across many generated
cases with the same shrink-free methodology)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import theory
from repro.core.voting import sim_vote, uni_vote
from repro.core.clustering import kmeans
from repro.kernels.kmeans.ref import assign_clusters_ref
from repro.kernels.simvote.ref import simvote_scores_ref
from repro.train.grad_compression import compress_with_feedback

SEEDS = list(range(12))


@pytest.mark.parametrize("seed", SEEDS)
def test_property_simvote_scores_are_convex_weights(seed):
    """Every SimVote score is a convex combination of sample labels -> [0,1],
    and equals the label when all samples agree."""
    rng = np.random.default_rng(seed)
    n, m, d = rng.integers(5, 200), rng.integers(2, 50), rng.integers(2, 33)
    x = rng.normal(size=(n, d)).astype(np.float32)
    s = rng.normal(size=(m, d)).astype(np.float32)
    y = (rng.random(m) < rng.random()).astype(np.float32)
    scores = np.asarray(simvote_scores_ref(jnp.asarray(x), jnp.asarray(s),
                                           jnp.asarray(y), 1.0))
    assert (scores >= -1e-6).all() and (scores <= 1 + 1e-6).all()
    ones = np.ones(m, np.float32)
    s_all = np.asarray(simvote_scores_ref(jnp.asarray(x), jnp.asarray(s),
                                          jnp.asarray(ones), 1.0))
    np.testing.assert_allclose(s_all, 1.0, atol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_property_unvote_threshold_partition(seed):
    """UniVote decisions partition tuples exactly by (lb, ub)."""
    rng = np.random.default_rng(seed)
    labels = (rng.random(rng.integers(3, 300)) < rng.random())
    lb = rng.uniform(0.01, 0.45)
    ub = rng.uniform(lb + 0.05, 0.99)
    nrest = int(rng.integers(1, 50))
    vr = uni_vote(labels.astype(float), nrest, lb, ub)
    total = len(vr.decided_true) + len(vr.decided_false) + len(vr.undetermined)
    assert total == nrest
    score = labels.mean()
    if score >= ub:
        assert len(vr.decided_true) == nrest
    elif score <= lb:
        assert len(vr.decided_false) == nrest
    else:
        assert len(vr.undetermined) == nrest


@pytest.mark.parametrize("seed", SEEDS)
def test_property_kmeans_assignment_is_nearest(seed):
    """Every point's assigned centroid is its true nearest centroid."""
    rng = np.random.default_rng(seed)
    n, d, k = int(rng.integers(20, 300)), int(rng.integers(2, 16)), int(rng.integers(2, 8))
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    cents, assign, _ = kmeans(jax.random.key(seed), x, k, max_iters=20)
    a2, _ = assign_clusters_ref(x, cents)
    assert (np.asarray(assign) == np.asarray(a2)).all()


@pytest.mark.parametrize("seed", SEEDS)
def test_property_xi_bounds(seed):
    """xi formulas always land in [0, 1] and shrink with epsilon."""
    rng = np.random.default_rng(seed)
    s2 = float(rng.uniform(1e-4, 0.25))
    l = float(rng.uniform(0.99, 0.99999))
    eps = sorted(rng.uniform(0.02, 0.45, size=4))
    xs = [theory.xi_for_epsilon_univote(e, s2, l) for e in eps]
    assert all(0 <= v <= 1 for v in xs)
    assert all(a >= b - 1e-12 for a, b in zip(xs, xs[1:]))


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_property_error_feedback_identity(seed):
    """Invariant: sum(sent) + residual == sum(true gradients) exactly."""
    rng = np.random.default_rng(seed)
    steps = int(rng.integers(2, 20))
    gs = [jnp.asarray(rng.normal(size=(64,)), jnp.float32) for _ in range(steps)]
    res = {"w": jnp.zeros((64,), jnp.float32)}
    sent = jnp.zeros((64,))
    for g in gs:
        c, res = compress_with_feedback({"w": g}, res, method="int8")
        sent = sent + c["w"]
    np.testing.assert_allclose(np.asarray(sent + res["w"]),
                               np.asarray(sum(gs)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_property_vote_bound_holds_when_committed(seed):
    """For random populations/samples: committed votes respect Thm 3.3's
    error bound (up to its stated failure probability)."""
    rng = np.random.default_rng(seed)
    lb, ub, eps = 0.15, 0.85, 0.15
    sigma2 = 0.25
    xi = theory.xi_for_epsilon_univote(eps, sigma2)
    bound = theory.vote_error_bound(lb, ub, eps)
    bad = tot = 0
    for _ in range(100):
        n = int(rng.integers(500, 4000))
        mu = float(rng.random())
        x = rng.random(n) < mu
        k = max(5, int(np.ceil(xi * n)))
        idx = rng.choice(n, size=min(k, n), replace=False)
        score = x[idx].mean()
        err = None
        if score >= ub:
            err = 1 - x.mean()
        elif score <= lb:
            err = x.mean()
        if err is not None:
            tot += 1
            bad += err > bound
    if tot >= 20:
        assert bad / tot <= 0.1
