"""Property tests: batched/segmented voting == per-cluster voting.

Seeded-random sweeps (`hypothesis` is unavailable offline) over ragged
cluster layouts — empty samples, empty rests, single-row clusters — assert
that the round executor's one-shot entry points (`uni_vote_batch`,
`sim_vote_batch`, `simvote_scores_segmented`) reproduce the per-cluster
decisions exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.voting import (sim_vote, sim_vote_batch, uni_vote,
                               uni_vote_batch)
from repro.kernels.simvote.kernel import simvote_scores_segmented_pallas
from repro.kernels.simvote.ref import (simvote_scores_ref,
                                       simvote_scores_segmented_ref)

SEEDS = list(range(10))


def _ragged_clusters(rng, d=None):
    c = int(rng.integers(1, 8))
    d = d or int(rng.integers(2, 24))
    xs, ss, ys = [], [], []
    for _ in range(c):
        n_c = int(rng.integers(0, 90))  # 0 => exhausted cluster
        m_c = int(rng.integers(1, 40))
        xs.append(rng.normal(size=(n_c, d)).astype(np.float32))
        ss.append(rng.normal(size=(m_c, d)).astype(np.float32))
        ys.append((rng.random(m_c) < rng.random()).astype(np.float32))
    return xs, ss, ys


@pytest.mark.parametrize("seed", SEEDS)
def test_property_uni_vote_batch_matches_per_cluster(seed):
    rng = np.random.default_rng(seed)
    c = int(rng.integers(1, 10))
    labels = [(rng.random(int(rng.integers(0, 60))) < rng.random()
               ).astype(float) for _ in range(c)]
    nuns = [int(rng.integers(0, 50)) for _ in range(c)]
    lb = float(rng.uniform(0.05, 0.45))
    ub = float(rng.uniform(lb + 0.05, 0.99))
    batch = uni_vote_batch(labels, nuns, lb, ub)
    assert len(batch) == c
    for lab, n_c, b in zip(labels, nuns, batch):
        v = uni_vote(lab, n_c, lb, ub)
        assert (v.decided_true == b.decided_true).all()
        assert (v.decided_false == b.decided_false).all()
        assert (v.undetermined == b.undetermined).all()


@pytest.mark.parametrize("seed", SEEDS)
def test_property_sim_vote_batch_matches_per_cluster(seed):
    rng = np.random.default_rng(seed)
    xs, ss, ys = _ragged_clusters(rng)
    lb = float(rng.uniform(0.1, 0.45))
    ub = float(rng.uniform(lb + 0.05, 0.95))
    batch = sim_vote_batch(xs, ss, ys, lb, ub)
    for x, s, y, b in zip(xs, ss, ys, batch):
        v = sim_vote(x, s, y, lb, ub)
        assert (v.decided_true == b.decided_true).all()
        assert (v.decided_false == b.decided_false).all()
        assert (v.undetermined == b.undetermined).all()
        if len(x):
            np.testing.assert_allclose(v.scores, b.scores, rtol=1e-5,
                                       atol=1e-6)


@pytest.mark.parametrize("seed", SEEDS)
def test_property_segmented_scores_match_per_cluster_ref(seed):
    """simvote_scores_segmented == C independent simvote_scores_ref calls."""
    rng = np.random.default_rng(seed + 100)
    xs, ss, ys = _ragged_clusters(rng)
    live = [i for i, x in enumerate(xs) if len(x)]
    if not live:
        return
    d = xs[0].shape[1]
    max_m = max(len(ss[i]) for i in live)
    s_pad = np.zeros((len(live), max_m, d), np.float32)
    y_pad = -np.ones((len(live), max_m), np.float32)
    taus = rng.uniform(0.5, 2.0, len(live))
    per = []
    for r, i in enumerate(live):
        s_pad[r, :len(ss[i])] = ss[i]
        y_pad[r, :len(ss[i])] = ys[i]
        per.append(np.asarray(simvote_scores_ref(
            jnp.asarray(xs[i]), jnp.asarray(ss[i]), jnp.asarray(ys[i]),
            float(taus[r]))))
    counts = np.array([len(xs[i]) for i in live])
    x_all = jnp.asarray(np.concatenate([xs[i] for i in live]))
    seg = np.asarray(simvote_scores_segmented_ref(
        x_all, counts, jnp.asarray(s_pad), jnp.asarray(y_pad), taus))
    np.testing.assert_allclose(seg, np.concatenate(per), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("seed", SEEDS[:4])
def test_property_segmented_pallas_matches_segmented_ref(seed):
    rng = np.random.default_rng(seed + 200)
    xs, ss, ys = _ragged_clusters(rng, d=16)
    live = [i for i, x in enumerate(xs) if len(x)]
    if not live:
        return
    d = 16
    max_m = max(len(ss[i]) for i in live)
    s_pad = np.zeros((len(live), max_m, d), np.float32)
    y_pad = -np.ones((len(live), max_m), np.float32)
    taus = rng.uniform(0.5, 2.0, len(live))
    for r, i in enumerate(live):
        s_pad[r, :len(ss[i])] = ss[i]
        y_pad[r, :len(ss[i])] = ys[i]
    counts = np.array([len(xs[i]) for i in live])
    x_all = jnp.asarray(np.concatenate([xs[i] for i in live]))
    ref = np.asarray(simvote_scores_segmented_ref(
        x_all, counts, jnp.asarray(s_pad), jnp.asarray(y_pad), taus))
    pal = np.asarray(simvote_scores_segmented_pallas(
        x_all, counts, jnp.asarray(s_pad), jnp.asarray(y_pad), taus,
        block_n=32, block_m=16, interpret=True))
    np.testing.assert_allclose(pal, ref, rtol=1e-5, atol=1e-6)


def test_uni_vote_batch_matches_at_exact_threshold_scores():
    """float32 1/10 != float64 1/10: batch scoring must use the same dtype
    arithmetic as uni_vote or the executors diverge at threshold-equal
    scores (e.g. one positive in ten samples with lb=0.1)."""
    labels = np.array([1] + [0] * 9, np.float32)
    single = uni_vote(labels, 5, lb=0.1, ub=0.9)
    batch, = uni_vote_batch([labels], [5], lb=0.1, ub=0.9)
    assert (single.decided_false == batch.decided_false).all()
    assert (single.undetermined == batch.undetermined).all()
    assert len(single.decided_true) == len(batch.decided_true) == 0


def test_uni_vote_empty_sample_is_undetermined():
    """An empty sample must not silently vote everything False (lb >= 0)."""
    vr = uni_vote(np.zeros(0), 7, lb=0.15, ub=0.85)
    assert len(vr.undetermined) == 7
    assert len(vr.decided_true) == 0 and len(vr.decided_false) == 0


def test_sim_vote_empty_sample_is_undetermined():
    """Same contract for SimVote: no samples, no (False) votes."""
    x = np.random.default_rng(0).normal(size=(5, 8)).astype(np.float32)
    vr = sim_vote(x, np.zeros((0, 8), np.float32), np.zeros(0), 0.15, 0.85)
    assert len(vr.undetermined) == 5 and len(vr.decided_false) == 0
    b_empty, b_live = sim_vote_batch(
        [x, x], [np.zeros((0, 8), np.float32), x[:2]],
        [np.zeros(0), np.array([1.0, 1.0], np.float32)], 0.15, 0.85)
    assert len(b_empty.undetermined) == 5 and len(b_empty.decided_false) == 0
    assert len(b_live.decided_true) == 5  # live cluster still votes
