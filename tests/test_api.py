"""Lazy Session/Query API (repro.api): routing, laziness, and bit-identity.

Hard contracts (ISSUE 3 acceptance criteria):
1. every legacy call pattern — sem_filter csv / csv-sim / reference /
   lotus / bargain, sem_filter_expr, sem_join — reproduces bit-identically
   (mask AND oracle call count, fixed seed) through the new API, including
   ``executor="round"`` vs ``"sequential"`` and ``pipeline_depth > 1``;
2. building/composing a lazy query issues zero oracle calls before
   ``.collect()``;
3. ``.explain()`` reports pilot cost estimates without perturbing the
   subsequent ``.collect()`` (flip-RNG stream and call counts unchanged);
4. two tables in one session never share precluster assignments.

Bit-identity here is asserted against the *direct* machinery
(``semantic_filter`` / ``PlanExecutor`` / baseline functions / ``sem_join``)
— not against the deprecated shims, which themselves route through the new
layer.
"""
import inspect
import warnings

import numpy as np
import pytest

from repro.api import (ExecutionPolicy, FilterQuery, OracleBudgetError,
                       QueryResult, Session)
from repro.core import CSVConfig, ProxyModel, SemanticTable, SyntheticOracle
from repro.core.baselines import (bargain_filter, lotus_filter,
                                  reference_filter)
from repro.core.csv_filter import semantic_filter
from repro.plan import And, JoinConfig, PlanExecutor, Pred, sem_join

N = 1500


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("imdb_review", n=N, seed=0)


def _oracle(ds, q="RV-Q1", flip=0.02):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=7,
                           token_lens=ds.token_lens)


def _proxy(ds):
    return ProxyModel(ds.labels["RV-Q1"], token_lens=ds.token_lens,
                      quality=0.8, center=0.82, concentration=0.15)


CFG = CSVConfig(n_clusters=4, xi=0.005)


# --------------------------------------------------------------- laziness
def test_building_queries_spends_zero_oracle_calls(ds):
    sess = Session()
    t = sess.table(texts=ds.texts, embeddings=ds.embeddings, name="reviews")
    o1, o2 = _oracle(ds), _oracle(ds, "RV-Q3")
    q = t.filter(o1, name="q1") & ~t.filter(o2, name="q3")
    assert isinstance(q, FilterQuery)
    jo = SyntheticOracle(np.zeros(len(ds.embeddings) ** 2 // N, dtype=bool))
    t.join(sess.table(embeddings=ds.embeddings[:1], name="tiny"), jo)
    assert o1.stats.n_calls == 0 and o2.stats.n_calls == 0
    assert jo.stats.n_calls == 0
    assert sess.stats.n_calls == 0


def test_single_pred_explain_is_closed_form(ds):
    """A bare Pred has a unique order: explain must not touch the oracle."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    o = _oracle(ds)
    ex = t.filter(o, name="q").explain()
    assert o.stats.n_calls == 0
    assert ex.pilot_calls == 0
    assert ex.est_oracle_calls > 0
    assert "est_oracle_calls" in str(ex)


# ------------------------------------------------- bit-identity: filters
@pytest.mark.parametrize("executor,depth", [("round", 1), ("round", 3),
                                            ("sequential", 1)])
def test_filter_csv_bit_identical(ds, executor, depth):
    cfg = CSVConfig(n_clusters=4, xi=0.005, executor=executor,
                    pipeline_depth=depth)
    ref_table = SemanticTable(embeddings=ds.embeddings)
    r_direct = semantic_filter(
        ds.embeddings, _oracle(ds), cfg,
        precomputed_assign=ref_table.precluster(cfg.n_clusters, cfg.seed))

    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    r = t.filter(_oracle(ds), name="q").collect(
        ExecutionPolicy(method="csv", n_clusters=4, xi=0.005,
                        executor=executor, pipeline_depth=depth))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_llm_calls
    assert r.pilot_calls == 0
    assert r.kind == "filter" and r.order == ["q"]


def test_filter_csv_sim_bit_identical(ds):
    cfg = CSVConfig(n_clusters=4, xi=0.005, vote="sim")
    ref_table = SemanticTable(embeddings=ds.embeddings)
    r_direct = semantic_filter(
        ds.embeddings, _oracle(ds), cfg,
        precomputed_assign=ref_table.precluster(cfg.n_clusters, cfg.seed))

    t = Session().table(embeddings=ds.embeddings)
    r = t.filter(_oracle(ds), name="q").collect(
        ExecutionPolicy(method="csv-sim", n_clusters=4, xi=0.005))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_llm_calls


def test_baselines_bit_identical(ds):
    n = len(ds.embeddings)
    t = Session().table(embeddings=ds.embeddings)

    r_direct = reference_filter(n, _oracle(ds))
    r = t.filter(_oracle(ds), name="r").collect(
        ExecutionPolicy(method="reference"))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_oracle_calls and r.kind == "baseline"

    r_direct = lotus_filter(n, _proxy(ds), _oracle(ds), sample_size=150)
    r = t.filter(_oracle(ds), name="l", proxy=_proxy(ds)).collect(
        ExecutionPolicy(method="lotus", baseline={"sample_size": 150}))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_oracle_calls
    assert r.n_proxy_calls == r_direct.n_proxy_calls == n

    r_direct = bargain_filter(n, _proxy(ds), _oracle(ds))
    r = t.filter(_oracle(ds), name="b", proxy=_proxy(ds)).collect(
        ExecutionPolicy(method="bargain"))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_oracle_calls


def test_session_stats_keep_proxy_spend_separate(ds):
    """Proxy calls (the cheap cascade model) must not inflate the session's
    LLM-oracle aggregate."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    r = t.filter(_oracle(ds), name="l", proxy=_proxy(ds)).collect(
        ExecutionPolicy(method="lotus"))
    assert sess.stats.n_calls == r.n_llm_calls
    assert sess.proxy_stats.n_calls == r.n_proxy_calls == len(ds.embeddings)


def test_expression_bit_identical_to_plan_executor(ds):
    def expr():
        return And(Pred("q1", _oracle(ds)), Pred("q3", _oracle(ds, "RV-Q3")))

    table = SemanticTable(embeddings=ds.embeddings)
    r_direct = PlanExecutor(table, cfg=CFG, optimize=True).run(expr())

    t = Session().table(embeddings=ds.embeddings)
    r = t.filter(expr()).collect(
        ExecutionPolicy(n_clusters=4, xi=0.005, optimize=True))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_llm_calls
    assert r.pilot_calls == r_direct.pilot_calls > 0
    assert r.order == r_direct.order
    assert set(r.round_log) == {"q1", "q3"}


def test_query_composition_matches_expression(ds):
    """`&` on queries builds the same logical plan as the raw AST."""
    t = Session().table(embeddings=ds.embeddings)
    q = t.filter(_oracle(ds), name="q1") & t.filter(_oracle(ds, "RV-Q3"),
                                                    name="q3")
    assert [p.name for p in q.expr.leaves()] == ["q1", "q3"]

    table = SemanticTable(embeddings=ds.embeddings)
    r_direct = PlanExecutor(table, cfg=CFG, optimize=True).run(
        And(Pred("q1", _oracle(ds)), Pred("q3", _oracle(ds, "RV-Q3"))))
    r = q.collect(ExecutionPolicy(n_clusters=4, xi=0.005))
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_llm_calls


def test_join_bit_identical(ds):
    nl, nr = 240, 300
    el, er = ds.embeddings[:nl], ds.embeddings[-nr:]
    pair_truth = np.outer(ds.labels["RV-Q1"][:nl],
                          ds.labels["RV-Q2"][-nr:]).ravel()
    jcfg = JoinConfig()
    tl_, tr_ = SemanticTable(embeddings=el), SemanticTable(embeddings=er)
    r_direct = sem_join(
        el, er, SyntheticOracle(pair_truth, seed=3), jcfg,
        assign_left=tl_.precluster(jcfg.n_clusters_left, jcfg.seed),
        assign_right=tr_.precluster(jcfg.n_clusters_right, jcfg.seed))

    sess = Session()
    hl = sess.table(embeddings=el, name="L")
    hr = sess.table(embeddings=er, name="R")
    r = hl.join(hr, SyntheticOracle(pair_truth, seed=3)).collect()
    assert (r.pair_mask == r_direct.pair_mask).all()
    assert r.n_llm_calls == r_direct.n_llm_calls
    assert r.kind == "join"
    assert (r.pairs == r_direct.pairs).all()


def test_join_rejects_baseline_methods(ds):
    sess = Session()
    hl = sess.table(embeddings=ds.embeddings[:100], name="jl")
    hr = sess.table(embeddings=ds.embeddings[:100], name="jr")
    q = hl.join(hr, SyntheticOracle(np.zeros(100 * 100, dtype=bool)))
    with pytest.raises(ValueError, match="not supported for joins"):
        q.collect(ExecutionPolicy(method="reference"))
    with pytest.raises(ValueError, match="not supported for joins"):
        q.explain(ExecutionPolicy(method="lotus"))


# -------------------------------------------- explain/collect interaction
def test_explain_does_not_perturb_collect(ds):
    """Explain pays the (memoized) pilot up front; the subsequent collect
    must consume the flip-RNG stream and report call counts exactly as a
    cold collect would."""
    def build():
        t = Session().table(embeddings=ds.embeddings)
        return (t.filter(_oracle(ds), name="q1")
                & t.filter(_oracle(ds, "RV-Q3"), name="q3")
                & t.filter(_oracle(ds, "RV-Q2"), name="q2"))

    r_cold = build().collect()
    warm = build()
    ex = warm.explain()
    assert ex.pilot_calls > 0 and len(ex.nodes) == 3
    assert ex.order[0] == "q3"  # most selective conjunct first
    r_warm = warm.collect()
    assert (r_cold.mask == r_warm.mask).all()
    assert r_cold.n_llm_calls == r_warm.n_llm_calls
    assert r_cold.pilot_calls == r_warm.pilot_calls == ex.pilot_calls


def test_explain_pilot_is_absorbed_into_session_stats(ds):
    """The pilot spent by explain() must show up in the run-level aggregate:
    after explain + collect, session totals equal the query's reported
    calls (pilot included) — same as a cold collect."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    q = (t.filter(_oracle(ds), name="q1")
         & t.filter(_oracle(ds, "RV-Q3"), name="q3"))
    q.explain()
    assert sess.stats.n_calls > 0  # pilot absorbed at explain time
    r = q.collect()
    assert sess.stats.n_calls == r.n_llm_calls

    cold_sess = Session()
    tc = cold_sess.table(embeddings=ds.embeddings)
    rc = (tc.filter(_oracle(ds), name="q1")
          & tc.filter(_oracle(ds, "RV-Q3"), name="q3")).collect()
    assert cold_sess.stats.n_calls == rc.n_llm_calls == r.n_llm_calls


def test_collect_other_policy_after_explain_matches_cold(ds):
    """Explain under one policy then collect under another (same seed /
    pilot_size): the cached pilot probe is reused, so reported call counts
    and masks match a cold collect under the second policy."""
    pol = ExecutionPolicy(n_clusters=8, xi=0.005)

    def build():
        t = Session().table(embeddings=ds.embeddings)
        return (t.filter(_oracle(ds), name="q1")
                & t.filter(_oracle(ds, "RV-Q3"), name="q3"))

    r_cold = build().collect(pol)
    warm = build()
    warm.explain()  # session-default policy, same seed/pilot_size
    r_warm = warm.collect(pol)
    assert (r_cold.mask == r_warm.mask).all()
    assert r_cold.n_llm_calls == r_warm.n_llm_calls
    assert r_cold.pilot_calls == r_warm.pilot_calls > 0


def test_combining_conflicting_policies_rejected(ds):
    t = Session().table(embeddings=ds.embeddings)
    q1 = t.filter(_oracle(ds), name="q1",
                  policy=ExecutionPolicy(xi=0.02))
    q2 = t.filter(_oracle(ds, "RV-Q3"), name="q3",
                  policy=ExecutionPolicy(method="csv-sim"))
    with pytest.raises(ValueError, match="conflicting ExecutionPolicies"):
        _ = q1 & q2
    # one explicit policy (or two equal ones) composes fine
    q3 = t.filter(_oracle(ds, "RV-Q3"), name="q3")
    assert (q1 & q3).policy == q1.policy
    q4 = t.filter(_oracle(ds, "RV-Q3"), name="q3",
                  policy=ExecutionPolicy(xi=0.02))
    assert (q1 & q4).policy == q1.policy


def test_explain_estimates_decrease_down_the_cascade(ds):
    t = Session().table(embeddings=ds.embeddings)
    q = (t.filter(_oracle(ds), name="q1")
         & t.filter(_oracle(ds, "RV-Q3"), name="q3"))
    ex = q.explain()
    lives = [nd.est_live_in for nd in ex.nodes]
    assert lives[0] == len(ds.embeddings) and lives[1] < lives[0]
    assert all(nd.selectivity is not None for nd in ex.nodes)


# -------------------------------------------------- session-level state
def test_two_tables_never_share_precluster_assignments(ds):
    """Regression (ISSUE 3 satellite): the session cache is keyed by table
    id, so same-(k, seed) clusterings of different tables stay distinct."""
    rng = np.random.default_rng(0)
    sess = Session()
    a = sess.table(embeddings=ds.embeddings, name="a")
    b = sess.table(embeddings=rng.normal(size=ds.embeddings.shape), name="b")
    assign_a = a.precluster(4, seed=0)
    assign_b = b.precluster(4, seed=0)
    assert ("a", 4, 0) in sess._assign_cache
    assert ("b", 4, 0) in sess._assign_cache
    assert assign_a is not assign_b
    assert not (assign_a == assign_b).all()
    # and the cache actually caches: same object back on re-request
    assert a.precluster(4, seed=0) is assign_a


def test_session_stats_accumulate_across_collects(ds):
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    r1 = t.filter(_oracle(ds), name="q1").collect()
    assert sess.stats.n_calls == r1.n_llm_calls
    r2 = t.filter(_oracle(ds, "RV-Q3"), name="q3").collect()
    assert sess.stats.n_calls == r1.n_llm_calls + r2.n_llm_calls
    assert len(sess.stats.batch_sizes) > 0


def test_oracle_registry_roundtrip(ds):
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    sess.register_oracle("positive", _oracle(ds), proxy=_proxy(ds))
    q = t.filter("positive")
    assert q.expr.name == "positive" and q.proxy is not None
    with pytest.raises(ValueError, match="already registered"):
        sess.register_oracle("positive", _oracle(ds))
    with pytest.raises(KeyError, match="no oracle registered"):
        t.filter("missing")


def test_table_registration_rules(ds):
    sess = Session()
    st = SemanticTable(embeddings=ds.embeddings)
    h1 = sess.table(table=st)
    assert sess.table(table=st) is h1  # same object => same handle
    with pytest.raises(ValueError, match="already registered"):
        sess.table(table=st, name="other")
    with pytest.raises(ValueError, match="already registered"):
        sess.table(embeddings=ds.embeddings, name=h1.name)
    assert sess[h1.name] is h1


# ------------------------------------------------------------ validation
def test_policy_and_query_validation(ds):
    with pytest.raises(ValueError, match="unknown method"):
        ExecutionPolicy(method="nope")
    with pytest.raises(ValueError, match="unknown executor"):
        ExecutionPolicy(executor="warp")
    with pytest.raises(ValueError, match="pipeline_depth"):
        ExecutionPolicy(pipeline_depth=0)

    sess = Session()
    t = sess.table(embeddings=ds.embeddings, name="a")
    u = sess.table(embeddings=ds.embeddings, name="b")
    with pytest.raises(ValueError, match="same table"):
        _ = t.filter(_oracle(ds), name="x") & u.filter(_oracle(ds), name="y")
    with pytest.raises(ValueError, match="requires a proxy"):
        t.filter(_oracle(ds), name="x").collect(
            ExecutionPolicy(method="lotus"))
    with pytest.raises(ValueError, match="single bare predicate"):
        (t.filter(_oracle(ds), name="x")
         & t.filter(_oracle(ds, "RV-Q3"), name="y")).collect(
            ExecutionPolicy(method="reference"))
    with pytest.raises(TypeError):
        t.filter(12345)


def test_budget_guard_spends_nothing(ds):
    t = Session().table(embeddings=ds.embeddings)
    o = _oracle(ds)
    with pytest.raises(OracleBudgetError, match="exceed"):
        t.filter(o, name="q").collect(ExecutionPolicy(max_oracle_calls=5))
    assert o.stats.n_calls == 0  # the guard is closed-form


def test_argument_validation_survives_python_O(ds):
    """Satellite: constructor/method misuse raises real exceptions."""
    with pytest.raises(ValueError, match="texts and/or embeddings"):
        SemanticTable()
    with pytest.raises(ValueError, match="no embedder"):
        SemanticTable(texts=["a", "b"]).embeddings
    table = SemanticTable(embeddings=ds.embeddings)
    with pytest.raises(ValueError, match="unknown method"):
        table.sem_filter(_oracle(ds), method="nope")
    with pytest.raises(ValueError, match="requires a proxy"):
        table.sem_filter(_oracle(ds), method="lotus")


# ------------------------------------------------------- legacy shims
def test_legacy_sem_filter_warns_and_matches_direct(ds):
    cfg = CSVConfig(n_clusters=4, xi=0.005)
    ref_table = SemanticTable(embeddings=ds.embeddings)
    r_direct = semantic_filter(
        ds.embeddings, _oracle(ds), cfg,
        precomputed_assign=ref_table.precluster(cfg.n_clusters, cfg.seed))

    table = SemanticTable(embeddings=ds.embeddings)
    with pytest.warns(DeprecationWarning, match="sem_filter"):
        r = table.sem_filter(_oracle(ds), method="csv", cfg=cfg)
    assert (r.mask == r_direct.mask).all()
    assert r.n_llm_calls == r_direct.n_llm_calls
    assert r.n_input == len(ds.embeddings)  # a genuine FilterResult


def test_legacy_sem_filter_expr_warns(ds):
    table = SemanticTable(embeddings=ds.embeddings)
    with pytest.warns(DeprecationWarning, match="sem_filter_expr"):
        r = table.sem_filter_expr(Pred("q1", _oracle(ds)), cfg=CFG)
    assert r.pilot_calls == 0 and r.order == ["q1"]


def test_deprecation_warnings_point_at_caller(ds):
    """The shims must attribute their DeprecationWarning to the CALLER's
    file/line (stacklevel), not to the shim body — otherwise every
    deprecation report points at operators.py and is useless for
    migration."""
    table = SemanticTable(embeddings=ds.embeddings)
    o = _oracle(ds)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        lineno = inspect.currentframe().f_lineno + 1
        table.sem_filter(o, method="csv", cfg=CFG)
        table.sem_filter_expr(Pred("q1", _oracle(ds)), cfg=CFG)
        tiny = SemanticTable(embeddings=ds.embeddings[:60])
        tiny.sem_join(SemanticTable(embeddings=ds.embeddings[:60]),
                      SyntheticOracle(np.zeros(60 * 60, dtype=bool)))
    dep = [w for w in rec if w.category is DeprecationWarning]
    assert len(dep) == 3
    assert all(w.filename == __file__ for w in dep), \
        [w.filename for w in dep]  # caller, not the shim module
    assert dep[0].lineno == lineno


def test_legacy_sem_join_warns(ds):
    nl, nr = 150, 180
    pair_truth = np.outer(ds.labels["RV-Q1"][:nl],
                          ds.labels["RV-Q2"][:nr]).ravel()
    tl_ = SemanticTable(embeddings=ds.embeddings[:nl])
    tr_ = SemanticTable(embeddings=ds.embeddings[:nr])
    with pytest.warns(DeprecationWarning, match="sem_join"):
        r = tl_.sem_join(tr_, SyntheticOracle(pair_truth, seed=3))
    assert r.pair_mask.shape == (nl, nr)


# ------------------------------------------------------- result surface
def test_query_result_unified_fields(ds):
    t = Session().table(embeddings=ds.embeddings)
    r = t.filter(_oracle(ds), name="q").collect()
    assert isinstance(r, QueryResult)
    assert r.mask is not None and r.pair_mask is None
    with pytest.raises(ValueError, match="join"):
        _ = r.pairs
    assert r.input_tokens > 0 and r.total_time_s >= 0
    assert r.policy.method == "csv"
    assert r.node_log[0].name == "q"


# ------------------------------------------------- pilot accounting (ISSUE 5)
def test_replan_reuses_pilot_stats_instead_of_reprobing(ds):
    """A re-plan resolving a different pilot-cache key (reuse knobs
    toggled) must serve the CACHED fresh probe, not probe the now
    memo-warm oracle: a warm re-probe would report pilot_calls=0 and the
    default tokens_per_call, making the pilot look free and corrupting
    the cost ordering."""
    sess = Session()
    t = sess.table(embeddings=ds.embeddings)
    q = (t.filter(_oracle(ds), name="q1")
         & t.filter(_oracle(ds, "RV-Q3"), name="q3"))
    on = ExecutionPolicy(n_clusters=4)
    off = on.replace(reuse_memo=False, reuse_stats=False)
    ex_on = q.explain(on)
    assert ex_on.pilot_calls > 0
    ex_off = q.explain(off)   # different cache key, oracle memo now warm
    assert ex_off.pilot_calls == ex_on.pilot_calls
    assert ex_off.order == ex_on.order
    stats = q._fresh_pilots[(on.seed, on.pilot_size, 0)]
    assert all(ps.pilot_calls > 0 and ps.tokens_per_call != 64.0
               for ps in stats.values())
    r = q.collect(off)
    assert r.pilot_calls == ex_on.pilot_calls
