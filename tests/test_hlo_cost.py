"""Trip-count-aware HLO cost model: exactness on known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_plain_matmul_flops_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((512, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 512), jnp.float32))
    assert analyze(c.as_text()).flops == pytest.approx(2 * 512**3, rel=1e-6)


def test_scan_trip_count_expanded():
    def f(x, ws):
        def body(cr, w):
            return cr @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((512, 512), jnp.float32),
                 jax.ShapeDtypeStruct((10, 512, 512), jnp.float32))
    got = analyze(c.as_text())
    assert got.flops == pytest.approx(10 * 2 * 512**3, rel=1e-6)
    # XLA's own cost_analysis undercounts by the trip count — the very
    # artifact this module exists to fix.  (Older jax returns a one-element
    # list; newer returns the dict directly.)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] == pytest.approx(2 * 512**3, rel=1e-6)


def test_nested_scan_product_of_trips():
    def g(x, ws):
        def outer(cr, wrow):
            def inner(c2, w):
                return c2 @ w, None
            y, _ = jax.lax.scan(inner, cr, wrow)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    c = _compile(g, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                 jax.ShapeDtypeStruct((4, 5, 256, 256), jnp.float32))
    assert analyze(c.as_text()).flops == pytest.approx(20 * 2 * 256**3,
                                                       rel=1e-6)


def test_bytes_scale_with_trips():
    def f(x, ws):
        def body(cr, w):
            return cr @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c1 = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                  jax.ShapeDtypeStruct((2, 256, 256), jnp.float32))
    c2 = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                  jax.ShapeDtypeStruct((20, 256, 256), jnp.float32))
    b1 = analyze(c1.as_text()).bytes
    b2 = analyze(c2.as_text()).bytes
    assert b2 > 5 * b1  # grows with trip count
