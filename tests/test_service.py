"""Concurrent semantic-filter service (ISSUE 5 acceptance criteria).

Hard contracts:
1. N interleaved ``submit()``s produce masks AND per-query oracle call
   counts identical to N serial ``collect()``s under fixed seeds — the
   cross-query batcher merges dispatches, it never perturbs per-query
   sampling, voting, memo, or flip-RNG streams;
2. on >= 4 concurrent queries over shared tables, the mean oracle batch
   size per merged invocation is >= 1.5x the serial per-invocation mean;
3. two submissions sharing an oracle are conflict-serialized in
   submission order (the second replays from the session memo exactly as
   it would serially);
4. a session saved to disk and reloaded replays previously-collected
   filter AND join queries at zero oracle calls, bit-identically; after a
   post-reload ``append()`` only dirty clusters re-vote — matching an
   unrestarted control bit for bit;
5. tenant admission: aggregate worst-case reservations against
   ``ExecutionPolicy.max_oracle_calls`` reject over-budget submissions
   up front, and settle to actual spend at gather.
"""
import time

import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.service import (FilterService, SessionStore, TenantBudgetError)

N = 1200
POL = ExecutionPolicy(n_clusters=4, xi=0.005)


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("imdb_review", n=N, seed=0)


@pytest.fixture(scope="module")
def join_sides():
    from repro.data import make_dataset
    dl = make_dataset("imdb_review", n=80, seed=1, n_topics=4)
    dr = make_dataset("imdb_review", n=60, seed=2, n_topics=4)
    truth = (dl.topics[:, None] % 2) == (dr.topics[None, :] % 2)
    return dl, dr, truth


def _oracle(ds, q="RV-Q1", flip=0.02, seed=7):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=seed,
                           token_lens=ds.token_lens)


def _blobs(n_per=300, k=4, seed=0):
    """k well-separated clusters: k-means recovers them exactly, so the
    dirty-cluster arithmetic is deterministic (same as test_session_reuse)."""
    rng = np.random.default_rng(seed)
    centers = np.eye(k, k, dtype=np.float32) * 10.0
    emb = np.concatenate([
        centers[i] + rng.normal(0, 0.5, (n_per, k)).astype(np.float32)
        for i in range(k)])
    labels = np.concatenate([np.full(n_per, bool(i % 2 == 0))
                             for i in range(k)])
    return centers, emb, labels


def _mixed_workload(ds, join_sides):
    """One session + the 5-query mixed workload (4 filters incl. an
    expression cascade, 1 join), with fresh oracle objects per call."""
    dl, dr, truth = join_sides
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    tl = sess.table(embeddings=dl.embeddings, name="L")
    tr = sess.table(embeddings=dr.embeddings, name="R")
    jo = SyntheticOracle(truth.ravel(), flip_prob=0.0, seed=3)
    queries = [
        t.filter(_oracle(ds, "RV-Q1"), name="A"),
        t.filter(_oracle(ds, "RV-Q3"), name="B"),
        t.filter(_oracle(ds, "RV-Q1", seed=11), name="C")
        & t.filter(_oracle(ds, "RV-Q3", seed=12), name="D"),
        ~t.filter(_oracle(ds, "RV-Q3", seed=13), name="E"),
        tl.join(tr, jo),
    ]
    return sess, queries


# ------------------------------------------------- concurrency determinism
def test_interleaved_submits_match_serial_collects(ds, join_sides):
    s_serial, qs = _mixed_workload(ds, join_sides)
    serial = [q.collect() for q in qs]
    serial_batches = []
    for q in qs:
        for o in (q._oracles() if hasattr(q, "_oracles") else [q.oracle]):
            serial_batches.extend(o.stats.batch_sizes)

    s_conc, qc = _mixed_workload(ds, join_sides)
    try:
        with s_conc.scheduler.holding():
            tickets = [s_conc.submit(q) for q in qc]
        conc = s_conc.gather(*tickets)
        for rs, rc in zip(serial, conc):
            assert rc.n_llm_calls == rs.n_llm_calls
            assert rc.pilot_calls == rs.pilot_calls
            if rs.mask is not None:
                assert (rc.mask == rs.mask).all()
            else:
                assert (rc.pair_mask == rs.pair_mask).all()
        # run-level aggregates agree too (order-independent totals)
        assert s_conc.stats.n_calls == s_serial.stats.n_calls
        assert s_conc.stats.input_tokens == s_serial.stats.input_tokens

        # acceptance: >= 4 concurrent queries over shared tables merge into
        # dispatches >= 1.5x the serial per-invocation mean
        merge = s_conc.scheduler.stats.merge
        assert merge.n_invocations > 0
        ratio = merge.mean_batch_size / np.mean(serial_batches)
        assert ratio >= 1.5, f"mean merged batch only {ratio:.2f}x serial"
        assert merge.merge_factor > 1.5
    finally:
        s_conc.close()


def test_submit_does_not_perturb_later_serial_collect(ds):
    """The scheduled clone and the serial path share pilot caches and memo
    identity: submit-then-collect behaves exactly like collect-then-collect
    (second run replays at zero calls)."""
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    o = _oracle(ds)
    q = t.filter(o, name="A")
    try:
        (r1,) = sess.gather(sess.submit(q))
        r2 = q.collect()   # serial, same query object
        assert r2.n_llm_calls == 0 and r2.n_replayed == N
        assert (r2.mask == r1.mask).all()
    finally:
        sess.close()


def test_conflicting_submissions_serialize_and_replay(ds):
    """Two submissions over one oracle object never run concurrently: the
    second defers until the first finishes, then replays its memoized
    decisions — the exact serial interleaving."""
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    o = _oracle(ds)
    try:
        with sess.scheduler.holding():
            k1 = sess.submit(t.filter(o, name="A"))
            k2 = sess.submit(t.filter(o, name="A"))
        r1, r2 = sess.gather(k1, k2)
        assert sess.scheduler.stats.n_deferred == 1
        assert r1.n_llm_calls > 0
        assert r2.n_llm_calls == 0 and r2.n_replayed == N
        assert (r2.mask == r1.mask).all()
        assert o.stats.n_calls == r1.n_llm_calls
    finally:
        sess.close()


def test_failed_query_does_not_wedge_the_scheduler(ds):
    class Boom(RuntimeError):
        pass

    class FailingOracle(SyntheticOracle):
        def _evaluate(self, ids):
            raise Boom("oracle down")

    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    bad = FailingOracle(ds.labels["RV-Q1"])
    good = _oracle(ds)
    try:
        with sess.scheduler.holding():
            kb = sess.submit(t.filter(bad, name="bad"))
            kg = sess.submit(t.filter(good, name="good"))
        with pytest.raises(Boom):
            kb.result()
        (rg,) = sess.gather(kg)
        assert rg.n_llm_calls > 0
        ref = Session(policy=POL).table(
            embeddings=ds.embeddings).filter(
                _oracle(ds), name="good").collect()
        assert (rg.mask == ref.mask).all()
    finally:
        sess.close()


# -------------------------------------------------------------- persistence
def _persist_session(ds, join_sides):
    """Session with registered (durable-named) oracles and tables."""
    dl, dr, truth = join_sides
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    tl = sess.table(embeddings=dl.embeddings, name="L")
    tr = sess.table(embeddings=dr.embeddings, name="R")
    sess.register_oracle("A", _oracle(ds, "RV-Q1"))
    sess.register_oracle("B", _oracle(ds, "RV-Q3"))
    sess.register_oracle("J", SyntheticOracle(truth.ravel(), flip_prob=0.0,
                                              seed=3))
    return sess, t, tl, tr


def test_persistence_roundtrip_zero_call_replay(ds, join_sides, tmp_path):
    sess, t, tl, tr = _persist_session(ds, join_sides)
    rA = t.filter("A").collect()
    rB0 = t.filter("B").collect()
    rB = (t.filter("A") & t.filter("B")).collect()
    rJ = tl.join(tr, sess.oracle("J")).collect()
    store = SessionStore(tmp_path)
    store.save(sess)

    # "new process": fresh session, fresh oracle objects, same names/data
    sess2, t2, tl2, tr2 = _persist_session(ds, join_sides)
    rep = store.load(sess2)
    assert set(rep.tables) == {"reviews", "L", "R"}
    assert rep.n_decisions >= 2 and rep.n_joins == 1 and not rep.skipped
    r2A = t2.filter("A").collect()
    assert r2A.n_llm_calls == 0 and r2A.n_replayed == N
    assert (r2A.mask == rA.mask).all()
    r2B0 = t2.filter("B").collect()
    assert r2B0.n_llm_calls == 0 and (r2B0.mask == rB0.mask).all()
    r2B = (t2.filter("A") & t2.filter("B")).collect()
    assert r2B.n_llm_calls == 0 and (r2B.mask == rB.mask).all()
    r2J = tl2.join(tr2, sess2.oracle("J")).collect()
    assert r2J.n_llm_calls == 0
    assert r2J.n_replayed == r2J.pair_mask.size
    assert (r2J.pair_mask == rJ.pair_mask).all()
    # restored sessions spent zero oracle calls end to end
    assert sess2.stats.n_calls == 0


def test_reload_then_append_revotes_only_dirty_clusters(tmp_path):
    centers, emb, labels = _blobs()
    add = centers[0] + np.random.default_rng(9).normal(
        0, 0.5, (40, 4)).astype(np.float32)
    post_labels = np.concatenate([labels, np.full(40, True)])

    def build():
        s = Session(policy=POL)
        t = s.table(embeddings=emb, name="blobs")
        # oracle over the post-append labels (ids must cover the grown
        # range; see docs/caching.md)
        s.register_oracle("P", SyntheticOracle(post_labels, flip_prob=0.0,
                                               seed=7))
        return s, t

    s1, t1 = build()
    r1 = t1.filter("P").collect()
    SessionStore(tmp_path).save(s1)

    s2, t2 = build()
    rep = SessionStore(tmp_path).load(s2)
    assert rep.tables == ["blobs"] and not rep.skipped
    t2.append(embeddings=add)
    r2 = t2.filter("P").collect()
    # exactly the 3 clean clusters replay; only cluster 0 (+ appendees)
    # re-votes
    assert r2.n_replayed == 900
    assert 0 < r2.n_llm_calls < r1.n_llm_calls
    assert (r2.mask[: len(labels)] == r1.mask).all()

    # bit-identical to the unrestarted control
    s3, t3 = build()
    t3.filter("P").collect()
    t3.append(embeddings=add)
    rc = t3.filter("P").collect()
    assert rc.n_llm_calls == r2.n_llm_calls
    assert (rc.mask == r2.mask).all()


def test_store_invalidates_on_changed_table(ds, tmp_path):
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    sess.register_oracle("A", _oracle(ds))
    t.filter("A").collect()
    SessionStore(tmp_path).save(sess)

    other = np.asarray(ds.embeddings).copy()
    other[0] += 1.0  # different content under the same name
    sess2 = Session(policy=POL)
    sess2.table(embeddings=other, name="reviews")
    sess2.register_oracle("A", _oracle(ds))
    rep = SessionStore(tmp_path).load(sess2)
    assert rep.tables == [] and rep.n_decisions == 0
    assert any("content changed" in s for s in rep.skipped)
    with pytest.raises(ValueError, match="content changed"):
        SessionStore(tmp_path).load(sess2, strict=True)


def test_store_invalidates_on_reencoded_texts(ds, tmp_path):
    """Same texts embedded by a DIFFERENT encoder are different data: the
    fingerprint hashes both components, so restored precluster state can
    never silently mismatch the rebuilt embedding space."""
    texts = [f"review number {i}" for i in range(N)]
    sess = Session(policy=POL)
    sess.table(texts=texts, embeddings=ds.embeddings, name="reviews")
    sess.register_oracle("A", _oracle(ds))
    sess["reviews"].filter("A").collect()
    SessionStore(tmp_path).save(sess)

    sess2 = Session(policy=POL)
    sess2.table(texts=texts, embeddings=ds.embeddings * 0.5, name="reviews")
    sess2.register_oracle("A", _oracle(ds))
    rep = SessionStore(tmp_path).load(sess2)
    assert rep.tables == [] and rep.n_decisions == 0
    assert any("content changed" in s for s in rep.skipped)


def test_result_under_hold_raises_instead_of_deadlocking(ds):
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    try:
        with sess.scheduler.holding():
            tk = sess.submit(t.filter(_oracle(ds), name="A"))
            with pytest.raises(RuntimeError, match="holding"):
                tk.result(timeout=5)
            with pytest.raises(RuntimeError, match="holding"):
                sess.gather(tk)   # gather must not destroy an active hold
        (r,) = sess.gather(tk)
        assert r.n_llm_calls > 0
    finally:
        sess.close()


def test_store_skips_unregistered_oracles(ds, tmp_path):
    """Decisions of inline (never-registered) oracles have no durable name:
    the save drops them with a note instead of corrupting the store."""
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    t.filter(_oracle(ds), name="anon").collect()
    store = SessionStore(tmp_path)
    store.save(sess)
    sess2 = Session(policy=POL)
    sess2.table(embeddings=ds.embeddings, name="reviews")
    rep = store.load(sess2)
    assert rep.n_decisions == 0 and rep.tables == ["reviews"]


# ---------------------------------------------------------------- admission
def test_tenant_admission_and_settlement(ds):
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    svc = FilterService(sess)
    svc.register_tenant("small", POL.replace(max_oracle_calls=100))
    svc.register_tenant("big", POL.replace(max_oracle_calls=50_000))
    try:
        with pytest.raises(TenantBudgetError):
            svc.submit("small", t.filter(_oracle(ds), name="S"))
        assert svc.tenant("small").n_rejected == 1

        o = _oracle(ds)
        tk = svc.submit("big", t.filter(o, name="A"))
        (r,) = svc.gather(tk)
        acct = svc.tenant("big")
        assert acct.spent == r.n_llm_calls > 0
        assert acct.reserved == 0.0
        # a replayable resubmission reserves ~0: warm queries fit budgets
        # their cold run would blow
        tk2 = svc.submit("big", t.filter(o, name="A"),
                         policy=POL.replace(max_oracle_calls=50))
        (r2,) = svc.gather(tk2)
        assert r2.n_llm_calls == 0 and acct.spent == r.n_llm_calls
    finally:
        svc.close()


def test_settlement_rides_on_completion_not_gather(ds):
    """A client consuming its ticket via result() (never gather) must
    still free the tenant's reservation — and a failed ticket consumed
    that way must not resurface in a later no-arg gather."""
    class Boom(RuntimeError):
        pass

    class FailingOracle(SyntheticOracle):
        def _evaluate(self, ids):
            raise Boom("oracle down")

    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    svc = FilterService(sess)
    svc.register_tenant("t", POL.replace(max_oracle_calls=2000))
    try:
        bad = svc.submit("t", t.filter(FailingOracle(ds.labels["RV-Q1"]),
                                       name="bad"))
        with pytest.raises(Boom):
            bad.result(timeout=60)
        acct = svc.tenant("t")
        deadline = 60.0
        while acct.reserved and deadline > 0:   # done-callback settles
            time.sleep(0.01)
            deadline -= 0.01
        assert acct.reserved == 0.0 and acct.spent == 0
        # the budget is genuinely free again, and the consumed failure
        # does not re-raise out of an unrelated gather
        ok = svc.submit("t", t.filter(_oracle(ds), name="ok"))
        (r,) = svc.gather()
        assert r is not None and r.n_llm_calls > 0
        assert ok.done()
    finally:
        svc.close()


def test_unknown_tenant_rejected(ds):
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings)
    svc = FilterService(sess)
    with pytest.raises(KeyError, match="unknown tenant"):
        svc.submit("ghost", t.filter(_oracle(ds), name="A"))
