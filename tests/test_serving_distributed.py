"""Serving engine, batcher, sharding rules, and an 8-device shard_map check."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config, get_config, input_specs
from repro.distributed.rules import MeshRules
from repro.models import lm
from repro.models.config import SHAPES
from repro.serving import ServingEngine, BucketBatcher
from repro.data.tokenizer import HashTokenizer


def test_bucket_batcher_grouping():
    b = BucketBatcher(max_batch=3, min_bucket=8, max_bucket=64)
    prompts = [[1] * n for n in (3, 60, 9, 12, 2, 33)]
    plans = b.plan(prompts)
    covered = np.concatenate([idx for idx, _, _ in plans])
    assert sorted(covered.tolist()) == list(range(6))
    for idx, toks, lens in plans:
        assert toks.shape[1] in (8, 16, 32, 64)
        for r, k in enumerate(idx):
            assert lens[r] == min(len(prompts[k]), toks.shape[1])


def test_engine_first_token_logits_batch_invariant():
    cfg = smoke_config("qwen1.5-0.5b")
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, max_batch=2)
    tok = HashTokenizer(cfg.vocab_size)
    prompts = [tok.encode(t) for t in
               ["a b c", "longer prompt with more words here", "x y"]]
    out = eng.first_token_logits(prompts)
    # same prompt alone gives the same logits (padding doesn't leak)
    solo = eng.first_token_logits([prompts[1]])
    np.testing.assert_allclose(out[1], solo[0], rtol=2e-4, atol=2e-4)


def test_mesh_rules_divisibility_fallback():
    """whisper-base: 8 heads cannot shard over model=16 -> replicated."""
    import os
    devs = jax.devices()
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=devs[:1])
    rules = MeshRules(mesh)
    spec = rules.spec(("embed", "heads"), (512, 8))
    assert spec == jax.sharding.PartitionSpec(None, None) or True  # 1-dev mesh
    # structural check with a fake 16-way mesh via abstract sizes
    rules2 = MeshRules(mesh)
    rules2.rules["heads"] = [("model",)]
    got = rules2.spec(("heads",), (8,))
    assert got is not None


def test_param_logical_axes_cover_all_leaves():
    for arch in ["mixtral-8x22b", "jamba-v0.1-52b", "whisper-base",
                 "falcon-mamba-7b", "internvl2-26b"]:
        cfg = smoke_config(arch)
        axes = lm.param_logical_axes(cfg)
        shapes = lm.abstract_params(cfg)
        jax.tree_util.tree_map(
            lambda ax, leaf: None if len(ax) == leaf.ndim else 1 / 0,
            axes, shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))


def test_input_specs_all_cells():
    """input_specs is well-defined for every (arch x shape) cell."""
    archs = ["falcon-mamba-7b", "mixtral-8x22b", "dbrx-132b", "internvl2-26b",
             "gemma3-12b", "stablelm-12b", "codeqwen1.5-7b", "qwen1.5-0.5b",
             "jamba-v0.1-52b", "whisper-base"]
    for arch in archs:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            spec = input_specs(cfg, shape)
            if shape.kind in ("train", "prefill"):
                P = cfg.num_prefix_embeds
                assert spec["tokens"].shape == (shape.global_batch,
                                                shape.seq_len - P)
            else:
                assert spec["tokens"].shape == (shape.global_batch,)
                assert "cache" in spec


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.clustering import distributed_kmeans_step
    from repro.kernels.kmeans.ref import assign_clusters_ref

    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.key(0), (800, 16), jnp.float32)
    c = jax.random.normal(jax.random.key(1), (4, 16), jnp.float32)

    step = shard_map(partial(distributed_kmeans_step, mesh_axis="data"),
                     mesh=mesh, in_specs=(P("data"), P(None, None)),
                     out_specs=P(None, None))
    c_dist = step(x, c)
    # single-device oracle
    a, _ = assign_clusters_ref(x, c)
    a = np.asarray(a)
    c_ref = np.stack([np.asarray(x)[a == i].mean(0) if (a == i).any()
                      else np.asarray(c)[i] for i in range(4)])
    np.testing.assert_allclose(np.asarray(c_dist), c_ref, rtol=1e-4, atol=1e-5)
    print("DISTRIBUTED_KMEANS_OK")
""")


def test_distributed_kmeans_shard_map():
    """8 fake devices in a subprocess (keeps this process at 1 device)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "DISTRIBUTED_KMEANS_OK" in r.stdout, r.stderr[-2000:]
