# NOTE: no XLA_FLAGS here — tests must see the single real CPU device.
# The 512-device override belongs ONLY to repro.launch.dryrun.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
