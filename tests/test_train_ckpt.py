"""Training substrate + checkpointing + gradient compression + fault tolerance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree
from repro.configs import smoke_config
from repro.models import lm
from repro.train import OptConfig, adamw_init, make_train_step
from repro.train.grad_compression import (compress_with_feedback,
                                          init_residuals, _int8_roundtrip,
                                          _topk_mask)


def _setup(arch="qwen1.5-0.5b", lr=3e-3):
    cfg = smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    oc = OptConfig(lr=lr, warmup_steps=2, total_steps=50)
    opt = adamw_init(params, oc)
    return cfg, params, oc, opt


def _batch(cfg, B=4, S=16, seed=0):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_loss_decreases():
    cfg, params, oc, opt = _setup()
    step = jax.jit(make_train_step(cfg, oc))
    batch = _batch(cfg)
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatched_grads_match_full():
    cfg, params, oc, opt = _setup()
    batch = _batch(cfg, B=4)
    full = make_train_step(cfg, oc)
    micro = make_train_step(cfg, oc, microbatches=2)
    p1, _, m1 = full(params, opt, batch)
    p2, _, m2 = micro(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    l1 = jax.tree_util.tree_leaves(p1)[3]
    l2 = jax.tree_util.tree_leaves(p2)[3]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3,
                               atol=1e-5)


def test_int8_roundtrip_error_small():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    r = _int8_roundtrip(g)
    rel = float(jnp.linalg.norm(r - g) / jnp.linalg.norm(g))
    assert rel < 0.02


def test_error_feedback_contracts():
    """Residual-corrected compression: accumulated error stays bounded and the
    *sum* of compressed messages converges to the sum of true gradients."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(128,)), jnp.float32)
              for _ in range(30)]
    res = {"w": jnp.zeros((128,), jnp.float32)}
    sent_sum = jnp.zeros((128,))
    true_sum = jnp.zeros((128,))
    for g in g_true:
        comp, res = compress_with_feedback({"w": g}, res, method="topk",
                                           topk_frac=0.2)
        sent_sum = sent_sum + comp["w"]
        true_sum = true_sum + g
    # with error feedback, sent_sum trails true_sum by at most the residual
    gap = float(jnp.linalg.norm(sent_sum - true_sum))
    assert gap == pytest.approx(float(jnp.linalg.norm(res["w"])), rel=1e-4)
    assert gap < 0.5 * float(jnp.linalg.norm(true_sum))


def test_checkpoint_roundtrip(tmp_path):
    cfg, params, oc, opt = _setup()
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(3, {"params": params, "opt": opt}, {"note": "x"})
    step, tree, extra = mgr.restore({"params": params, "opt": opt})
    assert step == 3 and extra["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree["params"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_crash_cleanup(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]
    # simulate a crashed writer
    (tmp_path / "step_00000005.tmp-dead").mkdir()
    assert mgr.latest_step() == 4
    mgr.save(6, tree)
    assert not list(tmp_path.glob("*.tmp-*"))


def test_checkpoint_detects_corruption(tmp_path):
    save_pytree({"x": jnp.arange(16)}, tmp_path / "ck")
    # shard extension depends on the active codec (zstd or the zlib fallback)
    blob, = (tmp_path / "ck").glob("shard_000.msgpack.*")
    data = bytearray(blob.read_bytes())
    data[-1] ^= 0xFF
    blob.write_bytes(bytes(data))
    with pytest.raises(Exception):
        load_pytree(tmp_path / "ck", {"x": jnp.arange(16)})


def test_async_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.arange(100)}, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_train_resume_equivalence(tmp_path):
    """Crash/restart: resume from checkpoint reproduces the exact state."""
    cfg, params, oc, opt = _setup()
    step = jax.jit(make_train_step(cfg, oc))
    mgr = CheckpointManager(tmp_path)
    b = [_batch(cfg, seed=s) for s in range(6)]
    for i in range(3):
        params, opt, _ = step(params, opt, b[i])
    mgr.save(3, {"params": params, "opt": opt})
    cont_p, cont_o = params, opt
    for i in range(3, 6):
        cont_p, cont_o, _ = step(cont_p, cont_o, b[i])
    # "crash" and restore
    _, tree, _ = mgr.restore({"params": params, "opt": opt})
    res_p, res_o = tree["params"], tree["opt"]
    for i in range(3, 6):
        res_p, res_o, _ = step(res_p, res_o, b[i])
    for a, c in zip(jax.tree_util.tree_leaves(res_p),
                    jax.tree_util.tree_leaves(cont_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=1e-6)
