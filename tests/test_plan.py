"""Query-plan subsystem: AST lowering, cascades, and the cost optimizer.

Two hard contracts (ISSUE 2 acceptance criteria):
1. a single-``Pred`` expression through the plan executor is bit-identical
   (mask, call count) to ``sem_filter`` under the same seed;
2. on a 3-conjunct workload the optimizer-ordered cascade spends strictly
   fewer oracle calls than naive left-to-right evaluation, pilot included.
"""
import numpy as np
import pytest

from repro.core import CSVConfig, SemanticTable, SyntheticOracle
from repro.core.csv_filter import semantic_filter
from repro.data import make_dataset
from repro.plan import (And, Not, Or, PlanExecutor, Pred, needs_ordering,
                        optimize, pilot_predicates)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("imdb_review", n=3000, seed=0)


def _oracle(ds, q, flip=0.02):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=7,
                           token_lens=ds.token_lens)


CFG = CSVConfig(n_clusters=4, xi=0.005)


# ------------------------------------------------------------------ AST
def test_operator_composition_flattens():
    a, b, c = (Pred(n, oracle=None) for n in "abc")
    expr = (a & b) & ~c
    assert isinstance(expr, And) and len(expr.children) == 3
    assert [p.name for p in expr.leaves()] == ["a", "b", "c"]
    assert expr.label == "(a AND b AND NOT c)"
    assert needs_ordering(expr)
    assert not needs_ordering(a)
    assert not needs_ordering(~a)  # Not has a unique order: no pilot needed
    with pytest.raises(TypeError):
        And(a, "not an expr")


def test_duplicate_name_with_different_oracles_rejected(ds):
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    expr = Pred("q", _oracle(ds, "RV-Q1")) & Pred("q", _oracle(ds, "RV-Q2"))
    with pytest.raises(ValueError, match="unique name"):
        PlanExecutor(table, cfg=CFG).run(expr)


# ------------------------------------------------------- bit identity
def test_single_pred_bit_identical_to_sem_filter(ds):
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    r_ref = table.sem_filter(_oracle(ds, "RV-Q1"), cfg=CFG)
    r_plan = table.sem_filter_expr(Pred("RV-Q1", _oracle(ds, "RV-Q1")),
                                   cfg=CFG)
    assert (r_ref.mask == r_plan.mask).all()
    assert r_ref.n_llm_calls == r_plan.n_llm_calls
    assert r_plan.pilot_calls == 0  # no ordering choice => no pilot spent
    assert r_plan.order == ["RV-Q1"]
    assert r_plan.results["RV-Q1"].n_input == len(ds.embeddings)


# --------------------------------------------------------- optimizer
def test_optimizer_beats_naive_three_conjuncts(ds):
    """The selective conjunct (RV-Q3, ~5%) must run first and shrink the
    later CSV runs enough to beat left-to-right even after paying for the
    pilot sample."""
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)

    def expr():
        return And(Pred("RV-Q1", _oracle(ds, "RV-Q1")),
                   Pred("RV-Q2", _oracle(ds, "RV-Q2")),
                   Pred("RV-Q3", _oracle(ds, "RV-Q3")))

    naive = PlanExecutor(table, cfg=CFG, optimize=False).run(expr())
    opt = PlanExecutor(table, cfg=CFG, optimize=True).run(expr())
    assert naive.order == ["RV-Q1", "RV-Q2", "RV-Q3"]
    assert opt.order[0] == "RV-Q3"  # most selective first
    assert opt.pilot_calls > 0
    assert opt.n_llm_calls < naive.n_llm_calls  # pilot included, strictly
    assert opt.est_calls_saved > 0
    assert opt.estimate.est_calls_ordered < opt.estimate.est_calls_naive
    # both plans agree with composing per-predicate ground truth closely
    truth = (ds.labels["RV-Q1"] & ds.labels["RV-Q2"] & ds.labels["RV-Q3"])
    assert np.mean(opt.mask == truth) > 0.9


def test_cascade_shrinks_live_sets(ds):
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    expr = And(Pred("RV-Q3", _oracle(ds, "RV-Q3")),
               Pred("RV-Q2", _oracle(ds, "RV-Q2")),
               Pred("RV-Q1", _oracle(ds, "RV-Q1")))
    r = PlanExecutor(table, cfg=CFG, optimize=False).run(expr)
    n_in = [rec.n_in for rec in r.node_log]
    assert n_in[0] == len(ds.embeddings)
    assert n_in[1] < n_in[0] and n_in[2] <= n_in[1]
    for rec in r.node_log:  # later conjuncts ran on the advertised subset
        assert rec.result.n_input == rec.n_in


def test_or_cascade_skips_accepted_tuples(ds):
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    expr = Or(Pred("RV-Q2", _oracle(ds, "RV-Q2")),
              Pred("RV-Q1", _oracle(ds, "RV-Q1")))
    r = PlanExecutor(table, cfg=CFG, optimize=False).run(expr)
    assert r.node_log[1].n_in == len(ds.embeddings) - r.node_log[0].n_out


def test_optimizer_orders_disjuncts_most_selective_last(ds):
    """OR short-circuits on True: high-selectivity disjuncts drop the most
    tuples, so the rank puts them first (cost/s ascending)."""
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    expr = Or(Pred("RV-Q3", _oracle(ds, "RV-Q3")),   # ~5% pass
              Pred("RV-Q1", _oracle(ds, "RV-Q1")))   # ~50% pass
    r = PlanExecutor(table, cfg=CFG, optimize=True).run(expr)
    assert r.order[0] == "RV-Q1"


# -------------------------------------------------- exact composition
def test_and_or_not_semantics_exact_when_exhausted():
    """n small enough that every cluster is fully sampled: CSV is exact,
    so the cascade must reproduce the boolean composition bit-for-bit."""
    ds = make_dataset("imdb_review", n=260, seed=3)
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    expr = ((Pred("q1", _oracle(ds, "RV-Q1", flip=0.0))
             & ~Pred("q2", _oracle(ds, "RV-Q2", flip=0.0)))
            | Pred("q3", _oracle(ds, "RV-Q3", flip=0.0)))
    r = PlanExecutor(table, cfg=CFG, optimize=True).run(expr)
    truth = ((ds.labels["RV-Q1"] & ~ds.labels["RV-Q2"])
             | ds.labels["RV-Q3"])
    assert (r.mask == truth).all()


# --------------------------------------------------- subset execution
def test_semantic_filter_subset_decides_only_subset(ds):
    oracle = _oracle(ds, "RV-Q1")
    subset = np.arange(0, len(ds.embeddings), 3)
    r = semantic_filter(ds.embeddings, oracle, CFG, subset_ids=subset)
    assert r.n_input == len(subset)
    outside = np.ones(len(ds.embeddings), dtype=bool)
    outside[subset] = False
    assert not r.mask[outside].any()  # mask stays False off-subset
    assert 0 < r.n_llm_calls <= len(subset)


def test_semantic_filter_empty_subset(ds):
    oracle = _oracle(ds, "RV-Q1")
    r = semantic_filter(ds.embeddings, oracle, CFG,
                        subset_ids=np.array([], dtype=np.int64))
    assert r.n_llm_calls == 0 and not r.mask.any() and r.n_input == 0


def test_subset_restricts_precomputed_assignment(ds):
    """Full-table precluster assignment + subset run must agree with
    clustering structure: every queue cluster is a subset of one full
    cluster, so per-cluster accounting still adds up."""
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    assign = table.precluster(CFG.n_clusters, CFG.seed)
    subset = np.nonzero(ds.labels["RV-Q2"])[0]
    r = semantic_filter(ds.embeddings, _oracle(ds, "RV-Q1"), CFG,
                        precomputed_assign=assign, subset_ids=subset)
    sampled_plus_voted = sum(rr.n_sampled + rr.n_voted for rr in r.round_log)
    assert sampled_plus_voted + r.n_fallback == len(subset)


def test_plan_reuses_precluster_cache(ds):
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    expr = And(Pred("RV-Q3", _oracle(ds, "RV-Q3")),
               Pred("RV-Q2", _oracle(ds, "RV-Q2")),
               Pred("RV-Q1", _oracle(ds, "RV-Q1")))
    PlanExecutor(table, cfg=CFG, optimize=True).run(expr)
    # one offline clustering serves all three cascaded predicates
    assert list(table._assign_cache) == [(CFG.n_clusters, CFG.seed)]


# ----------------------------------------------------- cost model unit
def test_pilot_and_optimize_are_deterministic(ds):
    leaves = [Pred("RV-Q1", _oracle(ds, "RV-Q1")),
              Pred("RV-Q3", _oracle(ds, "RV-Q3"))]
    rng1 = np.random.default_rng(5)
    rng2 = np.random.default_rng(5)
    live = np.arange(len(ds.embeddings))
    s1 = pilot_predicates(leaves, live, rng1, 32)
    s2 = pilot_predicates([Pred("RV-Q1", _oracle(ds, "RV-Q1")),
                           Pred("RV-Q3", _oracle(ds, "RV-Q3"))],
                          live, rng2, 32)
    assert s1["RV-Q3"].selectivity == s2["RV-Q3"].selectivity
    assert 0.0 < s1["RV-Q3"].selectivity < s1["RV-Q1"].selectivity
    est = optimize(And(*leaves), len(ds.embeddings), s1, CFG)
    assert est.order == ["RV-Q3", "RV-Q1"]
    assert est.naive_order == ["RV-Q1", "RV-Q3"]
