"""Quality & health observability (ISSUE 10 acceptance criteria).

Hard contracts:
1. auditing is observation-only: ``audit_rate=0`` (the default) is
   bit-identical to an audited run — same masks, same oracle call
   counts, same memo size — and an audited FIRST query does not perturb
   a later un-audited query (independent RNG streams);
2. audit spend is separate: fresh audit labels land under ``audit.calls``
   and never touch ``oracle.calls`` or the oracle's own stats/memo;
3. the Wilson interval covers the true accuracy on synthetic ground
   truth (flip=0 so oracle labels ARE ground truth);
4. health rules trip exactly once per breach edge and emit a recover on
   the way back;
5. the live status endpoints (/healthz, /statusz, /varz, /metrics)
   answer over real HTTP;
6. the flight recorder dumps a parseable debug bundle;
7. the Prometheus exporter writes # HELP lines, %g-formatted ``le``
   labels, and survives non-numeric gauges.
"""
import json
import urllib.request

import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.obs import (FlightRecorder, HealthMonitor, HealthRule,
                       JsonlAlertSink, MetricsRegistry, StatusHub, Tracer,
                       default_rules, registry_to_prometheus,
                       set_flight_recorder, set_monitor,
                       start_status_server, use_tracer, wilson_interval)

N = 600
POL = ExecutionPolicy(n_clusters=4, xi=0.005)


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("imdb_review", n=N, seed=0)


def _oracle(ds, q="RV-Q1", flip=0.02, seed=7):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=seed,
                           token_lens=ds.token_lens)


def _run(ds, audit_rate, flip=0.02, tracer=None):
    sess = Session(policy=POL.replace(audit_rate=audit_rate))
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    o = _oracle(ds, flip=flip)
    sess.register_oracle("q", o)
    if tracer is not None:
        with use_tracer(tracer):
            res = t.filter("q").collect()
    else:
        res = t.filter("q").collect()
    return res, o


# ------------------------------------------------------- wilson interval
def test_wilson_interval_basics():
    lo, hi = wilson_interval(90, 100)
    assert 0.0 <= lo < 0.9 < hi <= 1.0
    # degenerate inputs stay in [0, 1] and never crash
    assert wilson_interval(0, 0) == (0.0, 1.0)
    lo0, hi0 = wilson_interval(0, 50)
    assert lo0 == 0.0 and hi0 > 0.0
    loa, hia = wilson_interval(50, 50)
    assert loa < 1.0 and hia == 1.0
    # wider n -> tighter interval at the same rate
    lo_n, hi_n = wilson_interval(900, 1000)
    assert hi_n - lo_n < hi - lo


def test_wilson_interval_covers_true_accuracy(ds):
    # flip=0: the oracle IS the ground truth, so the query mask equals
    # the truth and the audited accuracy estimate must cover it
    tr = Tracer(metrics=MetricsRegistry())
    res, _ = _run(ds, audit_rate=0.4, flip=0.0, tracer=tr)
    truth = ds.labels["RV-Q1"].astype(bool)
    true_acc = float(np.mean(res.mask == truth))
    rep = res.audit_report()
    assert rep.n_audited > 0
    assert rep.accuracy_lo <= true_acc <= rep.accuracy_hi
    assert 0.0 <= rep.f1_lo <= rep.f1 <= rep.f1_hi <= 1.0
    # the report renders
    assert "accuracy" in str(rep)


# ----------------------------------------------- audit-off bit-identity
def test_audit_off_bit_identical(ds):
    res_off, o_off = _run(ds, audit_rate=0.0)
    tr = Tracer(metrics=MetricsRegistry())
    res_on, o_on = _run(ds, audit_rate=0.3, tracer=tr)
    np.testing.assert_array_equal(res_off.mask, res_on.mask)
    assert res_off.n_llm_calls == res_on.n_llm_calls
    assert o_off.stats.n_calls == o_on.stats.n_calls
    assert len(o_off._memo) == len(o_on._memo)  # audit never fills memo
    # no audit attached when off
    with pytest.raises(ValueError, match="no audit attached"):
        res_off.audit_report()


def test_audited_first_query_does_not_perturb_second(ds):
    # the audit draws labels through the oracle's RNG (flip>0) — state
    # save/restore means a LATER query sees identical flips either way
    def pair(audit_first):
        sess = Session(policy=POL)
        t = sess.table(embeddings=ds.embeddings, name="reviews")
        o1 = _oracle(ds, "RV-Q1", flip=0.05, seed=7)
        sess.register_oracle("q", o1)
        pol1 = POL.replace(audit_rate=0.3) if audit_first else POL
        if audit_first:
            tr = Tracer(metrics=MetricsRegistry())
            with use_tracer(tr):
                r1 = t.filter("q").collect(policy=pol1)
        else:
            r1 = t.filter("q").collect(policy=pol1)
        o2 = _oracle(ds, "RV-Q2", flip=0.05, seed=9)
        sess.register_oracle("q2", o2)
        r2 = t.filter("q2").collect()
        return r1, r2

    a1, a2 = pair(True)
    b1, b2 = pair(False)
    np.testing.assert_array_equal(a1.mask, b1.mask)
    np.testing.assert_array_equal(a2.mask, b2.mask)
    assert a2.n_llm_calls == b2.n_llm_calls


def test_audit_spend_separate_from_oracle(ds):
    tr = Tracer(metrics=MetricsRegistry())
    res, o = _run(ds, audit_rate=0.3, tracer=tr)
    snap = tr.metrics.snapshot()
    n_fresh = snap.get("audit.calls", 0.0)
    n_memo = snap.get("audit.cached", 0.0)
    assert n_fresh + n_memo > 0          # the audit did sample rows
    assert snap["oracle.calls"] == o.stats.n_calls  # untouched by audit
    assert snap["quality.audited_rows"] == n_fresh + n_memo
    rep = res.audit_report()
    assert rep.n_audited == n_fresh + n_memo
    assert rep.n_fresh_calls == n_fresh and rep.n_memo_hits == n_memo
    # vote-margin export rides the same traced run
    assert snap["quality.vote_margin"]["count"] > 0


# ------------------------------------------------------- health monitor
def test_alert_trips_once_per_breach_and_recovers():
    reg = MetricsRegistry()
    reg.counter("oracle.calls").inc(100)
    alerts = []
    mon = HealthMonitor(
        reg,
        rules=[HealthRule(name="too-many-calls", metric="oracle.calls",
                          threshold=150.0, op=">", severity="warning",
                          message="call budget runs hot")],
        sinks=[], min_interval_s=0.0)
    mon.add_sink(alerts.append)
    mon.evaluate()
    assert alerts == [] and mon.status()["status"] == "ok"
    reg.counter("oracle.calls").inc(100)          # 200 > 150: breach
    mon.evaluate()
    mon.evaluate()
    mon.evaluate()                                 # still breached: silent
    breaches = [a for a in alerts if a.kind == "breach"]
    assert len(breaches) == 1
    assert breaches[0].rule == "too-many-calls"
    assert mon.status()["status"] == "degraded"
    assert "too-many-calls" in mon.firing()
    reg.counter("oracle.calls").value = 10.0       # back under: recover
    mon.evaluate()
    kinds = [a.kind for a in alerts]
    assert kinds == ["breach", "recover"]
    assert mon.status()["status"] == "ok"
    reg.counter("oracle.calls").inc(500)           # re-breach: new alert
    mon.evaluate()
    assert [a.kind for a in alerts] == ["breach", "recover", "breach"]


def test_default_rules_quiet_on_empty_registry():
    mon = HealthMonitor(MetricsRegistry(), rules=default_rules(),
                        sinks=[], min_interval_s=0.0)
    mon.evaluate()                 # absent metrics never fire
    assert not any(mon.firing().values())
    assert mon.status()["status"] == "ok"


def test_jsonl_alert_sink_and_critical_hook(tmp_path):
    reg = MetricsRegistry()
    reg.set("service.tenant_budget_used_ratio", 0.95)
    crit = []
    mon = HealthMonitor(reg, rules=default_rules(), sinks=[
        JsonlAlertSink(tmp_path / "alerts.jsonl")],
        min_interval_s=0.0, on_critical=crit.append)
    mon.evaluate()
    lines = (tmp_path / "alerts.jsonl").read_text().splitlines()
    assert len(lines) == 1
    doc = json.loads(lines[0])
    assert doc["rule"] == "tenant-budget-burn"
    assert doc["severity"] == "critical" and doc["kind"] == "breach"
    assert len(crit) == 1
    assert mon.status()["status"] == "critical"


# ------------------------------------------------------ status endpoints
def test_status_endpoints_live(ds):
    reg = MetricsRegistry()
    reg.counter("oracle.calls").inc(42)
    mon = HealthMonitor(reg, rules=default_rules(), sinks=[],
                        min_interval_s=0.0)
    hub = StatusHub(monitor=mon)
    hub.add_provider("tenants", lambda: {"alice": {"budget": 100}})
    srv = start_status_server(reg, 0, hub=hub, label="test")
    host, port = srv.server_address[:2]
    base = f"http://{host}:{port}"
    try:
        def get(path, headers=None):
            req = urllib.request.Request(base + path,
                                         headers=headers or {})
            with urllib.request.urlopen(req, timeout=5) as r:
                return r.status, r.headers.get("Content-Type", ""), \
                    r.read().decode()

        code, ctype, body = get("/healthz")
        assert code == 200 and "json" in ctype
        doc = json.loads(body)
        assert doc["status"] == "ok" and doc["uptime_s"] >= 0
        code, _, body = get("/statusz")
        doc = json.loads(body)
        assert doc["tenants"] == {"alice": {"budget": 100}}
        assert "health" in doc
        _, ctype, body = get("/statusz?format=html")
        assert "html" in ctype and "<html" in body
        code, _, body = get("/varz")
        assert json.loads(body)["oracle.calls"] == 42.0
        _, _, body = get("/metrics")
        assert "oracle_calls 42" in body
        # a failing provider renders as an error section, not a 500
        hub.add_provider("boom", lambda: 1 / 0)
        _, _, body = get("/statusz")
        assert "error" in json.loads(body)["boom"]
    finally:
        srv.shutdown()


# ------------------------------------------------------- flight recorder
def test_flight_recorder_dump_parseable(ds, tmp_path):
    reg = MetricsRegistry()
    tr = Tracer(metrics=reg)
    with use_tracer(tr):
        _run(ds, audit_rate=0.0)[0]
    fr = FlightRecorder(tmp_path / "debug-bundle", tracer=tr, registry=reg)
    fr.record_delta()
    reg.counter("oracle.calls").inc(7)
    fr.record_delta()
    d = fr.dump("test-dump")
    man = json.loads((d / "manifest.json").read_text())
    assert man["reason"] == "test-dump"
    assert man["n_spans"] > 0
    metrics = json.loads((d / "metrics.json").read_text())
    assert "oracle.calls" in metrics
    spans = [json.loads(ln)
             for ln in (d / "spans.jsonl").read_text().splitlines()]
    assert spans and all("span_id" in s for s in spans)
    deltas = [json.loads(ln)
              for ln in (d / "metric_deltas.jsonl").read_text().splitlines()]
    assert any(dl["delta"].get("oracle.calls") == 7.0 for dl in deltas)


def test_flight_recorder_dumps_on_critical_alert(tmp_path):
    reg = MetricsRegistry()
    fr = FlightRecorder(tmp_path / "debug-bundle", tracer=None, registry=reg)
    set_flight_recorder(fr)
    try:
        reg.set("service.tenant_budget_used_ratio", 0.99)
        mon = HealthMonitor(reg, rules=default_rules(), sinks=[
            fr.note_alert], min_interval_s=0.0)
        mon.evaluate()
        man = json.loads(
            (tmp_path / "debug-bundle" / "manifest.json").read_text())
        assert man["reason"] == "critical-alert:tenant-budget-burn"
        assert fr.dumps == 1
    finally:
        set_flight_recorder(None)
        set_monitor(None)


# ----------------------------------------------------- exporter hardening
def test_prometheus_export_help_le_and_info():
    reg = MetricsRegistry()
    reg.counter("oracle.calls").inc(3)
    reg.histogram("round.wall_s").observe(0.5)
    reg.set_info("run.arch", "qwen1.5-0.5b")
    reg.gauge("weird.gauge").set("not-a-number")
    text = registry_to_prometheus(reg)
    assert "# HELP oracle_calls" in text
    assert "# HELP round_wall_s" in text
    # le labels are %g-formatted floats, not repr floats
    assert 'le="0.5"' in text and 'le="+Inf"' in text
    assert 'le="0.001"' in text and 'le="0.001000' not in text
    # a non-numeric gauge degrades to the info idiom instead of crashing
    assert 'weird_gauge{value="not-a-number"} 1' in text
    assert 'run_arch{value="qwen1.5-0.5b"} 1' in text
