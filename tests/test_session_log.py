"""Append-only session log (repro.service.log): durability edge cases.

Covers the WAL contracts docs/distributed.md promises: framed-record
round-trip, torn-final-record truncate-and-recover, concurrent-writer
rejection (lock file), compaction mid-stream equivalence against an
un-compacted replay, and restart cost bounded by the log tail.
"""
import os
import pathlib

import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.core.oracle import SyntheticOracle
from repro.data import make_dataset
from repro.service import SessionStore
from repro.service.log import (ConcurrentWriterError, LOG_MAGIC,
                               SessionLogStore, pack_record, read_records)

N = 900
POL = ExecutionPolicy(n_clusters=24, xi=0.01, seed=0)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("imdb_review", n=N, seed=0)


def _build(ds, extra=()):
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    sess.register_oracle("A", SyntheticOracle(
        ds.labels["RV-Q1"], flip_prob=0.02, seed=7,
        token_lens=ds.token_lens))
    for name, labels in extra:
        sess.register_oracle(name, SyntheticOracle(labels, flip_prob=0.0,
                                                   seed=11))
    return sess, t


# ----------------------------------------------------------- frame codec
def test_frame_roundtrip(tmp_path):
    p = tmp_path / "wal_000000.log"
    payloads = [{"t": "x", "i": 7, "arr": np.arange(6).reshape(2, 3)},
                {"t": "y", "s": "text", "f": 0.25, "none": None}]
    p.write_bytes(LOG_MAGIC + b"".join(pack_record(r) for r in payloads))
    records, ends, valid_end, size = read_records(p)
    assert valid_end == size == ends[-1]
    assert records[0]["i"] == 7
    assert (records[0]["arr"] == np.arange(6).reshape(2, 3)).all()
    assert records[1] == payloads[1]


def test_torn_final_record_truncate_and_recover(ds, tmp_path):
    sess, t = _build(ds)
    log = SessionLogStore(tmp_path)
    log.attach(sess)
    r1 = t.filter("A").collect()
    log.abandon()
    sess.close()

    gen = sorted(tmp_path.glob("wal_*.log"))[-1]
    intact = gen.stat().st_size
    with open(gen, "ab") as fh:            # crash mid-append: half a frame
        fh.write(pack_record({"t": "emb", "keys": [], "rows":
                              np.zeros((0, 4), np.float32)})[:9])
    sess2, t2 = _build(ds)
    log2 = SessionLogStore(tmp_path)
    rep = log2.restore(sess2)
    assert rep.torn_bytes == gen.stat().st_size - intact > 0
    log2.attach(sess2)                     # attach truncates the torn tail
    assert gen.stat().st_size == intact
    r2 = t2.filter("A").collect()
    assert (r2.mask == r1.mask).all() and r2.n_llm_calls == 0
    # recovered writer appends cleanly after the truncation point
    records, _, valid_end, size = read_records(gen)
    assert valid_end == size
    log2.close()
    sess2.close()


def test_corrupt_frame_drops_suffix(tmp_path):
    """A flipped byte mid-file: everything after the bad frame is
    unreadable by design (no resync) — replay stops at the corruption."""
    p = tmp_path / "wal_000000.log"
    recs = [{"t": "x", "i": i} for i in range(5)]
    frames = [pack_record(r) for r in recs]
    blob = bytearray(LOG_MAGIC + b"".join(frames))
    off = len(LOG_MAGIC) + len(frames[0]) + len(frames[1]) + 10
    blob[off] ^= 0xFF
    p.write_bytes(bytes(blob))
    records, _, valid_end, size = read_records(p)
    assert [r["i"] for r in records] == [0, 1]
    assert valid_end < size


# ------------------------------------------------------------------ lock
def test_concurrent_writer_rejected(ds, tmp_path):
    sess, t = _build(ds)
    log = SessionLogStore(tmp_path)
    log.attach(sess)
    with pytest.raises(ConcurrentWriterError, match="live writer"):
        SessionLogStore(tmp_path).attach(sess)
    log.close()
    sess.close()


def test_stale_lock_of_dead_pid_is_stolen(ds, tmp_path):
    # a kill -9'd writer leaves its lock behind; its pid is dead so the
    # next attach steals the lock instead of refusing forever
    (tmp_path / "wal.lock").write_text("999999999")
    sess, t = _build(ds)
    log = SessionLogStore(tmp_path)
    log.attach(sess)
    assert (tmp_path / "wal.lock").read_text() == str(os.getpid())
    log.close()
    sess.close()


# ------------------------------------------------------------ compaction
def test_compaction_mid_stream_equivalent_to_uncompacted(ds, tmp_path):
    """Same event stream, with and without a compaction in the middle:
    both restores must rebuild identical behavior (masks + zero calls)."""
    big = make_dataset("imdb_review", n=N + 100, seed=0)
    extra = [("C", big.labels["RV-Q1"]), ("D", big.labels["RV-Q3"])]

    def run(dirname, compact_mid):
        d = tmp_path / dirname
        sess, t = _build(ds, extra=extra)
        log = SessionLogStore(d)
        log.attach(sess)
        r1 = t.filter("A").collect()
        t.append(embeddings=big.embeddings[N:])      # mutation record
        r2 = t.filter("C").collect()
        if compact_mid:
            log.compact(sess)
        r3 = t.filter("D").collect()                 # tail after snapshot
        log.abandon()
        sess.close()

        sess2, t2 = _build(ds, extra=extra)          # base table only
        log2 = SessionLogStore(d)
        rep = log2.restore(sess2)
        log2.attach(sess2)
        # A's decision predates the append (its oracle only spans the base
        # rows), so only the post-append predicates re-collect here
        g2C = t2.filter("C").collect()
        g2D = t2.filter("D").collect()
        assert g2C.n_llm_calls == g2D.n_llm_calls == 0
        assert len(t2) == N + 100
        log2.close()
        sess2.close()
        return (r1.mask, r2.mask, r3.mask), (g2C.mask, g2D.mask), rep

    live_c, restored_c, rep_c = run("compacted", compact_mid=True)
    live_u, restored_u, rep_u = run("uncompacted", compact_mid=False)
    for a, b in zip(live_c, live_u):
        assert (a == b).all()              # compaction is invisible live
    for live, back in ((live_c, restored_c), (live_u, restored_u)):
        for a, b in zip(live[1:], back):
            assert (a == b).all()          # ...and across a restart
    # the compacted dir went through snapshot + carried mutations + tail;
    # the uncompacted one replayed the whole log
    assert rep_c.snapshot is not None and rep_c.n_carried_mutations == 1
    assert rep_u.snapshot is None and rep_u.n_tail_records > 0


def test_restart_cost_bounded_by_tail_not_session(ds, tmp_path):
    """After compaction the tail is empty: a restart replays ~no records
    even though the session accumulated many."""
    sess, t = _build(ds)
    log = SessionLogStore(tmp_path)
    log.attach(sess)
    t.filter("A").collect()
    pre_compact = read_records(
        sorted(tmp_path.glob("wal_*.log"))[-1])[0]
    assert len(pre_compact) > 3            # the session did accumulate
    log.compact(sess)
    log.close(compact=False)
    sess.close()

    sess2, t2 = _build(ds)
    log2 = SessionLogStore(tmp_path)
    rep = log2.restore(sess2)
    assert rep.snapshot is not None
    assert rep.n_tail_records == 0         # bounded by tail, not history
    log2.attach(sess2)
    r = t2.filter("A").collect()
    assert r.n_llm_calls == 0
    log2.close()
    sess2.close()


def test_compaction_deletes_old_generations(ds, tmp_path):
    sess, t = _build(ds)
    log = SessionLogStore(tmp_path)
    log.attach(sess)
    t.filter("A").collect()
    log.compact(sess)
    log.compact(sess)
    gens = sorted(tmp_path.glob("wal_*.log"))
    assert len(gens) == 1 and gens[0].name == "wal_000002.log"
    log.close()
    sess.close()


# ------------------------------------------- RestoreReport dropped surface
def test_snapshot_restore_surfaces_save_time_drops(ds, tmp_path):
    """Decisions of an anonymous (never-registered) oracle are dropped at
    save; the load report must say so instead of staying silent."""
    sess = Session(policy=POL)
    t = sess.table(embeddings=ds.embeddings, name="reviews")
    anon = SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.02, seed=7,
                           token_lens=ds.token_lens)
    t.filter(anon, name="q").collect()     # memoized under an id(), no name
    SessionStore(tmp_path).save(sess)
    sess.close()

    sess2 = Session(policy=POL)
    sess2.table(embeddings=ds.embeddings, name="reviews")
    rep = SessionStore(tmp_path).load(sess2)
    assert rep.dropped                      # surfaced, not discarded
    assert any("unregistered oracle" in d for d in rep.dropped)
    assert "dropped at save" in str(rep)
    sess2.close()
