"""Regression: the round-vectorized executor == the sequential driver.

The refactor's hard contract: under a fixed seed, batching every live
cluster's sample into one cross-cluster oracle call and voting all clusters
in one segmented dispatch changes NOTHING about the decisions — only the
batch sizes the serving layer sees.
"""
import numpy as np
import pytest

from repro.core import CSVConfig, SyntheticOracle, semantic_filter
from repro.core.csv_filter import RoundResult
from repro.data import make_dataset


@pytest.fixture(scope="module")
def ds():
    return make_dataset("imdb_review", n=3000, seed=0)


def _run(ds, executor, vote="uni", depth=1, xi=0.005):
    oracle = SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.02, seed=7,
                             token_lens=ds.token_lens)
    cfg = CSVConfig(n_clusters=4, xi=xi, vote=vote, executor=executor,
                    pipeline_depth=depth)
    return semantic_filter(ds.embeddings, oracle, cfg), oracle


@pytest.mark.parametrize("vote", ["uni", "sim"])
def test_round_executor_bit_identical_to_sequential(ds, vote):
    r_seq, _ = _run(ds, "sequential", vote)
    r_round, _ = _run(ds, "round", vote)
    assert (r_seq.mask == r_round.mask).all()
    assert r_seq.n_llm_calls == r_round.n_llm_calls
    assert r_seq.cluster_log == r_round.cluster_log  # per-round cluster log
    assert r_seq.n_voted == r_round.n_voted
    assert r_seq.n_fallback == r_round.n_fallback
    assert r_seq.recluster_rounds == r_round.recluster_rounds


def test_pipelined_dispatch_bit_identical(ds):
    """pipeline_depth > 1 (async double-buffered oracle) changes nothing."""
    r_seq, _ = _run(ds, "sequential")
    r_pipe, _ = _run(ds, "round", depth=3)
    assert (r_seq.mask == r_pipe.mask).all()
    assert r_seq.n_llm_calls == r_pipe.n_llm_calls
    assert r_seq.cluster_log == r_pipe.cluster_log
    waves = [r.waves for r in r_pipe.round_log]
    assert max(waves) > 1  # the round was actually split into waves


def test_round_executor_grows_oracle_batches(ds):
    """The point of the refactor: per-invocation oracle batches grow from
    ~per-cluster sample size to the cross-cluster round aggregate."""
    r_seq, o_seq = _run(ds, "sequential")
    r_round, o_round = _run(ds, "round")
    assert o_seq.stats.mean_batch_size > 0
    assert o_round.stats.mean_batch_size >= 2 * o_seq.stats.mean_batch_size
    assert len(o_round.stats.batch_sizes) < len(o_seq.stats.batch_sizes)
    # every round issued exactly one oracle submission (pipeline_depth=1)
    assert all(r.waves == 1 for r in r_round.round_log)
    assert all(isinstance(r, RoundResult) for r in r_round.round_log)


def test_round_log_accounts_for_every_tuple(ds):
    r, _ = _run(ds, "round")
    n = len(ds.embeddings)
    # each round partitions its clusters into sample + voted + undetermined;
    # undetermined feed the next round or the fallback — totals are exact
    total = sum(rr.n_sampled + rr.n_voted for rr in r.round_log)
    assert total + r.n_fallback == n
    for rr in r.round_log:
        assert rr.n_sampled == sum(rr.oracle_batches)


def test_filter_result_tokens_are_deltas(ds):
    """Reusing one oracle across predicates must not inflate token metrics."""
    oracle = SyntheticOracle(ds.labels["RV-Q1"], flip_prob=0.0, seed=7,
                             token_lens=ds.token_lens)
    cfg = CSVConfig(n_clusters=4, xi=0.005)
    r1 = semantic_filter(ds.embeddings, oracle, cfg)
    lifetime_in = oracle.stats.input_tokens
    assert r1.input_tokens == lifetime_in and r1.input_tokens > 0
    # second run on the same oracle: everything memo-cached => zero deltas
    r2 = semantic_filter(ds.embeddings, oracle, cfg)
    assert r2.n_llm_calls == 0
    assert r2.input_tokens == 0 and r2.output_tokens == 0
    assert oracle.stats.input_tokens == lifetime_in
    assert (r1.mask == r2.mask).all()
