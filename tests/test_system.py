"""End-to-end behaviour of the CSV semantic filter system (the paper's claims)."""
import numpy as np
import pytest

from repro.core import (CSVConfig, SemanticTable, SyntheticOracle, ProxyModel,
                        reference_filter)
from repro.core.operators import accuracy_f1
from repro.data import make_dataset


@pytest.fixture(scope="module")
def imdb():
    return make_dataset("imdb_review", n=6000, seed=0)


def _oracle(ds, q="RV-Q1", flip=0.02):
    return SyntheticOracle(ds.labels[q], flip_prob=flip, seed=7,
                           token_lens=ds.token_lens)


def test_csv_reduces_calls_with_comparable_accuracy(imdb):
    """Headline claim: sublinear LLM calls at near-Reference quality."""
    truth = imdb.labels["RV-Q1"]
    table = SemanticTable(texts=imdb.texts, embeddings=imdb.embeddings)
    r_ref = reference_filter(len(imdb.texts), _oracle(imdb))
    acc_ref, _ = accuracy_f1(r_ref.mask, truth)

    r = table.sem_filter(_oracle(imdb), method="csv",
                         cfg=CSVConfig(n_clusters=4, xi=0.005))
    acc, f1 = accuracy_f1(r.mask, truth)
    assert r.n_llm_calls < len(imdb.texts) / 4, r.n_llm_calls
    assert acc > acc_ref - 0.08, (acc, acc_ref)
    assert acc > 0.85


def test_simcsv_close_to_unicsv(imdb):
    truth = imdb.labels["RV-Q1"]
    table = SemanticTable(texts=imdb.texts, embeddings=imdb.embeddings)
    ru = table.sem_filter(_oracle(imdb), method="csv")
    rs = table.sem_filter(_oracle(imdb), method="csv-sim")
    au, _ = accuracy_f1(ru.mask, truth)
    as_, _ = accuracy_f1(rs.mask, truth)
    assert abs(au - as_) < 0.05


def test_all_tuples_decided(imdb):
    table = SemanticTable(texts=imdb.texts, embeddings=imdb.embeddings)
    r = table.sem_filter(_oracle(imdb), method="csv")
    assert r.mask.shape == (len(imdb.texts),)
    assert r.n_llm_calls + r.n_voted >= len(imdb.texts) * 0.99


def test_sampled_tuples_get_oracle_labels_directly(imdb):
    """Alg.1 lines 14-15: sampled tuples keep their oracle labels."""
    truth = imdb.labels["RV-Q1"]
    oracle = _oracle(imdb, flip=0.0)
    table = SemanticTable(texts=imdb.texts, embeddings=imdb.embeddings)
    r = table.sem_filter(oracle, method="csv")
    sampled = np.array(sorted(oracle.memo_snapshot().keys()))
    assert (r.mask[sampled] == truth[sampled]).all()


def test_driver_restart_uses_cache(imdb):
    """Fault tolerance: rerun with a restored memo re-issues zero calls."""
    oracle = _oracle(imdb)
    table = SemanticTable(texts=imdb.texts, embeddings=imdb.embeddings)
    r1 = table.sem_filter(oracle, method="csv")
    snap = oracle.memo_snapshot()

    oracle2 = _oracle(imdb)
    oracle2.memo_restore(snap)
    r2 = table.sem_filter(oracle2, method="csv")
    assert oracle2.stats.n_calls == 0  # everything served from the cache
    assert (r1.mask == r2.mask).all()


def test_lotus_and_bargain_linear_proxy_pass(imdb):
    """Paper §2.2: both cascades invoke the proxy O(|T|) times."""
    truth = imdb.labels["RV-Q1"]
    n = len(imdb.texts)
    table = SemanticTable(texts=imdb.texts, embeddings=imdb.embeddings)
    for method in ["lotus", "bargain"]:
        proxy = ProxyModel(truth, quality=1.2, seed=3,
                           token_lens=imdb.token_lens)
        r = table.sem_filter(_oracle(imdb), method=method, proxy=proxy)
        assert r.n_proxy_calls == n
        acc, _ = accuracy_f1(r.mask, truth)
        assert acc > 0.7


def test_low_selectivity_f1_degrades_gracefully():
    """CB-Q1 pathology: rare positives hurt F1 but accuracy stays high."""
    ds = make_dataset("codebase", n=6000, seed=1)
    truth = ds.labels["CB-Q1"]  # selectivity 0.033
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    r = table.sem_filter(_oracle(ds, q="CB-Q1"), method="csv")
    acc, f1 = accuracy_f1(r.mask, truth)
    assert acc > 0.9  # negatives dominate
    # lowering lb recovers recall at the cost of more calls (paper §4.2)
    r2 = table.sem_filter(_oracle(ds, q="CB-Q1"), method="csv",
                          cfg=CSVConfig(lb=0.01))
    _, f1_low = accuracy_f1(r2.mask, truth)
    assert r2.n_llm_calls >= r.n_llm_calls
