"""Model substrate: every layer family agrees across fwd / prefill / decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import LayerSpec, ModelConfig
from repro.models import lm

BASE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
            dtype="float32", attn_chunk_q=16, attn_chunk_kv=16, ssm_chunk=8)


def roundtrip(cfg, steps=3, **fwd_kw):
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits, _ = lm.forward(cfg, params, tokens, **fwd_kw)
    assert logits.shape == (B, S + cfg.num_prefix_embeds, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    P = cfg.num_prefix_embeds
    logits_p, cache, pos = lm.prefill(cfg, params, tokens,
                                      max_len=P + S + steps, **fwd_kw)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits),
                               rtol=3e-4, atol=3e-4)
    toks = tokens
    logits_d = None
    for i in range(steps):
        src = logits_p[:, -1] if i == 0 else logits_d
        tok = jnp.argmax(src, -1).astype(jnp.int32)
        logits_d, cache = lm.decode_step(cfg, params, cache, tok, pos)
        pos = pos + 1
        toks = jnp.concatenate([toks, tok[:, None]], axis=1)
    logits_f, _ = lm.forward(cfg, params, toks, **fwd_kw)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_f[:, -1]),
                               rtol=1e-3, atol=1e-3)


def test_dense_gqa():
    roundtrip(ModelConfig(name="t", family="dense", n_layers=4,
                          pattern=(LayerSpec(),), **BASE))


def test_qkv_bias():
    roundtrip(ModelConfig(name="t", family="dense", n_layers=2,
                          qkv_bias=True, pattern=(LayerSpec(),), **BASE))


def test_mamba():
    roundtrip(ModelConfig(name="t", family="ssm", n_layers=4,
                          pattern=(LayerSpec(kind="mamba", ffn="none"),),
                          **BASE))


def test_moe():
    roundtrip(ModelConfig(name="t", family="moe", n_layers=4, n_experts=4,
                          top_k=2, capacity_factor=8.0, moe_chunk=0,
                          pattern=(LayerSpec(ffn="moe"),), **BASE))


def test_swa_ring_cache():
    roundtrip(ModelConfig(name="t", family="dense", n_layers=4,
                          pattern=(LayerSpec(window=16),), **BASE))


def test_hybrid_superblock():
    pat = (LayerSpec(kind="mamba"), LayerSpec(kind="mamba", ffn="moe"),
           LayerSpec(kind="attn"), LayerSpec(kind="mamba", ffn="moe"))
    roundtrip(ModelConfig(name="t", family="hybrid", n_layers=8, n_experts=4,
                          top_k=2, capacity_factor=8.0, moe_chunk=0,
                          pattern=pat, **BASE))


def test_encdec():
    cfg = ModelConfig(name="t", family="audio", n_layers=2, encoder_layers=2,
                      encoder_len=12, norm_type="ln", pos_type="sinusoidal",
                      mlp_type="gelu", pattern=(LayerSpec(),), **BASE)
    enc = jax.random.normal(jax.random.key(5), (2, 12, 64), jnp.float32)
    roundtrip(cfg, enc_frames=enc)


def test_vlm_prefix():
    cfg = ModelConfig(name="t", family="vlm", n_layers=2, num_prefix_embeds=4,
                      pattern=(LayerSpec(),), **BASE)
    pre = jax.random.normal(jax.random.key(6), (2, 4, 64), jnp.float32)
    roundtrip(cfg, prefix_embeds=pre)


@pytest.mark.parametrize("impl", ["chunked", "tri"])
def test_attention_impls_match_plain(impl):
    cfg = ModelConfig(name="t", family="dense", n_layers=2,
                      pattern=(LayerSpec(),), **BASE)
    params = lm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, 256)
    ref, _ = lm.forward(cfg.replace(attn_impl="plain"), params, tokens)
    got, _ = lm.forward(cfg.replace(attn_impl=impl), params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_banded_swa_matches_plain():
    cfg = ModelConfig(name="t", family="dense", n_layers=2,
                      pattern=(LayerSpec(window=24),), **BASE)
    params = lm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, 256)
    ref, _ = lm.forward(cfg.replace(attn_impl="plain"), params, tokens)
    got, _ = lm.forward(cfg.replace(attn_impl="chunked", swa_banded=True),
                        params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_scan_vs_unrolled_layers():
    cfg = ModelConfig(name="t", family="dense", n_layers=4,
                      pattern=(LayerSpec(),), **BASE)
    params = lm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    a, _ = lm.forward(cfg, params, tokens)
    b, _ = lm.forward(cfg.replace(scan_layers=False), params, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_param_count_matches_init():
    from repro.utils.tree import tree_param_count
    cfg = ModelConfig(name="t", family="moe", n_layers=4, n_experts=4,
                      top_k=2, pattern=(LayerSpec(ffn="moe"),), **BASE)
    params = lm.init_params(cfg, jax.random.key(0))
    assert tree_param_count(params) == cfg.param_count()
