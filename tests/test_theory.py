"""Theory layer: formula shapes + empirical soundness of the vote bound."""
import math

import numpy as np
import pytest

from repro.core import theory


def test_xi_monotone_in_epsilon():
    """Larger tolerance -> smaller required sample ratio (Table 5 trend)."""
    xs = [theory.xi_for_epsilon_univote(e, sigma2=0.01) for e in
          (0.10, 0.15, 0.20, 0.25, 0.30)]
    assert all(a >= b for a, b in zip(xs, xs[1:]))
    assert all(0 < x <= 1 for x in xs)


def test_simvote_xi_at_least_univote():
    """Paper §4.5: SimVote's required xi exceeds UniVote's (looser bound)."""
    for e in (0.1, 0.2, 0.3):
        xu = theory.xi_for_epsilon_univote(e, sigma2=0.006)
        xs = theory.xi_for_epsilon_simvote(e, sigma2=0.006, v=2.0)
        assert xs >= xu


def test_epsilon_for_xi_inverts():
    for eps in (0.1, 0.2, 0.3):
        xi = theory.xi_for_epsilon_univote(eps, sigma2=0.02, l=0.9996)
        back = theory.epsilon_for_xi(xi, n=20000, sigma2=0.02, l=0.9996)
        assert back <= eps * 1.3 + 1e-6  # inverse within slack of forward


def test_bernstein_tail_decreases_with_k():
    tails = [theory.bernstein_tail(k, 10000, 0.1, 0.05) for k in
             (10, 50, 200, 1000)]
    assert all(a >= b for a, b in zip(tails, tails[1:]))


def test_vote_error_bound_form():
    assert theory.vote_error_bound(0.15, 0.85, 0.1) == pytest.approx(0.25)
    assert theory.vote_error_bound(0.15, 0.85, 0.0) == pytest.approx(0.15)


def test_empirical_bound_soundness():
    """Monte-Carlo: when the vote commits, empirical disagreement obeys
    max(lb+eps, 1-(ub-eps)) at the stated confidence (Theorem 3.3)."""
    rng = np.random.default_rng(0)
    lb, ub, eps, l = 0.15, 0.85, 0.1, 0.9996
    sigma2 = 0.25
    xi = theory.xi_for_epsilon_univote(eps, sigma2, l)
    bound = theory.vote_error_bound(lb, ub, eps)
    violations = trials = 0
    for _ in range(300):
        n = 5000
        mu = rng.choice([0.03, 0.5, 0.95])
        x = rng.random(n) < mu
        k = max(10, int(xi * n))
        sample = rng.choice(n, size=k, replace=False)
        score = x[sample].mean()
        if score >= ub:
            err = 1 - x.mean()
        elif score <= lb:
            err = x.mean()
        else:
            continue  # vote did not commit
        trials += 1
        if err > bound:
            violations += 1
    assert trials > 50
    assert violations / trials < 0.05  # failure prob is ~2*l^n << 5%


def test_choose_sample_size():
    assert theory.choose_sample_size(10000, 0.005, 101) == 101
    assert theory.choose_sample_size(100000, 0.005, 101) == 500
    assert theory.choose_sample_size(50, 0.005, 101) == 50
