"""Standing semantic queries over live streams (ISSUE 8 acceptance).

Hard contracts:
1. micro-batch ingestion: ``coalescing_appends()`` produces masks and
   call counts bit-identical to the per-append path while paying ONE
   version bump / dirty-set union per batch;
2. an idle ``QueryScheduler`` performs no dispatch work (the loop parks
   on its condition; ``stats.n_dispatch_ticks`` stays 0);
3. per-source rate budgets DEFER over-quota rows to later ticks, never
   drop them;
4. the delta engine notifies exactly the newly-matching rows once per
   (query, content) — duplicates and True->False->True flips of equal
   content are deduped, sink failures retry then dead-letter without
   re-notification;
5. graceful shutdown (in-process handler trigger) runs each cleanup
   exactly once and leaves a restorable checkpoint + flushed sinks;
6. a watcher killed after tick k and restored replays at ~0 oracle
   calls and notifies ticks k+1..n exactly as an unkilled control — zero
   duplicate notifications across the kill/restart;
7. per-tick oracle cost is sublinear vs re-filtering the whole table
   each tick, and the stream/sink/session counters surface under the
   unified metric names.
"""
import signal
import time

import numpy as np
import pytest

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.obs import MetricsRegistry, Tracer, use_tracer
from repro.service import SessionStore
from repro.service.lifecycle import GracefulShutdown
from repro.stream import (CallbackSink, DeltaTracker, JsonlSink, RateBudget,
                          SinkRunner, StreamWatcher, SyntheticSource)

N = 600
POL = ExecutionPolicy(n_clusters=4, xi=0.005)


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("imdb_review", n=N, seed=0)


def _blobs(n_per=150, k=4, seed=0):
    """k well-separated clusters: k-means recovers them exactly, so the
    dirty-cluster arithmetic is deterministic (same as test_service)."""
    rng = np.random.default_rng(seed)
    centers = np.eye(k, k, dtype=np.float32) * 10.0
    emb = np.concatenate([
        centers[i] + rng.normal(0, 0.5, (n_per, k)).astype(np.float32)
        for i in range(k)])
    labels = np.concatenate([np.full(n_per, bool(i % 2 == 0))
                             for i in range(k)])
    return centers, emb, labels


def _watcher(ds, state_dir, n_queries=2, arrive=60, quota=60,
             checkpoint_every=None, use_scheduler=True):
    """Session + watcher over one deterministic synthetic stream, with
    CallbackSinks collecting events per query."""
    sess = Session(policy=POL)
    keys = ["RV-Q1", "RV-Q2", "RV-Q3"]
    for i in range(n_queries):
        sess.register_oracle(f"p{i}", SyntheticOracle(
            ds.labels[keys[i % 3]], flip_prob=0.0, seed=7 + i,
            token_lens=ds.token_lens))
    store = SessionStore(state_dir) if state_dir is not None else None
    w = StreamWatcher(sess, table_name="feed", store=store,
                      checkpoint_every=checkpoint_every,
                      use_scheduler=use_scheduler)
    w.add_source(SyntheticSource("s0", texts=list(ds.texts),
                                 embeddings=ds.embeddings,
                                 arrive_per_tick=arrive, seed=3),
                 RateBudget(rows_per_tick=quota))
    events = {}
    for i in range(n_queries):
        lst = events.setdefault(f"p{i}", [])
        w.register(f"p{i}", sink=CallbackSink(
            (lambda L: lambda ev: L.append(ev))(lst)))
    return sess, w, events


# ------------------------------------------- 1. coalesced micro-batches
def test_coalesced_appends_bit_identical_to_per_append():
    centers, emb, labels = _blobs()
    rng = np.random.default_rng(9)
    chunks = [centers[i % 2] + rng.normal(0, 0.5, (15, 4)).astype(np.float32)
              for i in range(4)]
    post_labels = np.concatenate([labels, np.full(60, True)])

    def build():
        s = Session(policy=POL)
        t = s.table(embeddings=emb, name="blobs")
        s.register_oracle("P", SyntheticOracle(post_labels, flip_prob=0.0,
                                               seed=7))
        return s, t

    s1, t1 = build()
    t1.filter("P").collect()
    for c in chunks:
        t1.append(embeddings=c)          # 4 bumps, 4 dirty unions
    r1 = t1.filter("P").collect()

    s2, t2 = build()
    t2.filter("P").collect()
    v0 = t2.version
    with t2.coalescing_appends():
        for c in chunks:
            t2.append(embeddings=c)
        assert len(t2) == len(emb)       # reads see the pre-append table
    assert t2.version == v0 + 1          # ONE bump for the whole batch
    assert t1.version == v0 + 4
    r2 = t2.filter("P").collect()

    assert (r1.mask == r2.mask).all()
    assert r1.n_llm_calls == r2.n_llm_calls
    assert r1.pilot_calls == r2.pilot_calls
    assert r1.n_replayed == r2.n_replayed
    # identical patched assignments and dirty unions (modulo version
    # numbering: both paths leave exactly clusters 0 and 1 dirty)
    a1 = s1._assign_cache[("blobs", 4, POL.seed)]
    a2 = s2._assign_cache[("blobs", 4, POL.seed)]
    assert (a1 == a2).all()
    d1 = t1._dirty[(4, POL.seed)]
    d2 = t2._dirty[(4, POL.seed)]
    assert ((d1 > 0) == (d2 > 0)).all() and (d2 > 0).sum() == 2


def test_coalescing_nested_and_empty_blocks():
    _, emb, _ = _blobs(n_per=40)
    s = Session(policy=POL)
    t = s.table(embeddings=emb, name="b")
    v0 = t.version
    with t.coalescing_appends():
        pass                              # empty: no version bump
    assert t.version == v0
    with t.coalescing_appends():
        t.append(embeddings=emb[:3])
        with t.coalescing_appends():      # nested: outermost owns flush
            t.append(embeddings=emb[3:5])
        assert len(t) == len(emb)
    assert t.version == v0 + 1 and len(t) == len(emb) + 5


# ------------------------------------------------- 2. idle scheduler
def test_idle_scheduler_performs_no_dispatch_work(ds):
    sess = Session(policy=POL)
    sch = sess.scheduler
    assert sch.idle.wait(2.0)
    # poke the condition: spurious wakeups must not tick the dispatcher
    for _ in range(5):
        with sch._cv:
            sch._cv.notify_all()
    time.sleep(0.1)
    assert sch.stats.n_dispatch_ticks == 0
    assert sch.idle.is_set()

    t = sess.table(embeddings=ds.embeddings, name="r")
    tk = sess.submit(t.filter(SyntheticOracle(
        ds.labels["RV-Q1"], flip_prob=0.0, seed=7), name="A"))
    assert tk.result().mask.sum() > 0
    ticks_busy = sch.stats.n_dispatch_ticks
    assert ticks_busy > 0
    # drains back to idle and stays there with zero further dispatch work
    assert sch.idle.wait(5.0)
    time.sleep(0.1)
    assert sch.stats.n_dispatch_ticks == ticks_busy
    assert sch.stats.metrics_view()["service.dispatch_ticks"] == ticks_busy
    sess.close()


# ------------------------------------------------- 3. quota deferral
def test_quota_defers_rows_without_dropping(ds):
    sess, w, events = _watcher(ds, None, n_queries=1, arrive=90, quota=40)
    summaries = w.run()
    # arrivals outrun the quota: some ticks must carry a backlog, yet
    # every row is eventually ingested in arrival order
    assert max(s["backlog"] for s in summaries) > 0
    assert all(s["rows"] <= 40 for s in summaries)
    assert w.stats.n_rows_ingested == N and w.drained
    assert len(w.handle) == N
    src = w._sources[0][0]
    assert src.state()["ingested"] == N
    # more ticks than the no-quota schedule would need
    assert w.stats.n_ticks > N / 90
    sess.close()


# ------------------------------------------------- 4. delta + sinks
def test_delta_tracker_newly_matching_and_content_dedup():
    d = DeltaTracker()
    keys = [f"k{i}" for i in range(6)]
    emit, dd = d.delta(np.array([1, 0, 1, 0, 0, 0], bool), keys)
    assert emit == [0, 2] and dd == 0
    d.ack(np.array([1, 0, 1, 0, 0, 0], bool))
    # row 2 flips off, row 3 turns on; rows 0/2 already acked -> silent
    emit, dd = d.delta(np.array([1, 0, 0, 1, 0, 0], bool), keys)
    assert emit == [3] and dd == 0
    d.ack(np.array([1, 0, 0, 1, 0, 0], bool))
    # row 2 flips back on (same content): positional diff finds it,
    # content dedup suppresses it; row 4 duplicates row 0's content
    keys[4] = keys[0]
    emit, dd = d.delta(np.array([1, 0, 1, 1, 1, 0], bool), keys)
    assert emit == [] and dd == 2
    # append-only guard
    with pytest.raises(ValueError):
        d.delta(np.zeros(3, bool), keys[:3])


def test_sink_retry_then_dead_letter(tmp_path):
    calls = {"n": 0}
    delivered = []

    def flaky(ev):
        if ev["row"] == 13:
            raise IOError("wedged")      # poison row: never succeeds
        calls["n"] += 1
        if ev["row"] == 7 and calls["n"] == 1:
            raise IOError("transient")   # first attempt fails, retry wins
        delivered.append(ev)

    runner = SinkRunner(CallbackSink(flaky), retries=2,
                        dead_letter_path=tmp_path / "dead.jsonl")
    assert runner.deliver({"query": "q", "row": 7})
    assert not runner.deliver({"query": "q", "row": 13})
    assert runner.deliver({"query": "q", "row": 21})
    st = runner.stats
    assert st.n_delivered == 2 and st.n_dead_lettered == 1
    assert st.n_retries >= 1
    assert [e["row"] for e in delivered] == [7, 21]
    assert runner.dead_letters[0]["row"] == 13
    assert "OSError" in runner.dead_letters[0]["error"]
    assert (tmp_path / "dead.jsonl").read_text().count("\n") == 1


def test_dead_lettered_row_not_renotified(ds, tmp_path):
    # a sink that always fails: every newly-matching row dead-letters,
    # and later ticks never re-emit it (the delta engine acks regardless)
    sess, w, _ = _watcher(ds, tmp_path, n_queries=1, arrive=100, quota=100)
    sq = w.queries["p0"]
    sq.runner = SinkRunner(CallbackSink(
        lambda ev: (_ for _ in ()).throw(IOError("down"))), retries=0)
    w.run(n_ticks=3)
    dead = sq.runner.stats.n_dead_lettered
    assert dead > 0 and sq.runner.stats.n_delivered == 0
    rows = [d["row"] for d in sq.runner.dead_letters]
    assert len(rows) == len(set(rows))   # each row dead-lettered once
    sess.close()


# ------------------------------------------------- 5. graceful shutdown
def test_graceful_shutdown_runs_cleanups_once():
    ran = []
    gs = GracefulShutdown(exit_on_signal=False).install()
    gs.register("a", lambda: ran.append("a"))
    gs.register("boom", lambda: (_ for _ in ()).throw(RuntimeError("x")))
    gs.register("b", lambda: ran.append("b"))
    assert not gs.requested
    gs.trigger(signal.SIGTERM)           # in-process handler invocation
    assert gs.requested and gs.signum == signal.SIGTERM
    gs.trigger(signal.SIGTERM)           # second signal: no re-run
    gs.close()                           # normal exit: no re-run either
    assert ran == ["a", "b"]             # failing cleanup didn't block b


def test_graceful_shutdown_exit_mode_raises_systemexit():
    ran = []
    gs = GracefulShutdown(exit_on_signal=True)
    gs.register("ckpt", lambda: ran.append(1))
    with pytest.raises(SystemExit) as exc:
        gs._handler(signal.SIGINT, None)
    assert exc.value.code == 128 + signal.SIGINT
    assert ran == [1]


def test_watcher_shutdown_checkpoints_and_flushes_sinks(ds, tmp_path):
    sess, w, _ = _watcher(ds, tmp_path, n_queries=1, arrive=80, quota=80)
    sink_path = tmp_path / "out.jsonl"
    sq = w.queries["p0"]
    sq.runner = SinkRunner(JsonlSink(sink_path), retries=0)
    w.run(n_ticks=2)
    gs = GracefulShutdown(exit_on_signal=False).install()
    gs.register("watch-shutdown", w.shutdown)
    gs.trigger(signal.SIGINT)
    gs.close()
    assert w.has_checkpoint()
    # the flushed sink file holds exactly the delivered notifications
    lines = sink_path.read_text().strip().splitlines()
    assert len(lines) == sq.runner.stats.n_delivered > 0
    sess.close()


# ---------------------------------------- 6. kill/restart mid-stream
def test_midstream_reload_matches_unkilled_control(ds, tmp_path):
    # control: full run, never killed
    sess_c, w_c, ev_c = _watcher(ds, tmp_path / "ctl", arrive=60, quota=60)
    ticks_c = w_c.run()
    sess_c.close()

    # kill after tick k (shutdown path = final checkpoint + flush)
    k = 4
    sess_a, w_a, ev_a = _watcher(ds, tmp_path / "run", arrive=60, quota=60)
    for _ in range(k):
        w_a.tick()
    w_a.shutdown()
    sess_a.close()

    # restart: fresh session/watcher over the same stream + oracles
    sess_b, w_b, ev_b = _watcher(ds, tmp_path / "run", arrive=60, quota=60)
    assert w_b.has_checkpoint()
    report = w_b.restore()
    assert report.tables == ["feed"] and not report.skipped
    # rebuild itself costs ~0 oracle calls (ingestion replay + memo load)
    assert sess_b.stats.n_calls == 0
    assert w_b.stats.n_ticks == k
    ticks_b = w_b.run()
    sess_b.close()

    # ticks k+1..n notify exactly the control's rows, zero duplicates
    # across the kill/restart (per query, by row AND by content key)
    for q in ev_c:
        ctl_tail = [(e["tick"], e["row"]) for e in ev_c[q] if e["tick"] > k]
        got_tail = [(e["tick"], e["row"]) for e in ev_b[q]]
        assert got_tail == ctl_tail
        all_keys = [e["key"] for e in ev_a[q]] + [e["key"] for e in ev_b[q]]
        assert len(all_keys) == len(set(all_keys))
        assert sorted(all_keys) == sorted(e["key"] for e in ev_c[q])
    # and the tail's oracle spend matches the unkilled control's exactly
    assert ([t["oracle_calls"] for t in ticks_b]
            == [t["oracle_calls"] for t in ticks_c[k:]])


# ------------------------------- 7. sublinear cost + unified metrics
def test_per_tick_cost_sublinear_vs_full_refilter(ds):
    sess, w, _ = _watcher(ds, None, n_queries=1, arrive=60, quota=60)
    summaries = w.run()
    inc_calls = [s["oracle_calls"] for s in summaries]
    sess.close()

    # control: re-filter the whole table from scratch every tick
    full_calls = []
    for t in range(1, len(summaries) + 1):
        n_t = min(N, 60 * t)
        s = Session(policy=POL)
        s.register_oracle("p0", SyntheticOracle(
            ds.labels["RV-Q1"], flip_prob=0.0, seed=7,
            token_lens=ds.token_lens))
        h = s.table(texts=list(ds.texts[:n_t]),
                    embeddings=ds.embeddings[:n_t], name="feed")
        full_calls.append(h.filter("p0").collect().n_llm_calls)

    assert sum(inc_calls) < 0.5 * sum(full_calls)
    # steady state: a tick pays for its own rows, not the table
    assert all(c <= 60 for c in inc_calls[1:])
    assert full_calls[-1] > 3 * inc_calls[-1]


def test_stream_metrics_under_unified_names(ds, tmp_path):
    tr = Tracer(metrics=MetricsRegistry())
    with use_tracer(tr):
        sess, w, ev = _watcher(ds, tmp_path, n_queries=1, arrive=80,
                               quota=80)
        w.run(n_ticks=3)
        sess.close()
    snap = tr.metrics.snapshot()
    assert snap["stream.ticks"] == 3
    assert snap["stream.rows_ingested"] == w.stats.n_rows_ingested
    # tick 1 creates the table; ticks 2..3 append through the handle
    assert snap["session.append_rows"] == w.stats.n_rows_ingested - 80
    assert snap["sink.delivered"] == len(ev["p0"])
    # stream_tick spans wrap each tick
    assert sum(1 for s in tr.spans() if s.kind == "stream_tick") == 3
    # sync_from(watcher) carries the same totals into an exportable dump
    reg = MetricsRegistry()
    reg.sync_from(w)
    out = reg.snapshot()
    assert out["stream.notifications"] == w.stats.n_notifications
    assert out["sink.delivered"] == len(ev["p0"])
    assert out["sink.dead_lettered"] == 0


def test_memo_dirty_clusters_metric():
    # an append touching ONE of four well-separated clusters re-votes that
    # cluster only, and the partial-replay path reports it under the
    # unified name
    centers, emb, labels = _blobs()
    post = np.concatenate([labels, np.full(10, True)])
    tr = Tracer(metrics=MetricsRegistry())
    with use_tracer(tr):
        s = Session(policy=POL)
        t = s.table(embeddings=emb, name="b")
        s.register_oracle("P", SyntheticOracle(post, flip_prob=0.0, seed=7))
        t.filter("P").collect()
        rng = np.random.default_rng(3)
        t.append(embeddings=(centers[0]
                             + rng.normal(0, 0.5, (10, 4))).astype(np.float32))
        r = t.filter("P").collect()
    assert tr.metrics.snapshot()["memo.dirty_clusters"] == 1
    assert r.n_replayed > 0
