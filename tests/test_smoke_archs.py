"""Per-architecture smoke tests: reduced same-family config, one forward and
one train step on CPU; asserts output shapes and no NaNs (assignment f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config, list_archs
from repro.models import lm
from repro.train import OptConfig, adamw_init, make_train_step

ASSIGNED = ["falcon-mamba-7b", "mixtral-8x22b", "dbrx-132b", "internvl2-26b",
            "gemma3-12b", "stablelm-12b", "codeqwen1.5-7b", "qwen1.5-0.5b",
            "jamba-v0.1-52b", "whisper-base"]


def _batch_for(cfg, B=2, S=32, key=0):
    k = jax.random.key(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            k, (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            k, (B, cfg.encoder_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    logits, aux = lm.forward(cfg, params, batch["tokens"],
                             prefix_embeds=batch.get("prefix_embeds"),
                             enc_frames=batch.get("enc_frames"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S + cfg.num_prefix_embeds, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), arch
    assert not bool(jnp.isnan(aux)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params, oc)
    step = make_train_step(cfg, oc)
    batch = _batch_for(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # parameters actually moved
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1)), arch


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    expect = {
        "falcon-mamba-7b": (64, 4096, 65024),
        "mixtral-8x22b": (56, 6144, 32768),
        "dbrx-132b": (40, 6144, 100352),
        "internvl2-26b": (48, 6144, 92553),
        "gemma3-12b": (48, 3840, 262144),
        "stablelm-12b": (40, 5120, 100352),
        "codeqwen1.5-7b": (32, 4096, 92416),
        "qwen1.5-0.5b": (24, 1024, 151936),
        "jamba-v0.1-52b": (32, 4096, 65536),
        "whisper-base": (6, 512, 51865),
    }
    for arch, (L, D, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == D and cfg.vocab_size == V


def test_family_features():
    assert all(s.kind == "mamba" for s in get_config("falcon-mamba-7b").pattern)
    assert get_config("mixtral-8x22b").pattern[0].window == 4096
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    jam = get_config("jamba-v0.1-52b")
    kinds = [s.kind for s in jam.pattern]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum(s.ffn == "moe" for s in jam.pattern) == 4
    gem = get_config("gemma3-12b")
    wins = [s.window for s in gem.pattern]
    assert wins.count(1024) == 5 and wins.count(None) == 1
    assert gem.resolved_head_dim == 256
    assert get_config("codeqwen1.5-7b").qkv_bias
    assert get_config("whisper-base").is_encdec
