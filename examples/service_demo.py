"""Service demo: N concurrent mixed queries + a restartable session.

    PYTHONPATH=src python examples/service_demo.py

Drives the concurrent semantic-filter service (repro.service) end to end:
one multi-tenant ``FilterService`` over a Session, six mixed queries —
single filters, an expression cascade, a negation, a semantic join, and a
replay — submitted concurrently so their per-round oracle batches merge
into cross-query dispatches; then the session is checkpointed to disk,
rebuilt "in a new process", and every query replays at zero oracle calls.
Asserts the ISSUE-5 contracts inline (bit-identity to serial collects,
>= 1.5x merged batch size, 0-call reload replay) so CI smoke catches
regressions.
"""
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset
from repro.service import FilterService, TenantBudgetError

POL = ExecutionPolicy(n_clusters=4, xi=0.005)
N = 3000


def build_session(ds, dl, dr, pair_truth):
    """Session + registered tables/oracles (durable names -> restartable)."""
    sess = Session(policy=POL)
    sess.table(embeddings=ds.embeddings, name="reviews")
    sess.table(embeddings=dl.embeddings, name="L")
    sess.table(embeddings=dr.embeddings, name="R")
    # one oracle per predicate: concurrent queries over DISTINCT predicates
    # run fully overlapped; queries sharing a predicate (the replay below)
    # are conflict-serialized by the scheduler
    for name, q, seed in [("positive", "RV-Q1", 7), ("acting", "RV-Q3", 8),
                          ("plot", "RV-Q2", 9), ("long", "RV-Q1", 11),
                          ("noir", "RV-Q3", 12)]:
        sess.register_oracle(name, SyntheticOracle(
            ds.labels[q], flip_prob=0.02, seed=seed,
            token_lens=ds.token_lens))
    sess.register_oracle("same_topic", SyntheticOracle(
        pair_truth.ravel(), flip_prob=0.0, seed=3))
    return sess


ORACLES = ("positive", "acting", "plot", "long", "noir", "same_topic")


def workload(sess):
    t, tl, tr = sess["reviews"], sess["L"], sess["R"]
    return [
        ("filter positive", t.filter("positive")),
        ("filter acting", t.filter("acting")),
        ("cascade plot&long", t.filter("plot") & t.filter("long")),
        ("negation ~noir", ~t.filter("noir")),
        ("join L x R", tl.join(tr, sess.oracle("same_topic"))),
        ("replay positive", t.filter("positive")),   # conflict-serialized
    ]


def main():
    print("== concurrent semantic-filter service demo (repro.service) ==")
    ds = make_dataset("imdb_review", n=N, seed=0)
    dl = make_dataset("imdb_review", n=120, seed=1, n_topics=4)
    dr = make_dataset("imdb_review", n=90, seed=2, n_topics=4)
    pair_truth = (dl.topics[:, None] % 2) == (dr.topics[None, :] % 2)

    # ---- serial control: same queries, fresh session, one at a time ----
    serial_sess = build_session(ds, dl, dr, pair_truth)
    serial = [(label, q.collect()) for label, q in workload(serial_sess)]
    serial_batches = [b for name in ORACLES
                      for b in serial_sess.oracle(name).stats.batch_sizes]

    # ---- concurrent service: submit all six, gather once ----
    sess = build_session(ds, dl, dr, pair_truth)
    service = FilterService(sess)
    service.register_tenant("demo", POL.replace(max_oracle_calls=100_000))
    service.register_tenant("capped", POL.replace(max_oracle_calls=10))
    try:
        service.submit("capped", sess["reviews"].filter("positive"))
        raise AssertionError("capped tenant must be rejected")
    except TenantBudgetError as e:
        print(f"admission control: {e}")

    t0 = time.time()
    with sess.scheduler.holding():   # merge from the very first round
        tickets = [service.submit("demo", q, label=label)
                   for label, q in workload(sess)]
    results = service.gather(*tickets)
    conc_wall = time.time() - t0

    print(f"\n{'query':<20s} {'serial':>8s} {'service':>8s}  mask")
    for (label, rs), rc in zip(serial, results):
        same = ((rc.mask == rs.mask).all() if rs.mask is not None
                else (rc.pair_mask == rs.pair_mask).all())
        print(f"{label:<20s} {rs.n_llm_calls:>8d} {rc.n_llm_calls:>8d}  "
              f"{'identical' if same else 'DIFFERENT'}")
        assert same and rc.n_llm_calls == rs.n_llm_calls, label
    assert results[-1].n_llm_calls == 0, "resubmitted query must replay"

    merge = sess.scheduler.stats.merge
    ratio = merge.mean_batch_size / np.mean(serial_batches)
    print(f"\ncross-query batching: {merge.n_invocations} merged "
          f"dispatches, mean {merge.mean_batch_size:.0f} ids/invocation "
          f"vs {np.mean(serial_batches):.0f} serial "
          f"({ratio:.2f}x, merge factor {merge.merge_factor:.1f}); "
          f"gather wall {conc_wall:.2f}s")
    assert ratio >= 1.5, f"batching ratio {ratio:.2f} below 1.5x"
    acct = service.tenant("demo")
    print(f"tenant 'demo': spent {acct.spent} of {acct.budget} "
          f"({acct.n_admitted} queries)")

    # ---- restartable session: checkpoint, rebuild, 0-call replay ----
    with tempfile.TemporaryDirectory() as tmp:
        svc2 = FilterService(sess, store_dir=tmp)
        path = svc2.checkpoint()
        print(f"\ncheckpointed session state to {path.name}/")

        fresh = build_session(ds, dl, dr, pair_truth)  # "new process"
        restored = FilterService(fresh, store_dir=tmp)
        print(f"restore: {restored.restore()}")
        # 1000 calls: far below the cold run's worst case — replayable
        # leaves are budgeted at ~0, only the cascade's subset-restricted
        # second leaf (no full-table decision memo) reserves its estimate
        restored.register_tenant("demo", POL.replace(max_oracle_calls=1000))
        with fresh.scheduler.holding():
            tks = [restored.submit("demo", q, label=label)
                   for label, q in workload(fresh)]
        replays = restored.gather(*tks)
        total = sum(r.n_llm_calls for r in replays)
        for (label, rs), rr in zip(serial, replays):
            same = ((rr.mask == rs.mask).all() if rs.mask is not None
                    else (rr.pair_mask == rs.pair_mask).all())
            assert same and rr.n_llm_calls == 0, label
        print(f"reloaded session replayed all {len(replays)} queries at "
              f"{total} oracle calls (bit-identical; fits a 1000-call "
              "budget the 5000+-call cold run would blow)")
        restored.close()
    service.close()
    print("\nservice demo OK")


if __name__ == "__main__":
    main()
