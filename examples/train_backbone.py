"""End-to-end training driver: train a ~100M-param qwen-family model for a
few hundred steps on synthetic text with checkpoint/restart.

    PYTHONPATH=src python examples/train_backbone.py [--steps 300] [--dim small]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import make_dataset
from repro.data.loader import PackedLoader
from repro.data.tokenizer import HashTokenizer
from repro.models import lm
from repro.train import OptConfig, adamw_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--size", default="100m", choices=["tiny", "100m"])
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    base = get_config("qwen1.5-0.5b")
    if args.size == "100m":
        cfg = base.replace(n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
                           d_ff=2048, vocab_size=32768, dtype="float32")
    else:
        cfg = base.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                           d_ff=256, vocab_size=4096, dtype="float32")
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    tok = HashTokenizer(cfg.vocab_size)
    ds = make_dataset("imdb_review", n=3000, seed=0)
    docs = [tok.encode(t) for t in ds.texts]
    B, S = (8, 128) if args.size == "100m" else (4, 64)
    loader = PackedLoader(docs, batch=B, seq=S, seed=0)

    oc = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, oc))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    params = lm.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, oc)
    start = 0
    restored = mgr.restore({"params": params, "opt": opt})
    if restored[0] is not None:
        start, tree, _ = restored
        params, opt = tree["params"], tree["opt"]
        print(f"restored from checkpoint @ step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = loader.batch_at(step)
        params, opt, m = step_fn(params, opt,
                                 {k: jax.numpy.asarray(v)
                                  for k, v in batch.items()})
        if step % 20 == 0 or step == args.steps - 1:
            tput = B * S * (step - start + 1) / (time.time() - t0)
            print(f"step {step:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"lr={float(m['lr']):.2e} tok/s={tput:,.0f}")
        if step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt}, async_=True)
    mgr.wait()
    mgr.save(args.steps, {"params": params, "opt": opt})
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
