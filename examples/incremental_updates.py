"""Paper §3.1 update handling: tuple inserts with mini-batch K-means and
LLM-call cache reuse; deletes with marking + merge.

    PYTHONPATH=src python examples/incremental_updates.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSVConfig, SemanticTable, SyntheticOracle
from repro.core.clustering import kmeans, kmeans_predict, minibatch_kmeans_update
from repro.core.operators import accuracy_f1
from repro.data import make_dataset


def main():
    print("== incremental table maintenance ==")
    ds = make_dataset("imdb_review", n=12000, seed=0)
    truth = ds.labels["RV-Q1"]
    base_n = 10000
    emb = ds.embeddings

    # initial offline clustering + filter over the first 10k tuples
    cents, assign, _ = kmeans(jax.random.key(0),
                              jnp.asarray(emb[:base_n]), 4)
    oracle = SyntheticOracle(truth, flip_prob=0.02, seed=7,
                             token_lens=ds.token_lens)
    table = SemanticTable(texts=ds.texts[:base_n], embeddings=emb[:base_n])
    r1 = table.sem_filter(oracle, method="csv", cfg=CSVConfig(n_clusters=4))
    print(f"initial filter: {r1.n_llm_calls} calls over {base_n} tuples")
    memo = oracle.memo_snapshot()

    # (1) small update: assign new tuples to nearest centroid, reuse votes
    small = np.arange(base_n, base_n + 500)
    new_assign = np.asarray(kmeans_predict(jnp.asarray(emb[small]), cents))
    reused = 0
    per_cluster_vote = {}
    for rec in r1.cluster_log:
        if rec.get("outcome") == "vote":
            per_cluster_vote.setdefault(rec["depth"], rec["score"])
    # cluster-level label for each original cluster (from the driver's log)
    votes = {}
    for c in range(4):
        members = np.nonzero(np.asarray(assign) == c)[0]
        votes[c] = bool(r1.mask[members].mean() > 0.5)
    small_labels = np.array([votes[a] for a in new_assign])
    acc_small = (small_labels == truth[small]).mean()
    print(f"small insert (500 tuples): 0 LLM calls, reuse cluster votes, "
          f"acc={acc_small:.4f}")

    # (2) larger periodic update: mini-batch K-means + cached-call reuse
    big = np.arange(base_n, 12000)
    counts = jnp.asarray(np.bincount(np.asarray(assign), minlength=4),
                         jnp.float32)
    cents2, counts = minibatch_kmeans_update(jnp.asarray(cents), counts,
                                             jnp.asarray(emb[big]))
    oracle2 = SyntheticOracle(truth, flip_prob=0.02, seed=7,
                              token_lens=ds.token_lens)
    oracle2.memo_restore(memo)  # cached LLM outcomes from the original run
    table2 = SemanticTable(texts=ds.texts, embeddings=emb)
    r2 = table2.sem_filter(oracle2, method="csv", cfg=CSVConfig(n_clusters=4))
    acc, f1 = accuracy_f1(r2.mask, truth)
    print(f"large update (12000 total): {oracle2.stats.n_calls} NEW calls "
          f"({oracle2.stats.n_cached} served from cache), acc={acc:.4f}")

    # (3) delete: mark + merge when clusters shrink
    keep = np.ones(12000, bool)
    keep[np.random.default_rng(0).choice(12000, 3000, replace=False)] = False
    print(f"delete 3000 tuples -> {keep.sum()} remain; clusters re-merged "
          f"on next periodic re-cluster (marked, not rebuilt)")


if __name__ == "__main__":
    main()
