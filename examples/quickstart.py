"""Quickstart: CSV semantic filter end-to-end on a synthetic table.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import CSVConfig, SemanticTable, SyntheticOracle, reference_filter
from repro.core.operators import accuracy_f1
from repro.data import make_dataset


def main():
    print("== CSV semantic filter quickstart ==")
    ds = make_dataset("imdb_review", n=10000, seed=0)
    truth = ds.labels["RV-Q1"]
    table = SemanticTable(texts=ds.texts, embeddings=ds.embeddings)
    print(f"table: {len(table)} tuples; predicate: 'the review is positive' "
          f"(selectivity {truth.mean():.2f})")

    oracle = SyntheticOracle(truth, flip_prob=0.02, seed=7,
                             token_lens=ds.token_lens)
    ref = reference_filter(len(table), oracle)
    acc, f1 = accuracy_f1(ref.mask, truth)
    print(f"\nReference (linear scan): {ref.n_oracle_calls} LLM calls, "
          f"acc={acc:.4f} f1={f1:.4f}")

    for method in ["csv", "csv-sim"]:
        oracle = SyntheticOracle(truth, flip_prob=0.02, seed=7,
                                 token_lens=ds.token_lens)
        r = table.sem_filter(oracle, method=method,
                             cfg=CSVConfig(n_clusters=4, xi=0.005))
        acc, f1 = accuracy_f1(r.mask, truth)
        print(f"{method:8s}: {r.n_llm_calls} LLM calls "
              f"({len(table)/r.n_llm_calls:.1f}x fewer), "
              f"{r.n_voted} voted, {r.n_fallback} fallback, "
              f"acc={acc:.4f} f1={f1:.4f}, "
              f"recluster_time={r.recluster_time_s*1e3:.0f}ms")


if __name__ == "__main__":
    main()
