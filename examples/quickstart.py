"""Quickstart: the lazy Session/Query API end-to-end on a synthetic table.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the canonical ``repro.api`` surface: one Session, lazy
``.filter()`` queries, ``.explain()`` before spending a single oracle call,
``.collect()`` routing (CSV vs. the linear reference baseline), predicate
composition with ``&``/``~``, and run-level session accounting.
"""
import sys

sys.path.insert(0, "src")

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.core.operators import accuracy_f1
from repro.data import make_dataset


def fresh_oracle(ds, q, seed=7):
    return SyntheticOracle(ds.labels[q], flip_prob=0.02, seed=seed,
                           token_lens=ds.token_lens)


def main():
    print("== CSV semantic filter quickstart (repro.api) ==")
    ds = make_dataset("imdb_review", n=4000, seed=0)
    truth = ds.labels["RV-Q1"]

    sess = Session(policy=ExecutionPolicy(n_clusters=4, xi=0.005))
    reviews = sess.table(texts=ds.texts, embeddings=ds.embeddings,
                         name="reviews")
    print(f"table: {len(reviews)} tuples; predicate: 'the review is "
          f"positive' (selectivity {truth.mean():.2f})")

    # --- linear reference baseline through the same entry point ---
    ref = reviews.filter(fresh_oracle(ds, "RV-Q1"), name="positive").collect(
        sess.policy.replace(method="reference"))
    acc, f1 = accuracy_f1(ref.mask, truth)
    print(f"\nreference: {ref.n_llm_calls} LLM calls (linear scan), "
          f"acc={acc:.4f} f1={f1:.4f}")

    # --- CSV with UniVote and SimVote ---
    for method in ["csv", "csv-sim"]:
        r = reviews.filter(fresh_oracle(ds, "RV-Q1"), name="positive") \
                   .collect(sess.policy.replace(method=method))
        acc, f1 = accuracy_f1(r.mask, truth)
        fr = r.raw.results["positive"]
        print(f"{method:8s}: {r.n_llm_calls} LLM calls "
              f"({len(reviews)/r.n_llm_calls:.1f}x fewer), "
              f"{fr.n_voted} voted, {fr.n_fallback} fallback, "
              f"acc={acc:.4f} f1={f1:.4f}, "
              f"recluster_time={fr.recluster_time_s*1e3:.0f}ms")

    # --- lazy composition + explain: zero oracle calls until collect ---
    print("\n-- composed query: positive AND mentions-acting "
          "(cost-ordered cascade) --")
    q = (reviews.filter(fresh_oracle(ds, "RV-Q1"), name="positive")
         & reviews.filter(fresh_oracle(ds, "RV-Q3"), name="mentions_acting"))
    print(q.explain())
    r = q.collect()
    truth_and = ds.labels["RV-Q1"] & ds.labels["RV-Q3"]
    acc, f1 = accuracy_f1(r.mask, truth_and)
    print(f"collected: {r.n_llm_calls} LLM calls "
          f"(pilot {r.pilot_calls}), order={r.order}, "
          f"acc={acc:.4f} f1={f1:.4f}")

    print(f"\nsession totals: {sess.stats.n_calls} oracle calls, "
          f"{sess.stats.input_tokens} input tokens, "
          f"mean oracle batch {sess.stats.mean_batch_size:.1f}")


if __name__ == "__main__":
    main()
