"""Serve a small JAX backbone and run CSV with a REAL ModelOracle:
embeddings from the JAX encoder, decisions from yes/no logits through the
batched serving engine — the full production path at toy scale.

    PYTHONPATH=src python examples/serve_filter.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.api import ExecutionPolicy, Session
from repro.configs import smoke_config
from repro.core.oracle import ModelOracle
from repro.data import make_dataset
from repro.data.tokenizer import HashTokenizer
from repro.embeddings import EmbeddingModel
from repro.models import lm
from repro.serving import ServingEngine


def main():
    print("== semantic filter served by a JAX backbone ==")
    ds = make_dataset("imdb_review", n=600, seed=0)

    # model plane: the oracle LLM behind the batched serving engine
    cfg = smoke_config("llama3.1-8b")
    params = lm.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=8)
    tok = HashTokenizer(cfg.vocab_size)
    oracle = ModelOracle(engine, tok, "the review is positive", ds.texts)

    # data plane: embeddings from the JAX encoder (E5-style, chunked)
    encoder = EmbeddingModel(smoke_config("e5-large"), max_len=32)
    emb = encoder.encode(ds.texts)
    print(f"embedded {len(ds.texts)} tuples -> {emb.shape}")

    sess = Session(engine=engine)
    table = sess.table(texts=ds.texts, embeddings=emb, name="reviews")
    r = table.filter(oracle, name="positive").collect(
        ExecutionPolicy(method="csv", n_clusters=4, min_sample=25))
    print(f"CSV: {r.n_llm_calls} LLM invocations for {len(ds.texts)} tuples "
          f"({len(ds.texts)/max(1,r.n_llm_calls):.1f}x reduction)")
    print(f"engine stats: {engine.stats}")
    print(f"passed filter: {int(r.mask.sum())} tuples")
    # NOTE: the backbone is untrained — decisions are arbitrary but the
    # entire serving path (batcher -> prefill -> yes/no logits -> voting)
    # is the production one.


if __name__ == "__main__":
    main()
