"""Stream watcher demo: standing queries, kill/restart, exact delivery.

    PYTHONPATH=src python examples/watch_demo.py

Drives the streaming subsystem (repro.stream) end to end: a deterministic
replayed feed arrives tick by tick under a per-source rate budget, three
standing queries re-vote only the clusters each tick's appends touch, and
every newly-matching row is pushed exactly once to its sink.  Midway the
watcher is killed (the graceful-shutdown path: final checkpoint + sink
flush) and restarted from the ``SessionStore`` checkpoint — the rebuild
costs ~0 oracle calls and the remaining ticks notify exactly what an
unkilled control run notifies.  Asserts the ISSUE-8 contracts inline
(sublinear per-tick cost, zero duplicate notifications across the
kill/restart, zero-call restore) so CI smoke catches regressions.
"""
import signal
import sys
import tempfile

sys.path.insert(0, "src")

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset
from repro.service.lifecycle import GracefulShutdown
from repro.service.store import SessionStore
from repro.stream import (CallbackSink, RateBudget, StreamWatcher,
                          SyntheticSource)

POL = ExecutionPolicy(n_clusters=4, min_sample=25)
N = 500
PER_TICK = 50
KILL_AFTER = 4
QUERIES = [("positive", "RV-Q1", 7), ("acting", "RV-Q3", 8),
           ("plot", "RV-Q2", 9)]


def build(ds, state_dir):
    """Session + oracles + watcher over the same deterministic stream
    (durable oracle names -> the checkpoint is restorable)."""
    sess = Session(policy=POL)
    for name, key, seed in QUERIES:
        sess.register_oracle(name, SyntheticOracle(
            ds.labels[key], flip_prob=0.0, seed=seed,
            token_lens=ds.token_lens))
    store = SessionStore(state_dir) if state_dir else None
    watcher = StreamWatcher(sess, table_name="feed", store=store)
    watcher.add_source(
        SyntheticSource("feed0", texts=list(ds.texts),
                        embeddings=ds.embeddings,
                        arrive_per_tick=PER_TICK, seed=11),
        RateBudget(rows_per_tick=PER_TICK))
    events = {}
    for name, _, _ in QUERIES:
        lst = events.setdefault(name, [])
        watcher.register(name, sink=CallbackSink(
            (lambda L: lambda ev: L.append(ev))(lst)))
    return sess, watcher, events


def main():
    print("== stream watcher demo (repro.stream) ==")
    ds = make_dataset("imdb_review", n=N, seed=0)

    # ---- control: full run, never killed -------------------------------
    sess_c, w_c, ev_c = build(ds, None)
    ticks_c = w_c.run()
    n_total = sum(len(v) for v in ev_c.values())
    print(f"control: {len(ticks_c)} ticks, "
          f"{w_c.stats.n_oracle_calls} oracle calls, "
          f"{n_total} notifications")
    # sublinear: steady-state ticks pay for their own rows, not the table
    per_tick = [t["oracle_calls"] for t in ticks_c]
    assert all(c <= PER_TICK * len(QUERIES) for c in per_tick[1:]), per_tick
    sess_c.close()

    with tempfile.TemporaryDirectory() as tmp:
        # ---- leg 1: run to tick KILL_AFTER, then a SIGTERM-style kill --
        sess_a, w_a, ev_a = build(ds, tmp)
        shutdown = GracefulShutdown(exit_on_signal=False).install()
        shutdown.register("watch-shutdown", w_a.shutdown)
        for _ in range(KILL_AFTER):
            s = w_a.tick()
            print(f"tick {s['tick']}: +{s['rows']} rows, "
                  f"{s['oracle_calls']} oracle calls, "
                  f"{s['notified']} notified")
        shutdown.trigger(signal.SIGTERM)   # checkpoint + flush, once
        shutdown.close()
        sess_a.close()
        print(f"killed after tick {KILL_AFTER} "
              f"({sum(len(v) for v in ev_a.values())} rows notified so far)")

        # ---- leg 2: fresh process restores mid-stream ------------------
        sess_b, w_b, ev_b = build(ds, tmp)
        assert w_b.has_checkpoint()
        report = w_b.restore()
        assert sess_b.stats.n_calls == 0, "restore must not re-invoke"
        print(f"restored at tick {w_b.stats.n_ticks} at 0 oracle calls: "
              f"{report}")
        ticks_b = w_b.run()
        sess_b.close()

    # ---- the kill/restart contracts ------------------------------------
    for name, _, _ in QUERIES:
        ctl_tail = [(e["tick"], e["row"]) for e in ev_c[name]
                    if e["tick"] > KILL_AFTER]
        got_tail = [(e["tick"], e["row"]) for e in ev_b[name]]
        assert got_tail == ctl_tail, f"{name}: tail diverged from control"
        keys = ([e["key"] for e in ev_a[name]]
                + [e["key"] for e in ev_b[name]])
        assert len(keys) == len(set(keys)), f"{name}: duplicate across kill"
        assert sorted(keys) == sorted(e["key"] for e in ev_c[name]), name
    assert ([t["oracle_calls"] for t in ticks_b]
            == [t["oracle_calls"] for t in ticks_c[KILL_AFTER:]])
    print(f"restart leg: {len(ticks_b)} ticks notified exactly the "
          "control's rows — zero duplicates, zero drops")
    print("\nwatch demo OK")


if __name__ == "__main__":
    main()
