"""Distributed execution demo: sharded rounds + append-only session log.

    PYTHONPATH=src python examples/distributed_demo.py

Three acts, each asserting its contract inline (CI runs this as smoke):

1. **Sharded rounds** — a two-shard CSV round over the Fig. 4-sized imdb
   table: masks, oracle call counts, and cluster logs are bit-identical
   to the single-host run; only per-dispatch batch sizes shrink.
2. **Merged dispatch lane** — two Sessions (stand-ins for two scheduler
   processes) feed ONE dispatch lane through a ``DispatchCoordinator``,
   again bit-identical to serial collects.
3. **Continuous checkpointing** — a ``FilterService`` on an append-only
   session log (``log_dir``): every decision is durable the moment it is
   made, the "process" dies without a final checkpoint, and the restart
   replays snapshot + log tail to the same masks at zero oracle calls.
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.api import ExecutionPolicy, Session
from repro.core import CSVConfig, SyntheticOracle, semantic_filter
from repro.data import make_dataset
from repro.distributed import DispatchCoordinator
from repro.service import FilterService

N = 3000
POL = ExecutionPolicy(n_clusters=4, xi=0.005)


def _oracle(ds, key="RV-Q1", seed=7):
    return SyntheticOracle(ds.labels[key], flip_prob=0.02, seed=seed,
                           token_lens=ds.token_lens)


def act1_sharded_rounds(ds):
    print("== act 1: two-shard rounds, bit-identical to single-host ==")
    runs = {}
    for shards in (1, 2):
        r = semantic_filter(ds.embeddings, _oracle(ds),
                            CSVConfig(n_clusters=4, xi=0.005,
                                      shards=shards))
        runs[shards] = r
        batches = [b for rr in r.round_log for b in rr.oracle_batches]
        print(f"  shards={shards}: {r.n_llm_calls} oracle calls, "
              f"{len(r.round_log)} rounds, batches={batches}")
    r1, r2 = runs[1], runs[2]
    assert (r1.mask == r2.mask).all(), "masks diverged"
    assert r1.n_llm_calls == r2.n_llm_calls, "call counts diverged"
    assert r1.cluster_log == r2.cluster_log, "cluster logs diverged"
    assert any(rr.shards == 2 for rr in r2.round_log), "never sharded"
    print("  bit-identity holds: masks, calls, cluster logs all equal\n")


def act2_coordinator(ds):
    print("== act 2: two schedulers, one merged dispatch lane ==")
    serial = {}
    for q in ("RV-Q1", "RV-Q3"):
        s = Session(policy=POL)
        t = s.table(embeddings=ds.embeddings, name="reviews")
        serial[q] = t.filter(_oracle(ds, q), name="q").collect()
        s.close()
    coord = DispatchCoordinator()
    try:
        sessions, tickets = [], []
        for q in ("RV-Q1", "RV-Q3"):
            s = Session(policy=POL, coordinator=coord)
            t = s.table(embeddings=ds.embeddings, name="reviews")
            with s.scheduler.holding():
                tickets.append((q, s.scheduler.submit(
                    t.filter(_oracle(ds, q), name="q"))))
            sessions.append(s)
        for q, tk in tickets:
            r = tk.result()
            assert (r.mask == serial[q].mask).all(), f"{q}: mask diverged"
            assert r.n_llm_calls == serial[q].n_llm_calls
        print(f"  lanes attached: {coord.n_attached}; per-lane waves: "
              f"{[ls.n_waves for ls in coord.stats().values()]}")
        for s in sessions:
            s.close()
        assert coord.n_attached == 0, "lanes leaked after session close"
    finally:
        coord.close()
    print("  both sessions' masks/calls equal their serial controls\n")


def act3_continuous_checkpoint(ds, log_dir):
    print("== act 3: append-only log — crash, restart, replay ==")

    def build():
        s = Session(policy=POL.replace(shards=2, log_dir=log_dir,
                                       log_compact_records=6))
        t = s.table(embeddings=ds.embeddings, name="reviews")
        s.register_oracle("positive", _oracle(ds, "RV-Q1", 7))
        s.register_oracle("acting", _oracle(ds, "RV-Q3", 8))
        svc = FilterService(s)
        svc.register_tenant("demo", s.policy)
        return s, t, svc

    s1, t1, svc1 = build()
    svc1.restore()                       # fresh dir: starts recording
    (rp,) = svc1.gather(svc1.submit("demo", t1.filter("positive")))
    (ra,) = svc1.gather(svc1.submit("demo", t1.filter("acting")))
    gens = svc1.log._gen
    print(f"  live: positive={rp.n_llm_calls} calls, "
          f"acting={ra.n_llm_calls} calls; log generation {gens} "
          f"(compaction thresholds crossed mid-run)")
    svc1.log.abandon()                   # kill -9: no close, no snapshot
    s1.close()

    s2, t2, svc2 = build()
    rep = svc2.restore()
    print(f"  restart: {rep}")
    (rp2,) = svc2.gather(svc2.submit("demo", t2.filter("positive")))
    (ra2,) = svc2.gather(svc2.submit("demo", t2.filter("acting")))
    assert (rp2.mask == rp.mask).all() and (ra2.mask == ra.mask).all()
    assert rp2.n_llm_calls == 0 and ra2.n_llm_calls == 0, \
        "restart should replay, not recompute"
    assert s2.stats.n_calls == 0
    print(f"  replayed both filters at 0 oracle calls "
          f"({rp2.n_replayed} + {ra2.n_replayed} decisions from the log)")
    svc2.close()


def main():
    ds = make_dataset("imdb_review", n=N, seed=0)
    act1_sharded_rounds(ds)
    act2_coordinator(ds)
    with tempfile.TemporaryDirectory() as d:
        act3_continuous_checkpoint(ds, d)
    print("\ndistributed demo OK")


if __name__ == "__main__":
    main()
