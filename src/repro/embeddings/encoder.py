"""E5-style embedding encoder (paper phase-1 substrate).

Bidirectional transformer + mean pooling over non-pad positions; long
texts are split into chunks, embedded independently, and mean-merged —
exactly the paper's §4.1 long-input handling.  Reuses the model substrate's
attention/MLP layers with causal=False.
"""
from __future__ import annotations

import math
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import HashTokenizer
from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig
from repro.models.lm import _init_superblock


def init_encoder_params(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    D = cfg.d_model
    table = (jax.random.normal(k1, (cfg.padded_vocab, D), jnp.float32)
             / math.sqrt(D)).astype(cfg.dtype)
    pattern = (LayerSpec(kind="attn", ffn="dense"),)
    keys = jax.random.split(k2, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_superblock(k, cfg, pattern, False))(keys)
    return {"embed": {"table": table}, "blocks": blocks,
            "final_norm": L.init_norm(cfg)}


def encoder_forward(cfg: ModelConfig, params, tokens, mask):
    """tokens (B,S) int32, mask (B,S) bool -> pooled embeddings (B, D)."""
    h = params["embed"]["table"][tokens]
    B, S, D = h.shape
    h = h + L.sinusoidal_positions(jnp.arange(S)[None, :], D).astype(h.dtype)

    def body(carry, sb):
        h = carry
        hn = L.apply_norm(cfg, sb["l0"]["norm"], h)
        h = h + L.attention_plain(cfg, sb["l0"]["attn"], hn, causal=False,
                                  rope=False)
        hf = L.apply_norm(cfg, sb["l0"]["ffn_norm"], h)
        h = h + L.apply_mlp(cfg, sb["l0"]["ffn"], hf)
        return h, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = L.apply_norm(cfg, params["final_norm"], h)
    m = mask[..., None].astype(jnp.float32)
    pooled = jnp.sum(h.astype(jnp.float32) * m, axis=1) / jnp.maximum(
        jnp.sum(m, axis=1), 1.0)
    return pooled / jnp.maximum(
        jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


class EmbeddingModel:
    def __init__(self, cfg: ModelConfig, params=None, seed: int = 0,
                 max_len: int = 128, tokenizer: HashTokenizer = None):
        self.cfg = cfg
        self.params = params if params is not None else init_encoder_params(
            cfg, jax.random.key(seed))
        self.max_len = max_len
        self.tok = tokenizer or HashTokenizer(cfg.vocab_size)
        self._fn = jax.jit(lambda p, t, m: encoder_forward(cfg, p, t, m))

    def encode(self, texts: Sequence[str], batch: int = 64) -> np.ndarray:
        """Chunked embedding: mean of per-chunk embeddings (paper §4.1)."""
        chunks: List[List[int]] = []
        owner: List[int] = []
        for i, t in enumerate(texts):
            ids = self.tok.encode(t)
            for s in range(0, max(1, len(ids)), self.max_len):
                chunks.append(ids[s:s + self.max_len])
                owner.append(i)
        out = np.zeros((len(texts), self.cfg.d_model), np.float32)
        counts = np.zeros(len(texts), np.float32)
        for s in range(0, len(chunks), batch):
            group = chunks[s:s + batch]
            L_max = self.max_len
            toks = np.zeros((len(group), L_max), np.int32)
            mask = np.zeros((len(group), L_max), bool)
            for r, c in enumerate(group):
                toks[r, :len(c)] = c
                mask[r, :len(c)] = True
            emb = np.asarray(self._fn(self.params, jnp.asarray(toks),
                                      jnp.asarray(mask)))
            for r, o in enumerate(owner[s:s + batch]):
                out[o] += emb[r]
                counts[o] += 1
        out /= np.maximum(counts[:, None], 1.0)
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        return out / np.maximum(norms, 1e-9)


def encode_texts(texts, cfg=None, seed=0, max_len=128):
    from repro.configs import smoke_config
    cfg = cfg or smoke_config("e5-large")
    return EmbeddingModel(cfg, seed=seed, max_len=max_len).encode(texts)
