from repro.embeddings.encoder import EmbeddingModel, encode_texts
