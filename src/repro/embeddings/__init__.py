from repro.embeddings.cache import (CachingEmbedder, EmbeddingCache,
                                    content_key)
from repro.embeddings.encoder import EmbeddingModel, encode_texts

__all__ = ["CachingEmbedder", "EmbeddingCache", "content_key",
           "EmbeddingModel", "encode_texts"]
