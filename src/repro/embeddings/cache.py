"""Content-hash keyed embedding cache: the session-scoped reuse layer.

The paper's phase 1 (offline embedding) is query-agnostic, so in a session
that filters the same or overlapping data repeatedly the embeddings are the
first thing worth amortizing.  ``EmbeddingCache`` maps a hash of each text's
*content* (not its position) to its embedding row, so:

- registering a second table whose rows overlap an earlier one embeds only
  the genuinely new rows;
- ``TableHandle.append``/``update`` embed only the appended/changed rows;
- duplicate texts inside one batch are embedded once.

A ``Session`` owns one cache by default (two sessions never share state);
pass the same ``EmbeddingCache`` instance to several sessions to share
embeddings explicitly (``Session(embedding_cache=shared)``).
"""
from __future__ import annotations

import hashlib
from typing import Callable, Sequence

import numpy as np


def content_key(text: str) -> str:
    """Stable content hash of one tuple's text payload."""
    return hashlib.blake2b(text.encode("utf-8"), digest_size=16).hexdigest()


class EmbeddingCache:
    """Text-content -> embedding row store with hit/miss accounting.

    ``encoded_rows`` counts rows actually sent to the underlying embedder —
    the number the session-reuse benchmark and tests assert on.
    """

    def __init__(self):
        self._store: dict[str, np.ndarray] = {}
        self.hits = 0
        self.misses = 0
        self.encoded_rows = 0
        # durability hook: called as hook(keys, rows) after fresh rows are
        # inserted (repro.service.log appends them so a restart rebuilds
        # the cache without re-encoding)
        self.hook = None

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, text: str) -> bool:
        return content_key(text) in self._store

    def encode(self, texts: Sequence[str], embedder: Callable) -> np.ndarray:
        """Embed ``texts``, calling ``embedder`` only on cache misses.

        Misses are deduplicated before the embedder call (one row per unique
        unseen content), then every position is served from the store.
        """
        if len(texts) == 0:
            return np.zeros((0, 0), dtype=np.float32)
        keys = [content_key(t) for t in texts]
        missing_pos: list[int] = []
        seen_missing: set[str] = set()
        for pos, k in enumerate(keys):
            if k not in self._store and k not in seen_missing:
                seen_missing.add(k)
                missing_pos.append(pos)
        if missing_pos:
            fresh = np.asarray(embedder([texts[p] for p in missing_pos]),
                               dtype=np.float32)
            if fresh.ndim != 2 or fresh.shape[0] != len(missing_pos):
                raise ValueError(
                    f"embedder returned shape {fresh.shape}; expected "
                    f"({len(missing_pos)}, D)")
            for row, pos in enumerate(missing_pos):
                self._store[keys[pos]] = fresh[row]
            self.encoded_rows += len(missing_pos)
            if self.hook is not None:
                self.hook([keys[p] for p in missing_pos], fresh)
        self.misses += len(missing_pos)
        self.hits += len(keys) - len(missing_pos)
        return np.stack([self._store[k] for k in keys]).astype(np.float32)


class CachingEmbedder:
    """Drop-in embedder callable routed through an ``EmbeddingCache``.

    ``Session.table(texts=..., embedder=...)`` wraps the user's embedder in
    one of these, so lazy ``SemanticTable.embeddings`` materialization and
    incremental ``append``/``update`` all share the session cache.
    """

    def __init__(self, cache: EmbeddingCache, embedder: Callable):
        self.cache = cache
        self.embedder = embedder

    def __call__(self, texts: Sequence[str]) -> np.ndarray:
        return self.cache.encode(texts, self.embedder)
