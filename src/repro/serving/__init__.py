from repro.serving.engine import ServingEngine
from repro.serving.batcher import BucketBatcher, DispatchMergeStats
