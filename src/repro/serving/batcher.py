"""Continuous-batching-lite: padded length buckets for prompt batches.

TPU serving wants static shapes; requests are grouped into power-of-two
length buckets and padded batches, so each (bucket_len, batch) pair hits a
cached compiled program.  This is the fixed-shape analogue of vLLM's
continuous batching used by the paper's serving layer.
"""
from __future__ import annotations

import math
from typing import Iterable, List, Sequence, Tuple

import numpy as np


def bucket_len(n: int, min_bucket: int = 32, max_bucket: int = 8192) -> int:
    return min(max_bucket, max(min_bucket, 1 << math.ceil(math.log2(max(1, n)))))


class BucketBatcher:
    def __init__(self, max_batch: int = 32, pad_id: int = 0,
                 min_bucket: int = 32, max_bucket: int = 8192):
        self.max_batch = max_batch
        self.pad_id = pad_id
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket

    def plan(self, prompts: Sequence[List[int]]
             ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Group prompts -> [(orig_indices, tokens (b, L), lengths (b,))]."""
        order = np.argsort([len(p) for p in prompts], kind="stable")
        batches = []
        i = 0
        while i < len(order):
            j = min(i + self.max_batch, len(order))
            idx = order[i:j]
            L = bucket_len(max(len(prompts[k]) for k in idx),
                           self.min_bucket, self.max_bucket)
            toks = np.full((len(idx), L), self.pad_id, np.int32)
            lens = np.zeros(len(idx), np.int32)
            for r, k in enumerate(idx):
                p = prompts[k][-L:]  # truncate overlong from the left
                toks[r, :len(p)] = p
                lens[r] = len(p)
            batches.append((idx, toks, lens))
            i = j
        return batches
