"""Continuous-batching-lite: padded length buckets for prompt batches.

TPU serving wants static shapes; requests are grouped into power-of-two
length buckets and padded batches, so each (bucket_len, batch) pair hits a
cached compiled program.  This is the fixed-shape analogue of vLLM's
continuous batching used by the paper's serving layer.
"""
from __future__ import annotations

import math
from typing import Iterable, List, Sequence, Tuple

import numpy as np


def bucket_len(n: int, min_bucket: int = 32, max_bucket: int = 8192) -> int:
    return min(max_bucket, max(min_bucket, 1 << math.ceil(math.log2(max(1, n)))))


class DispatchMergeStats:
    """Fill accounting for merged cross-query oracle dispatches.

    The service scheduler's analogue of ``BucketBatcher.stats``: where the
    bucket batcher measures how well one query's prompts fill padded device
    batches, this measures how well concurrent queries fill each *oracle
    invocation* — one ``record`` per merged dispatch, holding the batch
    size each member request contributed.  ``mean_batch_size`` is the
    number the ISSUE-5 acceptance criterion compares against the serial
    per-invocation mean (``OracleStats.mean_batch_size``)."""

    def __init__(self):
        # running counters, NOT per-dispatch lists: a long-lived service
        # records one entry per tick forever, so growth must be O(1)
        self.n_invocations = 0
        self.n_requests = 0
        self.total_ids = 0
        self.last_invocation = 0   # merged ids in the most recent dispatch
        self.total_wall_s = 0.0    # evaluation wall-clock across dispatches
        self.last_wall_s = 0.0
        self.total_tokens = 0      # oracle tokens (input + decision) spent
        self.n_truncated = 0       # prompts left-truncated by the batcher

    def record(self, sizes: Iterable[int], wall_s: float = 0.0,
               tokens: int = 0, truncated: int = 0) -> None:
        sizes = [int(s) for s in sizes]
        self.n_invocations += 1
        self.n_requests += len(sizes)
        self.last_invocation = sum(sizes)
        self.total_ids += self.last_invocation
        self.last_wall_s = float(wall_s)
        self.total_wall_s += float(wall_s)
        self.total_tokens += int(tokens)
        self.n_truncated += int(truncated)

    @property
    def mean_batch_size(self) -> float:
        """Mean merged ids per dispatch (0.0 before the first record)."""
        if not self.n_invocations:
            return 0.0
        return self.total_ids / self.n_invocations

    @property
    def merge_factor(self) -> float:
        """Mean member requests folded into one dispatch (>= 1.0)."""
        if not self.n_invocations:
            return 0.0
        return self.n_requests / self.n_invocations

    @property
    def mean_wall_s(self) -> float:
        """Mean evaluation wall-clock per dispatch (tick wave)."""
        if not self.n_invocations:
            return 0.0
        return self.total_wall_s / self.n_invocations

    @property
    def tokens_per_s(self) -> float:
        """Oracle token throughput over the recorded evaluation time."""
        if self.total_wall_s <= 0:
            return 0.0
        return self.total_tokens / self.total_wall_s

    def metrics_view(self) -> dict:
        """Unified-name view for ``MetricsRegistry.sync_from``."""
        return {
            "service.invocations": self.n_invocations,
            "service.requests": self.n_requests,
            "service.merged_ids": self.total_ids,
            "service.tokens": self.total_tokens,
            "service.truncated": self.n_truncated,
            "service.mean_batch_size": self.mean_batch_size,
            "service.merge_factor": self.merge_factor,
            "service.tokens_per_s": self.tokens_per_s,
            "service.last_invocation": self.last_invocation,
            "service.last_wall_s": self.last_wall_s,
        }


class BucketBatcher:
    def __init__(self, max_batch: int = 32, pad_id: int = 0,
                 min_bucket: int = 32, max_bucket: int = 8192):
        self.max_batch = max_batch
        self.pad_id = pad_id
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        # cumulative planning stats: how well callers fill the buckets.
        # The CSV round executor exists to push fill_ratio toward 1.0 —
        # cross-cluster batches arrive max_batch-sized instead of per-cluster
        # trickles; benchmarks and the round planner read these numbers.
        self.stats = {"plans": 0, "prompts": 0, "batches": 0,
                      "padded_tokens": 0, "real_tokens": 0,
                      # overlong prompts silently lose their head (left
                      # truncation keeps the answer-bearing tail); count
                      # both events and tokens so the loss is visible
                      "truncated_prompts": 0, "truncated_tokens": 0}

    @property
    def mean_batch_size(self) -> float:
        return self.stats["prompts"] / max(1, self.stats["batches"])

    @property
    def fill_ratio(self) -> float:
        """Fraction of padded (batch x bucket_len) slots holding real tokens."""
        return self.stats["real_tokens"] / max(1, self.stats["padded_tokens"])

    def metrics_view(self) -> dict:
        """Unified-name view for ``MetricsRegistry.sync_from``."""
        return {
            "engine.plans": self.stats["plans"],
            "engine.prompts": self.stats["prompts"],
            "engine.batches": self.stats["batches"],
            "engine.padded_tokens": self.stats["padded_tokens"],
            "engine.real_tokens": self.stats["real_tokens"],
            "engine.truncated_prompts": self.stats["truncated_prompts"],
            "engine.truncated_tokens": self.stats["truncated_tokens"],
            "engine.bucket_fill": self.fill_ratio,
        }

    def plan(self, prompts: Sequence[List[int]]
             ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Group prompts -> [(orig_indices, tokens (b, L), lengths (b,))]."""
        order = np.argsort([len(p) for p in prompts], kind="stable")
        batches = []
        i = 0
        while i < len(order):
            j = min(i + self.max_batch, len(order))
            idx = order[i:j]
            L = bucket_len(max(len(prompts[k]) for k in idx),
                           self.min_bucket, self.max_bucket)
            toks = np.full((len(idx), L), self.pad_id, np.int32)
            lens = np.zeros(len(idx), np.int32)
            for r, k in enumerate(idx):
                p = prompts[k][-L:]  # truncate overlong from the left
                if len(prompts[k]) > L:
                    self.stats["truncated_prompts"] += 1
                    self.stats["truncated_tokens"] += len(prompts[k]) - L
                toks[r, :len(p)] = p
                lens[r] = len(p)
            batches.append((idx, toks, lens))
            self.stats["batches"] += 1
            self.stats["padded_tokens"] += len(idx) * L
            self.stats["real_tokens"] += int(lens.sum())
            i = j
        self.stats["plans"] += 1
        self.stats["prompts"] += len(prompts)
        return batches
