"""Batched serving engine: prefill + decode over the model zoo.

Drives the oracle-LLM side of the CSV pipeline: ``first_token_logits``
serves the semantic filter's yes/no decisions; ``generate`` serves the
example apps.  Static-shape bucketed batching keeps compile cache hits
high; per-(batch, bucket) jitted programs are cached.
"""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.obs.trace import get_tracer
from repro.serving.batcher import BucketBatcher


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 16,
                 pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.batcher = BucketBatcher(max_batch=max_batch, pad_id=pad_id)
        self._prefill_cache = {}
        self._decode_fn = None
        # batch_sizes keeps only a recent window (debug visibility); the
        # mean uses O(1) cumulative counters so a long-running server never
        # grows without bound
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0, "batches": 0,
                      "batched_prompts": 0, "batch_sizes": [],
                      # mirrored from the batcher so silent prompt-head
                      # loss is visible where serving stats are read
                      "truncated_prompts": 0, "truncated_tokens": 0}
    _BATCH_SIZE_WINDOW = 1024

    @property
    def mean_batch_size(self) -> float:
        """Mean prompts per compiled-program invocation — grows toward
        ``max_batch`` when callers (the CSV round executor) submit
        cross-cluster round batches instead of per-cluster trickles."""
        return self.stats["batched_prompts"] / max(1, self.stats["batches"])

    # -------------------------------------------------------------- prefill
    def _prefill_fn(self, L: int, with_cache: bool):
        key = (L, with_cache)
        if key not in self._prefill_cache:
            cfg = self.cfg

            if with_cache:
                def f(params, tokens):
                    return lm.prefill(cfg, params, tokens, max_len=L + 64)
            else:
                def f(params, tokens):
                    logits, _ = lm.forward(cfg, params, tokens)
                    return logits

            self._prefill_cache[key] = jax.jit(f)
        return self._prefill_cache[key]

    def _select_fn(self, L: int, per_prompt: bool):
        key = (L, "select", per_prompt)
        if key not in self._prefill_cache:
            cfg = self.cfg

            def f(params, tokens, lens, token_ids):
                return lm.first_logits_select(cfg, params, tokens, lens,
                                              token_ids)

            self._prefill_cache[key] = jax.jit(f)
        return self._prefill_cache[key]

    def first_token_logits(self, prompts: Sequence[List[int]],
                           token_ids=None) -> np.ndarray:
        """Logits at each prompt's last position.

        Without ``token_ids``: the full (n_prompts, padded_vocab) float32
        matrix.  With ``token_ids`` — (T,) shared across prompts or
        (n_prompts, T) per prompt — only those T logits per prompt come
        back to the host ((n_prompts, T)); the yes/no oracle fast path
        that never materializes the vocab axis.
        """
        if token_ids is not None:
            token_ids = np.asarray(token_ids, np.int32)
        n_tok = (self.cfg.padded_vocab if token_ids is None
                 else token_ids.shape[-1])
        out = np.zeros((len(prompts), n_tok), np.float32)
        tr = get_tracer()
        for idx, toks, lens in self.batcher.plan(prompts):
            with tr.span("engine_tick", kind="engine_tick", phase="prefill",
                         bucket_len=int(toks.shape[1]), batch=int(len(idx)),
                         tokens=int(lens.sum()),
                         attn_impl=self.cfg.attn_impl):
                if token_ids is None:
                    logits = self._prefill_fn(toks.shape[1], False)(
                        self.params, jnp.asarray(toks))
                    last = np.asarray(logits)[np.arange(len(idx)), lens - 1]
                else:
                    tids = (token_ids if token_ids.ndim == 1
                            else token_ids[idx])
                    last = np.asarray(self._select_fn(
                        toks.shape[1], token_ids.ndim == 2)(
                            self.params, jnp.asarray(toks),
                            jnp.asarray(lens), jnp.asarray(tids)))
            out[idx] = last
            self.stats["prefill_tokens"] += int(lens.sum())
            self.stats["batches"] += 1
            self.stats["batched_prompts"] += int(len(idx))
            self.stats["batch_sizes"].append(int(len(idx)))
            del self.stats["batch_sizes"][:-self._BATCH_SIZE_WINDOW]
            tr.metrics.inc("engine.prefill_tokens", int(lens.sum()))
            tr.metrics.inc("engine.ticks")
            tr.metrics.observe("engine.batch_size", int(len(idx)),
                               bounds=(1, 2, 4, 8, 16, 32, 64, 128, 256))
        tr.metrics.set_info("kernel.attn_impl", self.cfg.attn_impl)
        tr.metrics.set("engine.bucket_fill", self.batcher.fill_ratio)
        for k in ("truncated_prompts", "truncated_tokens"):
            self.stats[k] = self.batcher.stats[k]
        return out

    # --------------------------------------------------------------- decode
    def _decode(self, params, cache, tokens, pos):
        return lm.decode_step(self.cfg, params, cache, tokens, pos)

    def generate(self, prompts: Sequence[List[int]], max_new: int = 16,
                 temperature: float = 0.0, seed: int = 0) -> List[List[int]]:
        """Greedy/temperature decoding; returns generated ids per prompt."""
        if self._decode_fn is None:
            self._decode_fn = jax.jit(self._decode)
        results: List[List[int]] = [[] for _ in prompts]
        key = jax.random.key(seed)
        tr = get_tracer()
        for idx, toks, lens in self.batcher.plan(prompts):
            L = toks.shape[1]
            with tr.span("engine_tick", kind="engine_tick", phase="generate",
                         bucket_len=int(L), batch=int(len(idx)),
                         tokens=int(lens.sum()), max_new=int(max_new),
                         attn_impl=self.cfg.attn_impl):
                logits, cache, _ = self._prefill_fn(L, True)(
                    self.params, jnp.asarray(toks))
                # next_pos per sequence = its true length (cache rows
                # beyond a prompt's length contain pad K/V — masked by
                # per-seq pos)
                pos = jnp.asarray(lens, jnp.int32)
                last = np.asarray(logits)[np.arange(len(idx)), lens - 1]
                cur = jnp.asarray(self._sample(last, temperature, key))
                for step in range(max_new):
                    for r, k in enumerate(idx):
                        results[k].append(int(cur[r]))
                    logits_d, cache = self._decode_fn(self.params, cache,
                                                      cur, pos)
                    pos = pos + 1
                    key, sub = jax.random.split(key)
                    cur = jnp.asarray(self._sample(np.asarray(logits_d),
                                                   temperature, sub))
                    self.stats["decode_tokens"] += len(idx)
            tr.metrics.inc("engine.prefill_tokens", int(lens.sum()))
            tr.metrics.inc("engine.decode_tokens", int(max_new * len(idx)))
            tr.metrics.inc("engine.ticks")
        for k in ("truncated_prompts", "truncated_tokens"):
            self.stats[k] = self.batcher.stats[k]
        return results

    @staticmethod
    def _sample(logits: np.ndarray, temperature: float, key) -> np.ndarray:
        if temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        g = np.asarray(jax.random.gumbel(key, logits.shape))
        return np.argmax(logits / temperature + g, axis=-1).astype(np.int32)
