"""Checkpointing from scratch (no orbax offline): msgpack + zstd/zlib, atomic.

Layout per step:
    <dir>/step_<n>.tmp-<nonce>/   — written first
        shard_000.msgpack.<codec> — leaf payloads (chunked)
        MANIFEST.json             — tree structure, shapes, dtypes, checksums
    <dir>/step_<n>/               — atomic rename on completion

Compression: zstd when the ``zstandard`` package is importable, otherwise a
stdlib ``zlib`` fallback.  The codec is recorded in the manifest so restores
pick the right decompressor; requesting ``codec="zstd"`` explicitly without
the package installed is a clear error (not a silent downgrade).

Fault-tolerance properties:
- a crash mid-write leaves only a .tmp dir (ignored on restore);
- ``latest_step`` picks the newest *committed* checkpoint;
- restore re-shards onto whatever mesh/sharding the caller provides
  (elastic restart onto a different topology);
- async=True saves on a background thread (training continues), with
  ``wait()`` joining before the next save — checkpoint/compute overlap.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Optional

import zlib

import jax
import msgpack
import numpy as np

try:  # optional: zstd gives better ratios, zlib keeps the module importable
    import zstandard as zstd
except ImportError:  # pragma: no cover - depends on the environment
    zstd = None

_SHARD_EXT = {"zstd": ".zst", "zlib": ".zlib", "none": ".raw"}


def _default_codec() -> str:
    return "zstd" if zstd is not None else "zlib"


def _require_codec(codec: str):
    """Validate a write-side codec request (fail before any file I/O)."""
    if codec not in _SHARD_EXT:
        raise ValueError(f"unknown checkpoint codec: {codec!r}")
    if codec == "zstd" and zstd is None:
        raise ModuleNotFoundError(
            "checkpoint codec 'zstd' requested but the 'zstandard' "
            "package is not installed; install it or use codec='zlib'")


def _compress(blob: bytes, codec: str) -> bytes:
    _require_codec(codec)
    if codec == "zstd":
        return zstd.ZstdCompressor(level=3).compress(blob)
    if codec == "zlib":
        return zlib.compress(blob, level=6)
    return blob


def _decompress(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd but the 'zstandard' "
                "package is not installed; install it to restore")
        return zstd.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    if codec == "none":
        return blob
    raise ValueError(f"unknown checkpoint codec: {codec!r}")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_pytree(tree, path: pathlib.Path, extra_meta: dict = None,
                codec: Optional[str] = None):
    path = pathlib.Path(path)
    codec = codec or _default_codec()
    _require_codec(codec)  # fail before the tmp dir is created
    # genuine wall-clock uses (unique tmp name, "created" metadata) — the
    # TID251 duration-clock ban does not apply
    tmp = path.with_name(path.name + f".tmp-{os.getpid()}-{int(time.time()*1e3)}")  # noqa: TID251
    tmp.mkdir(parents=True, exist_ok=False)
    flat, _ = _flatten_with_paths(tree)
    manifest = {"leaves": [], "extra": extra_meta or {},
                "created": time.time(), "codec": codec}  # noqa: TID251
    shard_path = tmp / ("shard_000.msgpack" + _SHARD_EXT[codec])
    records = []
    for key, leaf in flat:
        arr = np.asarray(leaf)
        payload = arr.tobytes()
        records.append({"key": key, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "data": payload})
        manifest["leaves"].append({
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(payload).hexdigest()})
    blob = _compress(msgpack.packb(records, use_bin_type=True), codec)
    shard_path.write_bytes(blob)
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)  # atomic commit


def load_pytree(path: pathlib.Path, template=None, shardings=None,
                verify: bool = True):
    """Restore; optionally re-shard with a shardings tree (elastic restore)."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "MANIFEST.json").read_text())
    codec = manifest.get("codec", "zstd")  # pre-codec checkpoints were zstd
    if codec not in _SHARD_EXT:
        raise ValueError(f"unknown checkpoint codec: {codec!r}")
    shard = path / ("shard_000.msgpack" + _SHARD_EXT[codec])
    records = msgpack.unpackb(_decompress(shard.read_bytes(), codec),
                              raw=False)
    by_key = {}
    for rec, meta in zip(records, manifest["leaves"]):
        if verify:
            assert hashlib.sha1(rec["data"]).hexdigest() == meta["sha1"], \
                f"checksum mismatch at {rec['key']}"
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])
                            ).reshape(rec["shape"])
        by_key[rec["key"]] = arr

    if template is None:
        return by_key, manifest["extra"]
    flat, treedef = _flatten_with_paths(template)
    leaves = []
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    for (key, tmpl), sh in zip(flat, shard_flat):
        arr = by_key[key].astype(tmpl.dtype)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, manifest["extra"]


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def step_path(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:08d}"

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".json") or ".tmp-" in p.name:
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return max(steps) if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra_meta: dict = None,
             async_: bool = False):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            save_pytree(host_tree, self.step_path(step),
                        dict(extra_meta or {}, step=step))
            self._gc()

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, template=None, shardings=None, step: int = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = load_pytree(self.step_path(step), template, shardings)
        return step, tree, extra

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*")
                       if ".tmp-" not in p.name)
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for p in self.dir.glob("*.tmp-*"):
            shutil.rmtree(p, ignore_errors=True)
