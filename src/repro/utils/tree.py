"""Pytree helpers used across the framework (no flax/optax installed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_size_bytes(tree) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn, tree):
    """tree_map where fn receives (path_string, leaf)."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
