"""Wall-clock timing helper for benches (block_until_ready aware)."""
from __future__ import annotations

import time

import jax


class Timer:
    def __init__(self):
        self.start = None
        self.elapsed = 0.0

    def __enter__(self):
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.start
        return False


def time_jax(fn, *args, warmup: int = 1, iters: int = 3, **kwargs) -> float:
    """Median wall-clock seconds of fn(*args), blocking on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
