"""Timing helpers: the repo's single source of duration clocks.

Every duration measurement in ``src/`` routes through ``monotonic()``
(``time.perf_counter`` — monotonic, immune to wall-clock steps/NTP slews).
``time.time`` is reserved for genuine wall-clock *timestamps* (checkpoint
metadata, file names) and is lint-banned elsewhere (ruff TID251).
"""
from __future__ import annotations

import time

import jax


def monotonic() -> float:
    """Monotonic seconds for measuring durations (``t1 - t0``).

    The value is only meaningful as a difference against another
    ``monotonic()`` reading — never as a wall-clock date.
    """
    return time.perf_counter()


class Timer:
    def __init__(self):
        self.start = None
        self.elapsed = 0.0

    def __enter__(self):
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.start
        return False


def time_jax(fn, *args, warmup: int = 1, iters: int = 3, **kwargs) -> float:
    """Median wall-clock seconds of fn(*args), blocking on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
