"""Model layers: norms, RoPE, attention (GQA/SWA, train+decode), MLP, MoE, Mamba.

Conventions
-----------
- activations ``(B, S, D)``; q ``(B, S, H, hd)``; k/v ``(B, S, KV, hd)``;
  KV caches ``(B, L, KV, hd)``.
- GQA is computed with grouped einsums (no KV head repetition in memory).
- softmax / SSM scans run in fp32 regardless of param dtype.
- All functions are pure; params are plain nested dicts of jnp arrays.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.api import shard_act
from repro.models.config import ModelConfig

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_norm(cfg: ModelConfig):
    if cfg.norm_type == "ln":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + cfg.norm_eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(angles)[..., None, :], jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    """Whisper-style sinusoidal embeddings; positions (..., S) -> (..., S, D)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10000.0) / max(1, half - 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), dt),
        "wk": _dense_init(ks[1], (D, KV * hd), dt),
        "wv": _dense_init(ks[2], (D, KV * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, D), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    return p


def _project_qkv(cfg, p, x, kv_x=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, KV, H // KV, hd),
            k.reshape(B, Skv, KV, hd),
            v.reshape(B, Skv, KV, hd))


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,KV,G,hd), k/v (B,Sk,KV,hd), mask broadcastable (B,1,1,Sq,Sk)."""
    scores = jnp.einsum("bqcgh,bkch->bcgqk", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bcgqk,bkch->bqcgh", probs.astype(v.dtype), v)
    return out


def _causal_window_mask(q_pos, k_pos, window):
    """(..., Sq, Sk) bool mask: causal, optionally within sliding window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m = m & (k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m


def attention_plain(cfg: ModelConfig, p, x, *, causal: bool, window=None,
                    positions=None, kv_x=None, rope: bool = True):
    """Full-matrix attention; fine for short sequences / encoders."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if rope and cfg.pos_type == "rope" and kv_x is None:
        q = apply_rope(q.reshape(B, S, -1, hd), positions, cfg.rope_theta).reshape(q.shape)
        k = apply_rope(k, positions, cfg.rope_theta)
    mask = None
    if causal:
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = _causal_window_mask(positions, kpos, window)[:, None, None]
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(hd))
    out = out.reshape(B, S, -1)
    return out @ p["wo"]


def attention_chunked(cfg: ModelConfig, p, x, *, causal: bool, window=None,
                      positions=None):
    """Flash-style chunked attention in pure XLA (online softmax).

    Three schedules:
      - window (banded): q-chunk i attends only chunks in its band (static count)
      - causal + attn_impl=="tri": triangle-packed schedule — scan over the
        nq(nq+1)/2 (qi,kj) lower-triangle block pairs; zero wasted FLOPs
      - otherwise: rectangle schedule with masking (baseline; ~2x causal waste)
    """
    B, S, _ = x.shape
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    G = cfg.n_heads // KV
    cq = min(cfg.attn_chunk_q, S)
    ck = min(cfg.attn_chunk_kv, S)
    assert S % cq == 0 and S % ck == 0, (S, cq, ck)
    nq, nk = S // cq, S // ck
    scale = 1.0 / math.sqrt(hd)

    q, k, v = _project_qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.pos_type == "rope":
        q = apply_rope(q.reshape(B, S, -1, hd), positions, cfg.rope_theta).reshape(q.shape)
        k = apply_rope(k, positions, cfg.rope_theta)

    qc = q.reshape(B, nq, cq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)  # (nq,B,cq,KV,G,hd)
    dt = x.dtype

    def block(qi_pos, kj_pos, q_blk, k_blk, v_blk, m, l, acc):
        """online-softmax update for one (q_blk, k_blk) pair."""
        s = jnp.einsum("bqcgh,bkch->bcgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        msk = _causal_window_mask(qi_pos, kj_pos, window)[:, None, None] if causal \
            else None
        if msk is not None:
            s = jnp.where(msk, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p_, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bcgqk,bkch->bcgqh", p_.astype(dt), v_blk).astype(jnp.float32)
        return m_new, l_new, acc_new

    if window is not None and cfg.swa_banded:
        # banded: only the last wb+1 kv chunks can intersect the window
        wb = -(-window // ck)  # ceil
        nband = min(nk, wb + -(-cq // ck))

        def q_step(_, qi):
            q_blk = qc[qi]
            qi_pos = positions[:, qi * cq:(qi + 1) * cq] if positions.shape[0] == B \
                else jnp.arange(cq)[None] + qi * cq
            m = jnp.full((B, KV, G, cq), -1e30, jnp.float32)
            l = jnp.zeros((B, KV, G, cq), jnp.float32)
            acc = jnp.zeros((B, KV, G, cq, hd), jnp.float32)

            def band_step(carry, off):
                m, l, acc = carry
                # kv chunk index = qi_chunk_in_kv - off, clamped; mask handles dups
                base = (qi * cq) // ck
                kj = jnp.maximum(base - off, 0)
                k_blk = lax.dynamic_slice_in_dim(k, kj * ck, ck, axis=1)
                v_blk = lax.dynamic_slice_in_dim(v, kj * ck, ck, axis=1)
                kj_pos = (jnp.arange(ck)[None] + kj * ck)
                # drop duplicate clamped chunks: only off==base-kj is valid
                valid = (base - off) >= 0
                m2, l2, a2 = block(qi_pos, kj_pos, q_blk, k_blk, v_blk, m, l, acc)
                m, l, acc = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(valid, new, old), (m2, l2, a2), (m, l, acc))
                return (m, l, acc), None

            (m, l, acc), _ = lax.scan(band_step, (m, l, acc), jnp.arange(nband))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            return None, out.astype(dt)

        if getattr(cfg, "remat_inner", True):
            q_step = jax.checkpoint(q_step)
        _, outs = lax.scan(q_step, None, jnp.arange(nq))  # (nq,B,KV,G,cq,hd)
    elif causal and cfg.attn_impl == "tri":
        # triangle-packed: iterate lower-triangle block pairs, row-major
        qis, kjs = [], []
        for i in range(nq):
            hi = ((i + 1) * cq + ck - 1) // ck  # kv chunks covering <= q end
            for j in range(min(hi, nk)):
                qis.append(i)
                kjs.append(j)
        qis = jnp.array(qis, jnp.int32)
        kjs = jnp.array(kjs, jnp.int32)
        m0 = jnp.full((nq, B, KV, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((nq, B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((nq, B, KV, G, cq, hd), jnp.float32)

        def tri_step(carry, ij):
            m_all, l_all, a_all = carry
            qi, kj = ij
            q_blk = lax.dynamic_index_in_dim(qc, qi, 0, keepdims=False)
            k_blk = lax.dynamic_slice_in_dim(k, kj * ck, ck, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, kj * ck, ck, axis=1)
            qi_pos = jnp.arange(cq)[None] + qi * cq
            kj_pos = jnp.arange(ck)[None] + kj * ck
            m = lax.dynamic_index_in_dim(m_all, qi, 0, keepdims=False)
            l = lax.dynamic_index_in_dim(l_all, qi, 0, keepdims=False)
            acc = lax.dynamic_index_in_dim(a_all, qi, 0, keepdims=False)
            m, l, acc = block(qi_pos, kj_pos, q_blk, k_blk, v_blk, m, l, acc)
            m_all = lax.dynamic_update_index_in_dim(m_all, m, qi, 0)
            l_all = lax.dynamic_update_index_in_dim(l_all, l, qi, 0)
            a_all = lax.dynamic_update_index_in_dim(a_all, acc, qi, 0)
            return (m_all, l_all, a_all), None

        if getattr(cfg, "remat_inner", True):
            tri_step = jax.checkpoint(tri_step)
        (m_all, l_all, a_all), _ = lax.scan(tri_step, (m0, l0, a0), (qis, kjs))
        outs = (a_all / jnp.maximum(l_all[..., None], 1e-30)).astype(dt)
    else:
        # rectangle schedule: every q chunk scans all kv chunks with masking
        def q_step(_, qi):
            q_blk = qc[qi]
            qi_pos = jnp.arange(cq)[None] + qi * cq
            m = jnp.full((B, KV, G, cq), -1e30, jnp.float32)
            l = jnp.zeros((B, KV, G, cq), jnp.float32)
            acc = jnp.zeros((B, KV, G, cq, hd), jnp.float32)

            def kv_step(carry, kj):
                m, l, acc = carry
                k_blk = lax.dynamic_slice_in_dim(k, kj * ck, ck, axis=1)
                v_blk = lax.dynamic_slice_in_dim(v, kj * ck, ck, axis=1)
                kj_pos = jnp.arange(ck)[None] + kj * ck
                m, l, acc = block(qi_pos, kj_pos, q_blk, k_blk, v_blk, m, l, acc)
                return (m, l, acc), None

            (m, l, acc), _ = lax.scan(kv_step, (m, l, acc), jnp.arange(nk))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            return None, out.astype(dt)

        if getattr(cfg, "remat_inner", True):
            q_step = jax.checkpoint(q_step)
        _, outs = lax.scan(q_step, None, jnp.arange(nq))

    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, -1)  # (B,S,H*hd)
    return out @ p["wo"]


def attention_flash(cfg: ModelConfig, p, x, *, causal=True, window=None,
                    positions=None):
    """Pallas flash-attention kernel on the prefill/forward hot path.

    ``attn_impl="flash"`` runs ``repro.kernels.flash_attention`` (interpret
    mode off-TPU, so the serving path is testable on CPU);
    ``attn_impl="flash-ref"`` runs its jnp oracle.  Mask positions are
    sequence-local 0..S-1 (same assumption as the chunked tri/rect
    schedules); ``positions`` feeds RoPE only.
    """
    from repro.kernels.flash_attention.ops import flash_attention
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.pos_type == "rope":
        q = apply_rope(q.reshape(B, S, -1, hd), positions, cfg.rope_theta).reshape(q.shape)
        k = apply_rope(k, positions, cfg.rope_theta)
    qh = q.reshape(B, S, -1, hd).transpose(0, 2, 1, 3)   # (B, H, S, hd)
    kh = k.transpose(0, 2, 1, 3)                         # (B, KV, S, hd)
    vh = v.transpose(0, 2, 1, 3)
    impl = "ref" if cfg.attn_impl == "flash-ref" else "pallas"
    out = flash_attention(qh, kh, vh, causal=causal, window=window,
                          impl=impl)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)    # (B, S, H*hd)
    return out @ p["wo"]


def attention_apply(cfg: ModelConfig, p, x, *, causal=True, window=None,
                    positions=None, kv_x=None):
    """Dispatch plain vs chunked vs Pallas-flash by config / seq length."""
    S = x.shape[1]
    impl = cfg.attn_impl
    if kv_x is not None or not causal:
        return attention_plain(cfg, p, x, causal=causal, window=window,
                               positions=positions, kv_x=kv_x)
    if impl in ("flash", "flash-ref"):
        if S % min(128, S) == 0:  # kernel block divisibility
            return attention_flash(cfg, p, x, causal=causal, window=window,
                                   positions=positions)
        return attention_plain(cfg, p, x, causal=causal, window=window,
                               positions=positions)
    if impl == "plain" or (impl == "auto" and S <= 4096 and window is None):
        return attention_plain(cfg, p, x, causal=causal, window=window,
                               positions=positions)
    if S % min(cfg.attn_chunk_q, S) != 0:
        return attention_plain(cfg, p, x, causal=causal, window=window,
                               positions=positions)
    return attention_chunked(cfg, p, x, causal=causal, window=window,
                             positions=positions)


# ---------------------------------------------------------------- decode


def attention_decode(cfg: ModelConfig, p, x1, cache, pos, *, window=None,
                     cross_kv=None):
    """One-token decode against a KV cache.

    cache: {"k": (B, L, KV, hd), "v": (B, L, KV, hd)}; L = full seq for global
    layers, ring size for sliding-window layers.  Keys are stored post-RoPE.
    ``pos``: (B,) current position (0-based index of the new token).
    Returns (out (B,1,D), new_cache).
    """
    B = x1.shape[0]
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    q, k_new, v_new = _project_qkv(cfg, p, x1, None if cross_kv is None else x1)
    if cross_kv is not None:
        # cross-attention: static precomputed K/V, no cache update
        k, v = cross_kv["k"], cross_kv["v"]
        scores = jnp.einsum("bqcgh,bkch->bcgqk", q, k,
                            preferred_element_type=jnp.float32) / math.sqrt(hd)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bcgqk,bkch->bqcgh", probs.astype(v.dtype), v)
        return out.reshape(B, 1, -1) @ p["wo"], cache

    if cfg.pos_type == "rope":
        q = apply_rope(q.reshape(B, 1, -1, hd), pos[:, None], cfg.rope_theta).reshape(q.shape)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    L = cache["k"].shape[1]
    slot = pos % L if window is not None else jnp.minimum(pos, L - 1)
    # write new k/v at slot (per-batch dynamic scatter)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))

    if cfg.attn_impl in ("flash", "flash-ref") and window is None:
        # flash-decoding kernel: global layers keep a contiguous prefix
        # cache (slot s = position s), exactly the kernel's lengths
        # semantics.  Windowed ring buffers stay on the jnp path below.
        from repro.kernels.decode_attention.ops import decode_attention
        qd = q.reshape(B, -1, hd)                # (B, H, hd)
        kd = k_cache.transpose(0, 2, 1, 3)       # (B, KV, L, hd)
        vd = v_cache.transpose(0, 2, 1, 3)
        impl = "ref" if cfg.attn_impl == "flash-ref" else "pallas"
        out = decode_attention(qd, kd, vd, pos + 1, impl=impl)
        out = out.reshape(B, 1, -1) @ p["wo"]
        return out, {"k": k_cache, "v": v_cache}

    # validity: which cache slots hold tokens visible to this query
    slot_ids = jnp.arange(L)[None, :]  # (1, L)
    if window is None:
        valid = slot_ids <= pos[:, None]
    else:
        # ring buffer: slot s holds absolute position p' ≡ s (mod L), the
        # largest such p' ≤ pos; it is valid if pos - p' < window and p' ≥ 0
        delta = (pos[:, None] - slot_ids) % L  # age of entry in slots
        valid = (delta < jnp.minimum(window, pos[:, None] + 1))
    scores = jnp.einsum("bqcgh,bkch->bcgqk", q, k_cache,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bcgqk,bkch->bqcgh", probs.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------------
# MLP (dense)
# --------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "gelu":
        return {"w_in": _dense_init(ks[0], (D, F), dt),
                "b_in": jnp.zeros((F,), dt),
                "w_out": _dense_init(ks[1], (F, D), dt),
                "b_out": jnp.zeros((D,), dt)}
    return {"w_gate": _dense_init(ks[0], (D, F), dt),
            "w_up": _dense_init(ks[1], (D, F), dt),
            "w_down": _dense_init(ks[2], (F, D), dt)}


def apply_mlp(cfg: ModelConfig, p, x):
    if cfg.mlp_type == "gelu":
        h = jax.nn.gelu((x @ p["w_in"] + p["b_in"]).astype(jnp.float32)).astype(x.dtype)
        return h @ p["w_out"] + p["b_out"]
    g = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    return (g * (x @ p["w_up"])) @ p["w_down"]


# --------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-bounded scatter dispatch)
# --------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (D, E), jnp.float32),
        "w_gate": _dense_init(ks[1], (E, D, F), dt, fan_in=D),
        "w_up": _dense_init(ks[2], (E, D, F), dt, fan_in=D),
        "w_down": _dense_init(ks[3], (E, F, D), dt, fan_in=F),
    }


def _round_up(x, m):
    return (x + m - 1) // m * m


def moe_ffn_tokens(cfg: ModelConfig, p, x):
    """MoE over batched capacity groups x (B, T, D) -> (B, T, D), plus aux.

    Capacity-bounded scatter dispatch with *per-sequence groups* (GShard
    'groups' = the batch dim): every sequence dispatches into its own
    (E, C, D) buffer, so no data-dependent cross-shard movement exists and
    the SPMD partitioner keeps B on the data axis and E (or the expert FFN
    dim, when E doesn't divide the model axis) on the model axis.  Explicit
    shard_act constraints pin that layout — without them GSPMD replicates
    the expert compute across data shards (measured: 8-16x FLOP waste,
    see EXPERIMENTS.md §Perf).
    Tokens that overflow an expert's per-group capacity are dropped
    (contribute zero), standard GShard semantics.
    """
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, K)  # (B, T, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    C = _round_up(max(1, int(K * T / E * cfg.capacity_factor)), 8)
    C = min(C, T)
    # rank of each (token, slot) within its expert, flat (T*K) per sequence
    flat_e = topi.reshape(B, T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, T*K, E)
    ranks = jnp.take_along_axis(jnp.cumsum(onehot, axis=1),
                                flat_e[..., None], axis=2)[..., 0] - 1
    keep = ranks < C
    dst = jnp.where(keep, flat_e * C + ranks, E * C)  # (B, T*K); sentinel E*C

    x_rep = jnp.repeat(x, K, axis=1)  # (B, T*K, D)
    buf = jax.vmap(lambda xb, db: jnp.zeros((E * C + 1, D), x.dtype
                                            ).at[db].set(xb))(x_rep, dst)
    buf = buf[:, :E * C].reshape(B, E, C, D)
    buf = shard_act(buf, ("batch", "experts", None, None))

    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_act(h, ("batch", "experts", None, "ffn"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_buf = shard_act(out_buf, ("batch", "experts", None, None))
    out_flat = jnp.concatenate(
        [out_buf.reshape(B, E * C, D),
         jnp.zeros((B, 1, D), out_buf.dtype)], axis=1)

    gathered = jnp.take_along_axis(out_flat, dst[..., None], axis=1)
    out = jnp.sum(gathered.reshape(B, T, K, D)
                  * topv[..., None].astype(x.dtype), axis=2)

    # aux: load-balance loss (Switch) — mean fraction * mean prob per expert
    frac = jnp.mean(jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * imp)
    return out, aux


def apply_moe(cfg: ModelConfig, p, x):
    """x (B,S,D) -> (B,S,D); scanned over S-chunks to bound the dispatch
    buffers (capacity group = sequence x chunk)."""
    B, S, D = x.shape
    chunk = getattr(cfg, "moe_chunk", 8192)
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        return moe_ffn_tokens(cfg, p, x)
    nch = S // chunk
    xs = x.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)  # (nch,B,ck,D)

    def step(acc, xc):
        out, a = moe_ffn_tokens(cfg, p, xc)
        return acc + a, out

    if getattr(cfg, "remat_inner", True):
        step = jax.checkpoint(step)  # dispatch buffers recomputed in bwd
    aux, outs = lax.scan(step, jnp.float32(0.0), xs)
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, D)
    return out, aux / nch


# --------------------------------------------------------------------------
# Mamba-1 (selective scan)
# --------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig):
    D, di, ds, dr, dc = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.dt_rank, cfg.ssm_conv)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": _dense_init(ks[0], (D, 2 * di), dt),
        "conv_w": _dense_init(ks[1], (dc, di), dt, fan_in=dc),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": _dense_init(ks[2], (di, dr + 2 * ds), dt),
        "dt_proj": _dense_init(ks[3], (dr, di), dt),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, D), dt),
    }


def _mamba_gates(cfg, p, xr):
    """Common pre-scan computation: xr (B,S,di) -> dt, Bc, Cc (fp32)."""
    dr, ds = cfg.dt_rank, cfg.ssm_state
    dbc = (xr @ p["x_proj"]).astype(jnp.float32)  # (B,S,dr+2ds)
    dt_low, Bc, Cc = jnp.split(dbc, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    return dt, Bc, Cc  # (B,S,di), (B,S,ds), (B,S,ds)


def mamba_scan(cfg: ModelConfig, p, x, h0=None, conv0=None):
    """Full-sequence Mamba: x (B,S,D) -> (y (B,S,D), (h_final, conv_state)).

    Chunked along S (cfg.ssm_chunk): within-chunk associative scan in fp32,
    sequential carry across chunks — bounds the (B,ck,di,ds) intermediate.
    """
    B, S, D = x.shape
    di, ds, dc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = shard_act(x @ p["in_proj"], ("batch", None, "inner"))
    xr, z = jnp.split(xz, 2, axis=-1)  # (B,S,di) each
    xr = shard_act(xr, ("batch", None, "inner"))
    z = shard_act(z, ("batch", None, "inner"))

    # causal depthwise conv along S
    pad = jnp.zeros((B, dc - 1, di), xr.dtype) if conv0 is None else conv0.astype(xr.dtype)
    xp = jnp.concatenate([pad, xr], axis=1)  # (B, S+dc-1, di)
    conv_state = xp[:, -(dc - 1):, :] if dc > 1 else None
    xc = sum(xp[:, i:i + S, :] * p["conv_w"][i] for i in range(dc)) + p["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    xc = shard_act(xc, ("batch", None, "inner"))

    dt, Bc, Cc = _mamba_gates(cfg, p, xc)
    dt = shard_act(dt, ("batch", None, "inner"))
    A = -jnp.exp(p["A_log"])  # (di, ds)

    ck = min(cfg.ssm_chunk, S)
    xcf = xc.astype(jnp.float32)

    def run_chunk(h, dt_c, B_c, C_c, x_c):
        a = jnp.exp(dt_c[..., None] * A)  # (B,c,di,ds)
        b = (dt_c * x_c)[..., None] * B_c[:, :, None, :]  # (B,c,di,ds)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = lax.associative_scan(comb, (a, b), axis=1)
        h_all = a_cum * h[:, None] + b_cum  # (B,c,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", h_all, C_c)  # (B,c,di)
        y = y + p["D"] * x_c
        return h_all[:, -1], y

    def chunk_step(h, idx):
        sl = lambda a: lax.dynamic_slice_in_dim(a, idx * ck, ck, axis=1)
        return run_chunk(h, sl(dt), sl(Bc), sl(Cc), sl(xcf))

    if getattr(cfg, "remat_inner", True):
        # recompute the within-chunk associative scan in backward: drops the
        # per-chunk (B,ck,di,ds) stacks from 'saved' to 'transient'
        chunk_step = jax.checkpoint(chunk_step)

    h = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0
    n_main, tail = S // ck, S % ck
    if n_main:
        h, ys = lax.scan(chunk_step, h, jnp.arange(n_main))
        y_main = ys.transpose(1, 0, 2, 3).reshape(B, n_main * ck, di)
    else:
        y_main = jnp.zeros((B, 0, di), jnp.float32)
    if tail:
        sl = lambda a: a[:, n_main * ck:]
        h, y_tail = run_chunk(h, sl(dt), sl(Bc), sl(Cc), sl(xcf))
        y = jnp.concatenate([y_main, y_tail], axis=1)
    else:
        y = y_main
    y = shard_act(y, ("batch", None, "inner"))
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"], (h, conv_state)


def mamba_decode(cfg: ModelConfig, p, x1, state):
    """One-token Mamba step. state = {"h": (B,di,ds) fp32, "conv": (B,dc-1,di)}."""
    B = x1.shape[0]
    di, ds, dc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = x1 @ p["in_proj"]  # (B,1,2di)
    xr, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"].astype(xr.dtype), xr], axis=1)  # (B,dc,di)
    new_conv = window[:, 1:, :]
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]  # (B,di)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x1.dtype)[:, None, :]  # (B,1,di)

    dt, Bc, Cc = _mamba_gates(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)  # (B,di,ds)
    b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bc[:, 0, None, :]
    h = a * state["h"] + b  # (B,di,ds)
    y = jnp.einsum("bds,bs->bd", h, Cc[:, 0]) + p["D"] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None, :] * jax.nn.silu(z.astype(jnp.float32))).astype(x1.dtype)
    return y @ p["out_proj"], {"h": h, "conv": new_conv.astype(state["conv"].dtype)}
