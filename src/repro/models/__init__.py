from repro.models.config import LayerSpec, ModelConfig, ShapeCell, SHAPES, uniform_pattern
from repro.models import layers, lm
