"""Model configuration system.

Every assigned architecture (plus the paper's own backbones) is expressed as a
``ModelConfig``: a repeating *superblock* pattern of heterogeneous layers
(attention / Mamba, dense-FFN / MoE / no-FFN) scanned ``n_layers/len(pattern)``
times.  The scan keeps the HLO size O(superblock) instead of O(n_layers),
which matters both for TPU compile times and for activation rematerialization.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 128


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a superblock."""

    kind: str = "attn"  # "attn" | "mamba"
    window: Optional[int] = None  # sliding-window size; None = global attention
    ffn: str = "dense"  # "dense" | "moe" | "none"

    def __post_init__(self):
        assert self.kind in ("attn", "mamba"), self.kind
        assert self.ffn in ("dense", "moe", "none"), self.ffn


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # ssm | moe | vlm | dense | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba-1) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0  # 0 = decoder-only
    encoder_len: int = 0  # stub modality frontend sequence length
    # --- VLM prefix stub (internvl2) ---
    num_prefix_embeds: int = 0
    # --- misc ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    norm_type: str = "rms"  # "rms" | "ln"
    pos_type: str = "rope"  # "rope" | "sinusoidal"
    mlp_type: str = "swiglu"  # "swiglu" | "gelu"
    moe_chunk: int = 8192  # token-chunk for MoE dispatch (0 = off)
    # implementation switches (perf levers; see EXPERIMENTS.md §Perf)
    # "plain" | "chunked" | "auto" | "tri" | "flash" (Pallas kernels,
    # interpret mode off-TPU) | "flash-ref" (their jnp oracles)
    attn_impl: str = "auto"
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    swa_banded: bool = True  # skip KV chunks fully outside a sliding window
    ssm_chunk: int = 256
    remat_policy: str = "full"  # "full" | "dots" | "none"
    remat_inner: bool = True  # remat inside chunk scans (mamba/moe/attn)
    loss_chunk: int = 1024  # CE loss sequence-chunking (0 = off)
    scan_layers: bool = True
    source: str = ""  # provenance note ([arXiv/hf; tier])

    # ---------------------------------------------------------------- helpers
    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"superblock size {len(self.pattern)}"
        )
        if any(s.ffn == "moe" for s in self.pattern):
            assert self.n_experts > 0 and self.top_k > 0, self.name

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        v = self.vocab_size
        m = VOCAB_PAD_MULTIPLE
        return (v + m - 1) // m * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return (self.d_model + 15) // 16

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_specs(self):
        """Full per-layer spec list (pattern repeated)."""
        return list(self.pattern) * self.n_superblocks

    # ------------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for roofline)."""
        D, V = self.d_model, self.padded_vocab
        hd, H, KV = self.resolved_head_dim, self.n_heads, self.n_kv_heads
        n = V * D  # embedding (tied head)
        if not self.tie_embeddings:
            n += V * D
        for spec in self.layer_specs():
            n += D  # pre-norm
            if spec.kind == "attn":
                n += D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
                if self.qkv_bias:
                    n += H * hd + 2 * KV * hd
            else:  # mamba
                di, ds, dr = self.d_inner, self.ssm_state, self.dt_rank
                n += D * 2 * di + self.ssm_conv * di + di  # in_proj, conv
                n += di * (dr + 2 * ds) + dr * di + di  # x_proj, dt_proj(+bias)
                n += di * ds + di  # A_log, D
                n += di * D  # out_proj
            if spec.ffn == "dense":
                n += D + 3 * D * self.d_ff  # norm + swiglu
            elif spec.ffn == "moe":
                n += D + D * self.n_experts  # norm + router
                n += self.n_experts * 3 * D * self.d_ff
        n += D  # final norm
        if self.is_encdec:
            # encoder layers: attn + dense ffn + norms; cross-attn in decoder
            enc = self.encoder_layers * (
                2 * D + D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D + 3 * D * self.d_ff
            )
            # decoder cross-attention blocks (one per decoder layer)
            xattn = self.n_layers * (D + D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D)
            n += enc + xattn + D  # + encoder final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        full_moe = moe_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active_moe = moe_layers * self.top_k * 3 * self.d_model * self.d_ff
        return self.param_count() - full_moe + active_moe

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def uniform_pattern(kind="attn", window=None, ffn="dense") -> Tuple[LayerSpec, ...]:
    return (LayerSpec(kind=kind, window=window, ffn=ffn),)


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
