"""Decoder LM / encoder-decoder built from superblock patterns with scan.

The layer stack is stored as *stacked* superblock params (leading dim
``n_superblocks``) and executed with ``lax.scan`` so HLO size and compile
time are O(superblock), not O(n_layers).  Remat is applied per superblock.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.api import shard_act
from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig

# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, with_xattn: bool):
    ks = jax.random.split(key, 6)
    p = {"norm": L.init_norm(cfg)}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["mamba"] = L.init_mamba(ks[0], cfg)
    if with_xattn:
        p["xattn_norm"] = L.init_norm(cfg)
        p["xattn"] = L.init_attention(ks[1], cfg, cross=True)
    if spec.ffn == "dense":
        p["ffn_norm"] = L.init_norm(cfg)
        p["ffn"] = L.init_mlp(ks[2], cfg)
    elif spec.ffn == "moe":
        p["ffn_norm"] = L.init_norm(cfg)
        p["moe"] = L.init_moe(ks[2], cfg)
    return p


def _init_superblock(key, cfg: ModelConfig, pattern, with_xattn: bool):
    ks = jax.random.split(key, len(pattern))
    return {f"l{i}": _init_layer(ks[i], cfg, spec, with_xattn)
            for i, spec in enumerate(pattern)}


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, kblocks, kenc, khead = jax.random.split(key, 4)
    D, Vp = cfg.d_model, cfg.padded_vocab
    table = (jax.random.normal(kemb, (Vp, D), jnp.float32) * (1.0 / math.sqrt(D))
             ).astype(cfg.dtype)
    params = {"embed": {"table": table}}
    sb_keys = jax.random.split(kblocks, cfg.n_superblocks)
    params["blocks"] = jax.vmap(
        lambda k: _init_superblock(k, cfg, cfg.pattern, cfg.is_encdec))(sb_keys)
    params["final_norm"] = L.init_norm(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": L._dense_init(khead, (Vp, D), jnp.dtype(cfg.dtype))}
    if cfg.is_encdec:
        enc_pattern = (LayerSpec(kind="attn", ffn="dense"),)
        enc_keys = jax.random.split(kenc, cfg.encoder_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_superblock(k, cfg, enc_pattern, False))(enc_keys)
        params["enc_final_norm"] = L.init_norm(cfg)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — dry-run stand-in, no allocation."""
    return jax.eval_shape(partial(init_params, cfg), jax.random.key(0))


# --------------------------------------------------------------------------
# logical axes for sharding
# --------------------------------------------------------------------------

_SUFFIX_AXES = {
    ("embed", "table"): ("vocab", "embed"),
    ("lm_head", "w"): ("vocab", "embed"),
    ("attn", "wq"): ("embed", "heads"),
    ("attn", "wk"): ("embed", "kv_heads"),
    ("attn", "wv"): ("embed", "kv_heads"),
    ("attn", "wo"): ("heads", "embed"),
    ("attn", "bq"): ("heads",),
    ("attn", "bk"): ("kv_heads",),
    ("attn", "bv"): ("kv_heads",),
    ("xattn", "wq"): ("embed", "heads"),
    ("xattn", "wk"): ("embed", "kv_heads"),
    ("xattn", "wv"): ("embed", "kv_heads"),
    ("xattn", "wo"): ("heads", "embed"),
    ("ffn", "w_gate"): ("embed", "ffn"),
    ("ffn", "w_up"): ("embed", "ffn"),
    ("ffn", "w_down"): ("ffn", "embed"),
    ("ffn", "w_in"): ("embed", "ffn"),
    ("ffn", "w_out"): ("ffn", "embed"),
    ("ffn", "b_in"): ("ffn",),
    ("ffn", "b_out"): (None,),
    ("moe", "router"): ("embed", None),
    ("moe", "w_gate"): ("experts", "embed", "ffn"),
    ("moe", "w_up"): ("experts", "embed", "ffn"),
    ("moe", "w_down"): ("experts", "ffn", "embed"),
    ("mamba", "in_proj"): ("embed", "inner"),
    ("mamba", "conv_w"): (None, "inner"),
    ("mamba", "conv_b"): ("inner",),
    ("mamba", "x_proj"): ("inner", None),
    ("mamba", "dt_proj"): (None, "inner"),
    ("mamba", "dt_bias"): ("inner",),
    ("mamba", "A_log"): ("inner", None),
    ("mamba", "D"): ("inner",),
    ("mamba", "out_proj"): ("inner", "embed"),
}


def param_logical_axes(cfg: ModelConfig):
    """Tree of logical-axis tuples matching init_params structure."""
    shapes = abstract_params(cfg)

    def assign(path: str, leaf):
        parts = path.split("/")
        stacked = parts[0] in ("blocks", "enc_blocks")
        key = tuple(parts[-2:])
        axes = _SUFFIX_AXES.get(key)
        if axes is None:  # norms, biases etc -> replicated
            axes = (None,) * (leaf.ndim - (1 if stacked else 0))
        if stacked:
            axes = (None,) + tuple(axes)
        assert len(axes) == leaf.ndim, (path, axes, leaf.shape)
        return tuple(axes)

    from repro.utils.tree import tree_map_with_path_str
    return tree_map_with_path_str(assign, shapes)


# --------------------------------------------------------------------------
# forward (train / full-sequence)
# --------------------------------------------------------------------------


def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p, h, positions,
                 enc_out=None):
    """One layer, full-sequence mode.  Returns (h, aux)."""
    aux = jnp.float32(0.0)
    hn = L.apply_norm(cfg, p["norm"], h)
    if spec.kind == "attn":
        attn_out = L.attention_apply(cfg, p["attn"], hn, causal=True,
                                     window=spec.window, positions=positions)
    else:
        attn_out, _ = L.mamba_scan(cfg, p["mamba"], hn)
    h = h + attn_out
    if "xattn" in p and enc_out is not None:
        hx = L.apply_norm(cfg, p["xattn_norm"], h)
        h = h + L.attention_plain(cfg, p["xattn"], hx, causal=False,
                                  kv_x=enc_out)
    if spec.ffn == "dense":
        hf = L.apply_norm(cfg, p["ffn_norm"], h)
        h = h + L.apply_mlp(cfg, p["ffn"], hf)
    elif spec.ffn == "moe":
        hf = L.apply_norm(cfg, p["ffn_norm"], h)
        out, a = L.apply_moe(cfg, p["moe"], hf)
        h = h + out
        aux = aux + a
    return h, aux


def _superblock_fwd(cfg: ModelConfig, sb_params, h, positions, enc_out=None):
    aux = jnp.float32(0.0)
    for i, spec in enumerate(cfg.pattern):
        h, a = _apply_layer(cfg, spec, sb_params[f"l{i}"], h, positions, enc_out)
        aux = aux + a
    return h, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _run_stack(cfg: ModelConfig, blocks, h, positions, enc_out=None,
               pattern=None):
    pattern = pattern if pattern is not None else cfg.pattern

    def body(carry, sb_params):
        h, aux = carry
        cfg_local = cfg if pattern is cfg.pattern else cfg.replace(pattern=pattern)
        h2, a = _superblock_fwd(cfg_local, sb_params, h, positions, enc_out)
        return (h2, aux + a), None

    body = _remat(cfg, body)
    if cfg.scan_layers:
        (h, aux), _ = lax.scan(body, (h, jnp.float32(0.0)), blocks)
    else:
        n = jax.tree_util.tree_leaves(blocks)[0].shape[0]
        carry = (h, jnp.float32(0.0))
        for i in range(n):
            sb = jax.tree_util.tree_map(lambda x: x[i], blocks)
            carry, _ = body(carry, sb)
        h, aux = carry
    return h, aux


def _embed_tokens(cfg: ModelConfig, params, tokens):
    return params["embed"]["table"][tokens]


def _logits(cfg: ModelConfig, params, h):
    h = L.apply_norm(cfg, params["final_norm"], h)
    table = params["lm_head"]["w"] if not cfg.tie_embeddings else params["embed"]["table"]
    logits = jnp.einsum("bsd,vd->bsv", h, table, preferred_element_type=jnp.float32)
    return shard_act(logits, ("batch", None, "vocab"))


def encode(cfg: ModelConfig, params, enc_frames):
    """Whisper-style encoder over stub frame embeddings (B, Senc, D)."""
    B, Senc, _ = enc_frames.shape
    pos = jnp.arange(Senc)[None, :]
    h = enc_frames + L.sinusoidal_positions(pos, cfg.d_model).astype(enc_frames.dtype)
    enc_pattern = (LayerSpec(kind="attn", ffn="dense"),)

    def body(carry, sb_params):
        h, _ = carry
        hn = L.apply_norm(cfg, sb_params["l0"]["norm"], h)
        h = h + L.attention_plain(cfg, sb_params["l0"]["attn"], hn, causal=False,
                                  rope=False)
        hf = L.apply_norm(cfg, sb_params["l0"]["ffn_norm"], h)
        h = h + L.apply_mlp(cfg, sb_params["l0"]["ffn"], hf)
        return (h, jnp.float32(0.0)), None

    (h, _), _ = lax.scan(_remat(cfg, body), (h, jnp.float32(0.0)),
                         params["enc_blocks"])
    return L.apply_norm(cfg, params["enc_final_norm"], h)


def forward_hidden(cfg: ModelConfig, params, tokens, prefix_embeds=None,
                   enc_frames=None):
    """Full-sequence forward up to the final hidden states -> (h, aux)."""
    h = _embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    if cfg.pos_type == "sinusoidal":
        h = h + L.sinusoidal_positions(jnp.arange(S)[None, :], cfg.d_model
                                       ).astype(h.dtype)
    h = shard_act(h, ("batch", None, None))
    positions = jnp.arange(S)[None, :]
    enc_out = encode(cfg, params, enc_frames) if cfg.is_encdec else None
    return _run_stack(cfg, params["blocks"], h, positions, enc_out)


def first_logits_select(cfg: ModelConfig, params, tokens, lens, token_ids):
    """Last-position logits for selected vocab ids only -> (B, T).

    The serving fast path for yes/no oracles: same hidden states, same
    final norm, and the same per-row dot products as ``forward`` + a
    last-position gather — only the (B, padded_vocab) float32
    materialization is skipped.  ``token_ids`` is (T,) shared across the
    batch or (B, T) per prompt; ``lens`` (B,) true prompt lengths.
    """
    h, _ = forward_hidden(cfg, params, tokens)
    hl = h[jnp.arange(h.shape[0]), lens - 1]           # (B, D)
    hl = L.apply_norm(cfg, params["final_norm"], hl)
    table = params["lm_head"]["w"] if not cfg.tie_embeddings else params["embed"]["table"]
    rows = table[token_ids]                            # (T, D) or (B, T, D)
    if rows.ndim == 3:
        return jnp.einsum("bd,btd->bt", hl, rows,
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bd,td->bt", hl, rows,
                      preferred_element_type=jnp.float32)


def forward(cfg: ModelConfig, params, tokens, prefix_embeds=None,
            enc_frames=None):
    """Full-sequence forward -> (logits (B,S,Vp), aux).

    - ``prefix_embeds`` (B, P, D): VLM stub — prepended to token embeddings;
      total sequence length = P + tokens.shape[1].
    - ``enc_frames`` (B, Senc, D): audio stub for enc-dec models.
    """
    h, aux = forward_hidden(cfg, params, tokens, prefix_embeds, enc_frames)
    return _logits(cfg, params, h), aux


# --------------------------------------------------------------------------
# KV cache + decode
# --------------------------------------------------------------------------


def _ring_len(cfg: ModelConfig, spec: LayerSpec, max_len: int) -> int:
    if spec.window is None:
        return max_len
    return min(spec.window, max_len)


def make_cache(cfg: ModelConfig, batch: int, max_len: int, kv_dtype=None):
    """Zero-initialized decode cache pytree (+ per-layer cross-attn slots)."""
    kv_dtype = kv_dtype or jnp.dtype(cfg.dtype)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nsb = cfg.n_superblocks
    cache = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            Lr = _ring_len(cfg, spec, max_len)
            entry = {"k": jnp.zeros((nsb, batch, Lr, KV, hd), kv_dtype),
                     "v": jnp.zeros((nsb, batch, Lr, KV, hd), kv_dtype)}
        else:
            entry = {"h": jnp.zeros((nsb, batch, cfg.d_inner, cfg.ssm_state),
                                    jnp.float32),
                     "conv": jnp.zeros((nsb, batch, cfg.ssm_conv - 1,
                                        cfg.d_inner), kv_dtype)}
        if cfg.is_encdec:
            entry["xk"] = jnp.zeros((nsb, batch, cfg.encoder_len, KV, hd), kv_dtype)
            entry["xv"] = jnp.zeros((nsb, batch, cfg.encoder_len, KV, hd), kv_dtype)
        cache[f"l{i}"] = entry
    return cache


def cache_logical_axes(cfg: ModelConfig, long_context: bool = False):
    """Logical axes tree matching make_cache structure."""
    seq_axis = "kv_seq_long" if long_context else "kv_seq"
    axes = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            entry = {"k": (None, "kv_batch", seq_axis, "kv_heads", None),
                     "v": (None, "kv_batch", seq_axis, "kv_heads", None)}
        else:
            entry = {"h": (None, "kv_batch", "inner", None),
                     "conv": (None, "kv_batch", None, "inner")}
        if cfg.is_encdec:
            entry["xk"] = (None, "kv_batch", None, "kv_heads", None)
            entry["xv"] = (None, "kv_batch", None, "kv_heads", None)
        axes[f"l{i}"] = entry
    return axes


def _apply_layer_decode(cfg: ModelConfig, spec: LayerSpec, p, c, h, pos):
    hn = L.apply_norm(cfg, p["norm"], h)
    if spec.kind == "attn":
        out, new_kv = L.attention_decode(
            cfg, p["attn"], hn, {"k": c["k"], "v": c["v"]}, pos,
            window=spec.window)
        c = dict(c, **new_kv)
    else:
        out, new_s = L.mamba_decode(cfg, p["mamba"], hn,
                                    {"h": c["h"], "conv": c["conv"]})
        c = dict(c, **new_s)
    h = h + out
    if "xattn" in p and "xk" in c:
        hx = L.apply_norm(cfg, p["xattn_norm"], h)
        out, _ = L.attention_decode(cfg, p["xattn"], hx, None, pos,
                                    cross_kv={"k": c["xk"], "v": c["xv"]})
        h = h + out
    if spec.ffn == "dense":
        hf = L.apply_norm(cfg, p["ffn_norm"], h)
        h = h + L.apply_mlp(cfg, p["ffn"], hf)
    elif spec.ffn == "moe":
        hf = L.apply_norm(cfg, p["ffn_norm"], h)
        out, _ = L.apply_moe(cfg, p["moe"], hf)
        h = h + out
    return h, c


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step.  tokens (B,) int32; pos (B,) 0-based position.

    Returns (logits (B, Vp), new_cache).
    """
    h = _embed_tokens(cfg, params, tokens[:, None])  # (B,1,D)
    if cfg.pos_type == "sinusoidal":
        h = h + L.sinusoidal_positions(pos[:, None], cfg.d_model).astype(h.dtype)

    def body(h, inp):
        sb_params, sb_cache = inp
        new_sb_cache = {}
        for i, spec in enumerate(cfg.pattern):
            h, new_sb_cache[f"l{i}"] = _apply_layer_decode(
                cfg, spec, sb_params[f"l{i}"], sb_cache[f"l{i}"], h, pos)
        return h, new_sb_cache

    h, new_cache = lax.scan(body, h, (params["blocks"], cache))
    logits = _logits(cfg, params, h)[:, 0]
    return logits, new_cache


# --------------------------------------------------------------------------
# prefill: full-sequence forward that also builds the decode cache
# --------------------------------------------------------------------------


def _project_kv_cache(cfg: ModelConfig, p, hn, positions, ring_len: int):
    """K/V for the whole sequence (post-RoPE), folded into a ring layout."""
    B, S, _ = hn.shape
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (hn @ p["wk"])
    v = (hn @ p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.pos_type == "rope":
        k = L.apply_rope(k, positions, cfg.rope_theta)
    if ring_len >= S:
        pad = ring_len - S
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return k, v
    # keep last ring_len positions at slot p % ring_len
    kl, vl = k[:, S - ring_len:], v[:, S - ring_len:]
    slots = jnp.arange(S - ring_len, S) % ring_len
    kc = jnp.zeros_like(kl).at[:, slots].set(kl)
    vc = jnp.zeros_like(vl).at[:, slots].set(vl)
    return kc, vc


def prefill(cfg: ModelConfig, params, tokens, prefix_embeds=None,
            enc_frames=None, max_len=None, last_only: bool = False):
    """Forward over a prompt, building the decode cache.

    Returns (logits, cache, next_pos (B,)); logits are (B,S,Vp), or (B,Vp)
    for the last position only when ``last_only`` (production serving never
    needs the full (B,S,V) tensor — see EXPERIMENTS.md §Perf round 1).
    """
    h = _embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    max_len = max_len or S
    if cfg.pos_type == "sinusoidal":
        h = h + L.sinusoidal_positions(jnp.arange(S)[None, :], cfg.d_model
                                       ).astype(h.dtype)
    positions = jnp.arange(S)[None, :]
    enc_out = encode(cfg, params, enc_frames) if cfg.is_encdec else None

    def body(carry, sb_params):
        h = carry
        sb_cache = {}
        for i, spec in enumerate(cfg.pattern):
            p = sb_params[f"l{i}"]
            entry = {}
            hn = L.apply_norm(cfg, p["norm"], h)
            if spec.kind == "attn":
                ring = _ring_len(cfg, spec, max_len)
                entry["k"], entry["v"] = _project_kv_cache(cfg, p["attn"], hn,
                                                           positions, ring)
                attn = L.attention_apply(cfg, p["attn"], hn, causal=True,
                                         window=spec.window, positions=positions)
                h = h + attn
            else:
                out, (hstate, conv) = L.mamba_scan(cfg, p["mamba"], hn)
                entry["h"], entry["conv"] = hstate, conv
                h = h + out
            if "xattn" in p and enc_out is not None:
                hx = L.apply_norm(cfg, p["xattn_norm"], h)
                h = h + L.attention_plain(cfg, p["xattn"], hx, causal=False,
                                          kv_x=enc_out)
                kx = (enc_out @ p["xattn"]["wk"]).reshape(
                    B, -1, cfg.n_kv_heads, cfg.resolved_head_dim)
                vx = (enc_out @ p["xattn"]["wv"]).reshape(
                    B, -1, cfg.n_kv_heads, cfg.resolved_head_dim)
                entry["xk"], entry["xv"] = kx, vx
            if spec.ffn == "dense":
                hf = L.apply_norm(cfg, p["ffn_norm"], h)
                h = h + L.apply_mlp(cfg, p["ffn"], hf)
            elif spec.ffn == "moe":
                hf = L.apply_norm(cfg, p["ffn_norm"], h)
                out, _ = L.apply_moe(cfg, p["moe"], hf)
                h = h + out
            sb_cache[f"l{i}"] = entry
        return h, sb_cache

    h, cache = lax.scan(_remat(cfg, body), h, params["blocks"])
    # pad ring caches to max_len layout conventions already handled above
    if last_only:
        logits = _logits(cfg, params, h[:, -1:])[:, 0]
    else:
        logits = _logits(cfg, params, h)
    next_pos = jnp.full((B,), S, jnp.int32)
    return logits, cache, next_pos
