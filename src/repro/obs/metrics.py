"""MetricsRegistry: one naming scheme over the stack's scattered stats.

The execution layers each kept their own counters — ``OracleStats`` (calls,
tokens, batch sizes), ``DispatchMergeStats`` (merged-dispatch fill/wall),
``ServiceStats`` (submit/defer/complete), ``ServingEngine.stats`` and
``BucketBatcher.stats`` (device batches, padding fill, truncation).  Those
dataclasses REMAIN the per-object accounting of record (bit-compatibility:
nothing about their delta/clone/merge semantics changes); this registry is
the unified, exportable aggregate over them:

- live instrumentation (tracer-gated) bumps counters/histograms as a side
  effect of execution — ``oracle.calls``, ``engine.prefill_tokens``,
  ``memo.replays``, ``round.wall_s``, ...;
- ``sync_from`` absorbs a stats object through its ``metrics_view()``
  (added to each legacy dataclass) so end-of-run dumps carry the full
  unified picture even for counters with no live hook.

Three instrument kinds, all O(1) memory:

- ``Counter``: monotonically increasing float (calls, tokens).
- ``Gauge``: last-set value (fill ratios, means) + ``info`` string gauges
  (``kernel.attn_impl``) rendered Prometheus-style as ``name{value="x"} 1``.
- ``Histogram``: fixed bucket bounds; observations update per-bucket counts
  and count/sum/min/max only — 10k observations occupy exactly the same
  memory as 10 (asserted in tests/test_obs.py).

``NULL_REGISTRY`` is the disabled no-op twin the ``NullTracer`` exposes, so
hot paths publish unconditionally without branching.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

# default histogram bounds: log-ish spacing covering micro-batches (1-1e5
# ids) and sub-ms..minutes wall times once scaled; callers with a better
# idea pass bounds= at first observe()
DEFAULT_BOUNDS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                  50.0, 100.0, 500.0, 1000.0, 5000.0, 10000.0)


class Counter:
    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value: Any = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Bounded histogram: fixed buckets, O(1) per observation, O(1) memory."""

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")
    kind = "histogram"

    def __init__(self, name: str, bounds: Tuple[float, ...] = DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")
        self.counts = [0] * (len(self.bounds) + 1)  # +1 = +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # bisect: first bound >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Name -> instrument map with create-on-first-use accessors."""

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}
        self._info: Dict[str, str] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls(name, *args))
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    # ----------------------------------------------------------- accessors
    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = DEFAULT_BOUNDS) -> Histogram:
        return self._get(name, Histogram, bounds)

    # ---------------------------------------------------------- shorthands
    def inc(self, name: str, v: float = 1.0) -> None:
        self.counter(name).inc(v)

    def set(self, name: str, v) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float,
                bounds: Tuple[float, ...] = DEFAULT_BOUNDS) -> None:
        self.histogram(name, bounds).observe(v)

    def set_info(self, name: str, value: str) -> None:
        """String-valued gauge (Prometheus info idiom)."""
        with self._lock:
            self._info[name] = str(value)

    # -------------------------------------------------------------- absorb
    def sync_from(self, *stats_objects, prefix: str = "") -> None:
        """Absorb legacy stats dataclasses through their ``metrics_view()``:
        counters/gauges land under the unified names (counter values are
        SET, not added — a view reflects the object's current totals)."""
        for obj in stats_objects:
            if obj is None:
                continue
            view = obj.metrics_view() if hasattr(obj, "metrics_view") \
                else dict(obj)
            for name, value in view.items():
                full = prefix + name
                if isinstance(value, str):
                    self.set_info(full, value)
                elif name.endswith(tuple(_GAUGE_SUFFIXES)):
                    self.set(full, float(value))
                else:
                    self.counter(full).value = float(value)

    # -------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view (JSON-ready) of every instrument."""
        with self._lock:
            metrics = dict(self._metrics)
            info = dict(self._info)
        out: Dict[str, Any] = {}
        for name, m in sorted(metrics.items()):
            if isinstance(m, Histogram):
                out[name] = {
                    "count": m.count, "sum": m.sum, "mean": m.mean,
                    "min": (None if m.count == 0 else m.min),
                    "max": (None if m.count == 0 else m.max),
                    "buckets": dict(zip([*map(str, m.bounds), "+Inf"],
                                        m.counts))}
            else:
                out[name] = m.value
        for name, v in sorted(info.items()):
            out[name] = v
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (names sanitized ``.`` -> ``_``)."""
        from repro.obs.export import registry_to_prometheus
        return registry_to_prometheus(self)

    def _iter_instruments(self) -> Iterable:
        with self._lock:
            yield from sorted(self._metrics.items())

    def _iter_info(self) -> List[Tuple[str, str]]:
        with self._lock:
            return sorted(self._info.items())


# names carrying a point-in-time reading (means/ratios/rates) sync as gauges
_GAUGE_SUFFIXES = ("_ratio", "_per_s", "mean_batch_size", "merge_factor",
                   "fill", "last_invocation", "last_wall_s")


class NullRegistry:
    """No-op registry: the disabled-observability fast path."""

    enabled = False

    def counter(self, name):
        return _NULL_INSTRUMENT

    def gauge(self, name):
        return _NULL_INSTRUMENT

    def histogram(self, name, bounds=DEFAULT_BOUNDS):
        return _NULL_INSTRUMENT

    def inc(self, name, v=1.0):
        pass

    def set(self, name, v):
        pass

    def observe(self, name, v, bounds=DEFAULT_BOUNDS):
        pass

    def set_info(self, name, value):
        pass

    def sync_from(self, *stats_objects, prefix=""):
        pass

    def snapshot(self):
        return {}

    def to_prometheus(self):
        return ""


class _Null:
    __slots__ = ()

    def inc(self, v=1.0):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


_NULL_INSTRUMENT = _Null()
NULL_REGISTRY = NullRegistry()
