"""Health monitors: declarative alert rules over the metrics registry.

A ``HealthRule`` is one threshold over the registry snapshot — either a
dotted metric name (histograms resolve to their mean) or an arbitrary
``value_fn`` deriving a number from the whole snapshot (ratios, deltas).
``HealthMonitor.evaluate()`` runs every rule and routes **edge-triggered**
alerts to pluggable sinks: a rule fires exactly once when it crosses into
breach, stays silent while the breach persists, and emits one ``recover``
alert when it crosses back — so a flapping metric cannot flood the sinks.

The monitor is evaluated from the hot loops' natural heartbeat — the
service scheduler's barrier tick and the stream watcher's tick — through
the ambient ``get_monitor()`` hook, whose default is a no-op null monitor
(same pattern as ``repro.obs.trace.get_tracer``): an uninstrumented run
pays one module-global read per tick and nothing else.

Critical alerts additionally invoke ``on_critical`` (the flight recorder
registers its dump there; docs/observability.md).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.utils.timing import monotonic

SEVERITIES = ("info", "warning", "critical")


@dataclasses.dataclass
class Alert:
    """One edge-triggered rule transition (breach or recovery)."""
    rule: str
    severity: str
    kind: str                  # "breach" | "recover"
    value: Optional[float]
    threshold: float
    message: str
    wall_time: float

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        v = "n/a" if self.value is None else f"{self.value:g}"
        return (f"[{self.severity}] {self.rule} {self.kind}: value={v} "
                f"threshold={self.threshold:g} — {self.message}")


def _metric_value(snapshot: Dict[str, Any], name: str) -> Optional[float]:
    """Scalar view of one snapshot entry; histograms read as their mean."""
    v = snapshot.get(name)
    if v is None:
        return None
    if isinstance(v, dict):
        v = v.get("mean")
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def ratio(num: str, den: Sequence[str]) -> Callable[[Dict[str, Any]],
                                                    Optional[float]]:
    """value_fn: ``num / sum(den)`` over the snapshot; None until den > 0."""
    def fn(snapshot: Dict[str, Any]) -> Optional[float]:
        n = _metric_value(snapshot, num)
        d = sum(_metric_value(snapshot, k) or 0.0 for k in den)
        if n is None or d <= 0:
            return None
        return n / d
    return fn


def counter_delta(total: str, mark: str) -> Callable[[Dict[str, Any]],
                                                     Optional[float]]:
    """value_fn: ``total - mark`` (e.g. WAL bytes since last compaction)."""
    def fn(snapshot: Dict[str, Any]) -> Optional[float]:
        t = _metric_value(snapshot, total)
        if t is None:
            return None
        return t - (_metric_value(snapshot, mark) or 0.0)
    return fn


@dataclasses.dataclass
class HealthRule:
    """One declarative threshold.

    metric: dotted registry name (histograms -> mean), or None when
    ``value_fn`` derives the value from the full snapshot.  ``op`` is the
    breach direction: ``">"`` fires when value > threshold, ``"<"`` when
    value < threshold.  A rule whose value is unavailable (metric absent,
    denominator zero, fewer than ``min_count`` histogram observations)
    never fires.
    """
    name: str
    threshold: float
    metric: Optional[str] = None
    value_fn: Optional[Callable[[Dict[str, Any]], Optional[float]]] = None
    op: str = ">"
    severity: str = "warning"
    message: str = ""
    min_count: int = 0         # histogram metrics: required observations

    def __post_init__(self):
        if (self.metric is None) == (self.value_fn is None):
            raise ValueError(f"rule {self.name!r}: exactly one of metric/"
                             "value_fn must be set")
        if self.op not in (">", "<"):
            raise ValueError(f"rule {self.name!r}: op must be '>' or '<'")
        if self.severity not in SEVERITIES:
            raise ValueError(f"rule {self.name!r}: severity must be one of "
                             f"{SEVERITIES}")

    def current(self, snapshot: Dict[str, Any]) -> Optional[float]:
        if self.value_fn is not None:
            return self.value_fn(snapshot)
        raw = snapshot.get(self.metric)
        if (self.min_count and isinstance(raw, dict)
                and raw.get("count", 0) < self.min_count):
            return None
        return _metric_value(snapshot, self.metric)

    def breached(self, value: Optional[float]) -> bool:
        if value is None:
            return False
        return value > self.threshold if self.op == ">" else \
            value < self.threshold


# -------------------------------------------------------------- alert sinks
class LogAlertSink:
    """Prints alerts to stdout with an optional prefix (CLI default)."""

    def __init__(self, prefix: str = "[health]"):
        self.prefix = prefix

    def __call__(self, alert: Alert) -> None:
        print(f"{self.prefix} {alert}")


class JsonlAlertSink:
    """Appends one JSON object per alert to a file."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def __call__(self, alert: Alert) -> None:
        with self.path.open("a") as f:
            f.write(json.dumps(alert.to_dict(), sort_keys=True) + "\n")


class CallbackAlertSink:
    """Routes alerts to an arbitrary callable (tests, pagers, queues)."""

    def __init__(self, fn: Callable[[Alert], None]):
        self.fn = fn

    def __call__(self, alert: Alert) -> None:
        self.fn(alert)


# ------------------------------------------------------------- the monitor
class HealthMonitor:
    """Evaluates rules over one registry and routes edge-triggered alerts."""

    enabled = True

    def __init__(self, registry, rules: Sequence[HealthRule] = (),
                 sinks: Sequence[Callable[[Alert], None]] = (),
                 min_interval_s: float = 1.0,
                 on_critical: Optional[Callable[[Alert], None]] = None,
                 recent_capacity: int = 64):
        self.registry = registry
        self.rules: List[HealthRule] = list(rules)
        self.sinks: List[Callable[[Alert], None]] = list(sinks)
        self.min_interval_s = float(min_interval_s)
        self.on_critical = on_critical
        self._firing: Dict[str, bool] = {}
        self._recent: deque = deque(maxlen=recent_capacity)
        self._last_eval = float("-inf")
        self._lock = threading.Lock()

    # -------------------------------------------------------- configuration
    def add_rule(self, rule: HealthRule) -> "HealthMonitor":
        self.rules.append(rule)
        return self

    def add_sink(self, sink: Callable[[Alert], None]) -> "HealthMonitor":
        self.sinks.append(sink)
        return self

    # ------------------------------------------------------------ queries
    def firing(self) -> Dict[str, bool]:
        with self._lock:
            return dict(self._firing)

    def recent(self, n: int = 20) -> List[Alert]:
        with self._lock:
            return list(self._recent)[-n:]

    def status(self) -> Dict[str, Any]:
        """healthz view: overall status + what is firing right now."""
        firing = {k for k, v in self.firing().items() if v}
        sev = {r.name: r.severity for r in self.rules}
        critical = any(sev.get(name) == "critical" for name in firing)
        return {
            "status": ("critical" if critical
                       else "degraded" if firing else "ok"),
            "firing": sorted(firing),
            "rules": len(self.rules),
        }

    # ---------------------------------------------------------- evaluation
    def maybe_evaluate(self) -> List[Alert]:
        """Rate-limited evaluate() — the tick-loop entry point."""
        now = monotonic()
        with self._lock:
            if now - self._last_eval < self.min_interval_s:
                return []
            self._last_eval = now
        return self.evaluate()

    def evaluate(self) -> List[Alert]:
        snapshot = self.registry.snapshot()
        alerts: List[Alert] = []
        for rule in self.rules:
            value = rule.current(snapshot)
            breach = rule.breached(value)
            with self._lock:
                was = self._firing.get(rule.name, False)
                self._firing[rule.name] = breach
            if breach == was:
                continue  # edge-triggered: steady state is silent
            alert = Alert(
                rule=rule.name, severity=rule.severity,
                kind="breach" if breach else "recover", value=value,
                threshold=rule.threshold,
                message=rule.message or rule.name,
                wall_time=time.time())  # noqa: TID251 — operator-facing
            alerts.append(alert)
        if alerts:
            with self._lock:
                self._recent.extend(alerts)
            metrics = self.registry
            for alert in alerts:
                metrics.inc("health.alerts")
                for sink in self.sinks:
                    try:
                        sink(alert)
                    except Exception as e:  # a broken pager must not
                        print(f"[health] sink failed: {e!r}")  # kill ticks
                if (alert.kind == "breach" and alert.severity == "critical"
                        and self.on_critical is not None):
                    try:
                        self.on_critical(alert)
                    except Exception as e:
                        print(f"[health] on_critical failed: {e!r}")
        self.registry.inc("health.evaluations")
        return alerts


class _NullMonitor:
    """Ambient default: absorbs tick hooks at zero cost."""

    enabled = False
    rules: List[HealthRule] = []

    def maybe_evaluate(self):
        return []

    def evaluate(self):
        return []

    def recent(self, n: int = 20):
        return []

    def firing(self):
        return {}

    def status(self):
        return {"status": "ok", "firing": [], "rules": 0}


NULL_MONITOR = _NullMonitor()
_active = NULL_MONITOR


def get_monitor():
    return _active


def set_monitor(monitor) -> None:
    """Install the process-wide monitor (None restores the null default)."""
    global _active
    _active = monitor if monitor is not None else NULL_MONITOR


# ------------------------------------------------------------ default rules
def default_rules() -> List[HealthRule]:
    """The operational rule set the CLIs install (docs/observability.md).

    Thresholds are deliberately conservative defaults — every rule is a
    plain dataclass, so deployments tune or replace them freely.
    """
    return [
        HealthRule(
            name="vote-margin-collapse", metric="quality.vote_margin",
            op="<", threshold=0.02, min_count=8, severity="warning",
            message="mean cluster vote margin is hugging the decision "
                    "band; votes are barely decided"),
        HealthRule(
            name="memo-hit-rate-drop",
            value_fn=ratio("oracle.cached", ("oracle.calls",
                                             "oracle.cached")),
            op="<", threshold=0.05, severity="info",
            message="session memo is answering <5% of oracle traffic"),
        HealthRule(
            name="tenant-budget-burn",
            metric="service.tenant_budget_used_ratio",
            op=">", threshold=0.9, severity="critical",
            message="a tenant has burned >90% of its admission budget"),
        HealthRule(
            name="sink-dead-letters",
            value_fn=ratio("sink.dead_lettered", ("sink.delivered",
                                                  "sink.dead_lettered")),
            op=">", threshold=0.01, severity="critical",
            message="stream notifications are dead-lettering"),
        HealthRule(
            name="stream-tick-lag", metric="stream.tick_lag_rows",
            op=">", threshold=500.0, severity="warning",
            message="the stream source is deferring rows faster than "
                    "ticks drain them"),
        HealthRule(
            name="wal-growth",
            value_fn=counter_delta("log.bytes",
                                   "log.last_compaction_bytes"),
            op=">", threshold=float(16 << 20), severity="warning",
            message="session WAL grew >16 MiB since the last compaction"),
        HealthRule(
            name="stream-centroid-drift", metric="stream.centroid_drift",
            op=">", threshold=0.5, severity="warning",
            message="incoming rows have drifted from the frozen stream "
                    "centroids; consider reclustering"),
    ]
