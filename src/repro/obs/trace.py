"""Hierarchical tracing: end-to-end spans across every execution layer.

One query produces one span tree —

    query                      Query.collect (filter / join / baseline)
      plan_node                PlanExecutor per executed leaf
        round                  CSV driver re-clustering round (or join round)
          plan                 sample planning (RNG draws)
          oracle               oracle submit + wait (per wave)
          vote                 segmented voting dispatch + application
          partition            recluster-or-fallback tail
    dispatch_wave              QueryScheduler._run_wave (cross-query merge;
                               parented to the requesting oracle span)
      engine_tick              ServingEngine per bucketed device batch

Span ids are stable small integers assigned in creation order under one
lock, so a deterministic run yields a deterministic id assignment.  The
*current* span is thread-local (``contextvars``): spans opened on one
thread nest automatically; cross-thread edges (task thread -> scheduler
dispatch lane) are drawn explicitly by capturing ``tracer.current()`` into
the request and passing it as ``parent=``.

The module-global active tracer defaults to ``NULL_TRACER`` whose ``span``
is a no-op returning a shared singleton — instrumented hot paths pay one
attribute lookup and a no-op call when tracing is disabled, and notably
never build per-span state.  Enable with ``set_tracer(Tracer())`` or the
``use_tracer`` context manager.  Bit-identity: tracing only *observes*
(clocks + counters); it never touches an RNG stream, an oracle memo, or a
device dispatch, so traced and untraced runs produce identical masks and
call counts (asserted in tests/test_obs.py).
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.metrics import NULL_REGISTRY, MetricsRegistry
from repro.utils.timing import monotonic

_current: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


class Span:
    """One timed, attributed node of the trace tree."""

    __slots__ = ("span_id", "parent_id", "name", "kind", "t0", "t1",
                 "attrs", "thread")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 kind: str, attrs: Dict[str, Any]):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.kind = kind
        self.t0 = 0.0
        self.t1: Optional[float] = None
        self.attrs = attrs
        self.thread = threading.current_thread().name

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes (e.g. results known only at exit)."""
        self.attrs.update(attrs)
        return self

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else monotonic()) - self.t0

    def to_dict(self) -> Dict[str, Any]:
        return {"span_id": self.span_id, "parent_id": self.parent_id,
                "name": self.name, "kind": self.kind, "t0": self.t0,
                "dur_s": (None if self.t1 is None else self.t1 - self.t0),
                "thread": self.thread, "attrs": dict(self.attrs)}

    def __repr__(self):
        return (f"Span({self.span_id}, {self.name!r}, "
                f"parent={self.parent_id})")


class _SpanCtx:
    """Context manager entering/exiting one span (one per ``Tracer.span``)."""

    __slots__ = ("_tracer", "_span", "_token")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span
        self._token = None

    def __enter__(self) -> Span:
        self._span.t0 = monotonic()
        self._token = _current.set(self._span)
        return self._span

    def __exit__(self, *exc) -> bool:
        self._span.t1 = monotonic()
        _current.reset(self._token)
        return False


class _NullSpan:
    """Shared do-nothing span: the disabled-tracing fast path."""

    __slots__ = ()
    span_id = None
    parent_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every operation is a no-op (the ambient default).

    ``metrics`` is the no-op registry, so instrumented code can publish
    unconditionally (``tracer.metrics.inc(...)``) without branching."""

    enabled = False
    metrics = NULL_REGISTRY

    def span(self, name, kind: str = "span", parent=None, **attrs):
        return NULL_SPAN

    def current(self):
        return None

    def spans(self):
        return []


NULL_TRACER = NullTracer()


class Tracer:
    """Collecting tracer: builds the span tree and feeds a MetricsRegistry.

    Spans are appended (under a lock) at *entry*, so a crashed run still
    shows what was in flight (``t1 is None``).  ``epoch_wall``/``epoch_mono``
    pin the monotonic timeline to a wall-clock instant for exports.
    """

    enabled = True

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.epoch_wall = time.time()  # noqa: TID251 — wall anchor for export
        self.epoch_mono = monotonic()
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._next_id = 1

    def span(self, name: str, kind: str = "span", parent=None,
             **attrs) -> _SpanCtx:
        """Open a span as a context manager yielding the ``Span``.

        ``parent`` overrides the thread-local current span — the explicit
        cross-thread edge (scheduler wave -> requesting oracle span).  It
        accepts a ``Span``, a span id, or None (root).
        """
        if parent is None:
            cur = _current.get()
            parent_id = None if cur is None else cur.span_id
        elif isinstance(parent, Span):
            parent_id = parent.span_id
        else:
            parent_id = int(parent)
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            sp = Span(sid, parent_id, name, kind, attrs)
            self._spans.append(sp)
        return _SpanCtx(self, sp)

    def current(self) -> Optional[Span]:
        """The innermost open span on THIS thread (None outside any span)."""
        return _current.get()

    def spans(self) -> List[Span]:
        """Snapshot of all spans in creation order (open spans included)."""
        with self._lock:
            return list(self._spans)

    # ------------------------------------------------------------- export
    def export_jsonl(self, path) -> int:
        from repro.obs.export import write_spans_jsonl
        return write_spans_jsonl(self.spans(), path)

    def export_perfetto(self, path) -> int:
        from repro.obs.export import write_perfetto
        return write_perfetto(self.spans(), path, epoch_mono=self.epoch_mono)


# ------------------------------------------------------------ active tracer
_active: Any = NULL_TRACER


def get_tracer():
    """The ambient tracer every instrumented layer reads (one global so the
    CSV driver, engine, and scheduler threads all agree)."""
    return _active


def set_tracer(tracer) -> None:
    """Install ``tracer`` (or ``None``/``NULL_TRACER`` to disable)."""
    global _active
    _active = tracer if tracer is not None else NULL_TRACER


@contextlib.contextmanager
def use_tracer(tracer):
    """Scoped ``set_tracer``: restores the previous tracer on exit."""
    global _active
    prev = _active
    _active = tracer if tracer is not None else NULL_TRACER
    try:
        yield tracer
    finally:
        _active = prev
