"""Live status endpoints: /metrics, /healthz, /statusz, /varz.

Extends what used to be ``serve.py``'s bare Prometheus listener into a
small operational surface on the same port:

- ``/metrics`` — Prometheus text exposition (unchanged scrape target).
- ``/healthz`` — ``{"status": "ok"|"degraded"|"critical", ...}`` from the
  health monitor's firing set; HTTP 503 when a critical rule is firing,
  200 otherwise (load-balancer friendly).
- ``/statusz`` — one JSON document assembled from registered *providers*
  (in-flight queries, per-tenant budgets, tick rate, log generation/size,
  stream lag, recent alerts); append ``?format=html`` (or send
  ``Accept: text/html``) for a minimal human-readable page.
- ``/varz`` — the raw registry snapshot as JSON.

Providers are late-bound through a ``StatusHub`` so the server can start
before the service exists: ``serve.py`` boots the listener first, then the
service/watcher register their sections as they come up.  Every provider
call is defensive — a crashing section renders as an error string, never a
500.
"""
from __future__ import annotations

import html
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional

from repro.obs.export import _jsonable, registry_to_prometheus
from repro.utils.timing import monotonic


class StatusHub:
    """Late-bound data sources for the status endpoints."""

    def __init__(self, monitor=None, flight=None):
        self.monitor = monitor
        self.flight = flight
        self._providers: Dict[str, Callable[[], Any]] = {}
        self._lock = threading.Lock()
        self.started_wall = time.time()  # noqa: TID251 — operator-facing
        self._started_mono = monotonic()

    def add_provider(self, name: str, fn: Callable[[], Any]) -> "StatusHub":
        with self._lock:
            self._providers[name] = fn
        return self

    # ------------------------------------------------------------- views
    def healthz(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"status": "ok", "firing": [], "rules": 0}
        if self.monitor is not None:
            out.update(self.monitor.status())
        out["uptime_s"] = monotonic() - self._started_mono
        return out

    def statusz(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "uptime_s": monotonic() - self._started_mono,
            "started_wall": self.started_wall,
            "health": self.healthz(),
        }
        if self.monitor is not None:
            out["recent_alerts"] = [a.to_dict()
                                    for a in self.monitor.recent(20)]
        with self._lock:
            providers = dict(self._providers)
        for name, fn in sorted(providers.items()):
            try:
                out[name] = fn()
            except Exception as e:  # a dead section must not kill the page
                out[name] = {"error": repr(e)}
        return out


def _statusz_html(doc: Dict[str, Any]) -> str:
    """Minimal human-readable rendering of the statusz document."""
    health = doc.get("health", {})
    rows = "".join(
        f"<tr><td>{html.escape(str(k))}</td>"
        f"<td><pre>{html.escape(json.dumps(_jsonable(v), indent=2, sort_keys=True))}"
        f"</pre></td></tr>"
        for k, v in doc.items() if k != "health")
    return (
        "<!doctype html><html><head><title>statusz</title></head><body>"
        f"<h1>statusz — {html.escape(str(health.get('status', '?')))}</h1>"
        f"<p>uptime {doc.get('uptime_s', 0):.1f}s · firing: "
        f"{html.escape(', '.join(health.get('firing', [])) or 'none')}</p>"
        f"<table border=1 cellpadding=4>{rows}</table>"
        "<p><a href='/healthz'>/healthz</a> · <a href='/varz'>/varz</a> · "
        "<a href='/metrics'>/metrics</a></p>"
        "</body></html>")


def start_status_server(registry, port: int, host: str = "127.0.0.1",
                        hub: Optional[StatusHub] = None,
                        label: str = "status"):
    """Serve the status endpoints on a daemon thread; returns the server.

    ``port=0`` binds an ephemeral port (tests); the actual address is
    ``server.server_address``.  The bound address is logged exactly once.
    """
    hub = hub if hub is not None else StatusHub()

    class Handler(BaseHTTPRequestHandler):
        def _send(self, code: int, body: str, ctype: str) -> None:
            data = body.encode()
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def _send_json(self, code: int, doc: Any) -> None:
            self._send(code, json.dumps(_jsonable(doc), indent=2,
                                        sort_keys=True) + "\n",
                       "application/json")

        def do_GET(self):
            path, _, query = self.path.partition("?")
            if path in ("/", "/metrics"):
                self._send(200, registry_to_prometheus(registry),
                           "text/plain; version=0.0.4")
            elif path == "/healthz":
                doc = hub.healthz()
                code = 503 if doc.get("status") == "critical" else 200
                self._send_json(code, doc)
            elif path == "/varz":
                self._send_json(200, registry.snapshot())
            elif path == "/statusz":
                doc = hub.statusz()
                wants_html = ("format=html" in query
                              or "text/html" in self.headers.get("Accept",
                                                                 ""))
                if wants_html:
                    self._send(200, _statusz_html(doc), "text/html")
                else:
                    self._send_json(200, doc)
            else:
                self._send_json(404, {"error": f"unknown path {path!r}",
                                      "paths": ["/metrics", "/healthz",
                                                "/statusz", "/varz"]})

        def log_message(self, fmt, *args):  # silence per-request stderr spam
            pass

    srv = ThreadingHTTPServer((host, port), Handler)
    srv.hub = hub  # tests and callers reach the hub through the server
    bound_host, bound_port = srv.server_address[0], srv.server_address[1]
    threading.Thread(target=srv.serve_forever, daemon=True,
                     name="status-server").start()
    print(f"[{label}] status endpoints at http://{bound_host}:{bound_port}"
          "/statusz (/healthz /varz /metrics)")
    return srv
