"""Online quality auditing: does the xi/epsilon guarantee hold *right now*?

CSV's promise is statistical — sublinear oracle calls with a bounded error
rate — but nothing in the serving stack measured whether the guarantee
actually holds on a live workload.  ``ExecutionPolicy(audit_rate=...)``
opts a query into an online audit: after the voted mask is produced,
``audit_query_result`` draws a small **stratified, seeded** audit sample
(proportional across the query's clusters), labels it with the **real
oracle**, and compares against the CSV-voted labels.  The result is an
``AuditReport`` with Wilson-interval accuracy/precision/recall/F1
estimates, per-cluster disagreement rates, and the clusters whose observed
error breaches the configured bound (candidates for re-vote/re-cluster).

Isolation contract (the whole point of this module living in ``obs``):

- audit labeling never writes the oracle's memo, never touches
  ``oracle.stats``, and snapshots/restores the oracle's RNG stream (the
  synthetic flip stream) around its ``_evaluate`` call — so a run with
  auditing on produces **bit-identical masks and oracle-call counts** to
  the same run with auditing off, and every query that follows is
  unperturbed;
- audit spend is accounted only under ``audit.*`` metrics
  (``audit.calls``, ``audit.cached``, ``audit.input_tokens``) and the
  report itself — never ``oracle.*``;
- the audit sample is drawn from its own seeded stream
  (``[audit_seed, _AUDIT_STREAM]``), independent of the driver, pilot,
  and flip streams (same idiom as the executor's ``_PILOT_STREAM``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.trace import get_tracer

# independent seed-stream constant for the audit sampler (spawn-key idiom,
# like the executor's _PILOT_STREAM) — never shared with driver/pilot/flip
_AUDIT_STREAM = 0x5DEECE66
# clusters need at least this many audited rows before they can be flagged
MIN_CLUSTER_AUDIT = 5


def wilson_interval(k: int, n: int, z: float = 1.96):
    """Wilson score interval for a binomial proportion ``k/n``.

    Preferred over the normal approximation because it behaves at the
    boundaries (k=0, k=n) and at audit-sized n.  Returns ``(lo, hi)``;
    an empty sample is maximally uncertain: ``(0, 1)``.
    """
    if n <= 0:
        return 0.0, 1.0
    p = k / n
    denom = 1.0 + z * z / n
    center = (p + z * z / (2 * n)) / denom
    half = z * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n)) / denom
    return max(0.0, center - half), min(1.0, center + half)


def _f1(p: float, r: float) -> float:
    return 0.0 if p + r == 0 else 2 * p * r / (p + r)


@dataclasses.dataclass
class AuditReport:
    """Outcome of one online audit (``QueryResult.audit_report()``)."""
    n_rows: int                 # table rows the query decided
    n_audited: int              # stratified audit sample size
    n_agree: int                # audited rows where voted == oracle label
    n_fresh_calls: int          # oracle rows labeled fresh (audit.calls)
    n_memo_hits: int            # audited rows answered from the oracle memo
    input_tokens: int           # audit-only token spend
    error_bound: float          # tolerated disagreement rate (xi-bound)
    accuracy: float
    accuracy_lo: float
    accuracy_hi: float
    precision: float
    precision_lo: float
    precision_hi: float
    recall: float
    recall_lo: float
    recall_hi: float
    f1: float
    f1_lo: float
    f1_hi: float
    # clusters whose audited disagreement rate exceeds error_bound (with
    # >= MIN_CLUSTER_AUDIT audited rows): candidates for re-vote/re-cluster
    flagged_clusters: List[Dict[str, Any]]
    sample_ids: np.ndarray      # the audited row ids (seeded, reproducible)

    @property
    def breached(self) -> bool:
        """True when the audit is *confident* the guarantee is violated:
        even the optimistic end of the accuracy interval falls below
        ``1 - error_bound``, or a specific cluster breached the bound."""
        return (self.accuracy_hi < 1.0 - self.error_bound
                or bool(self.flagged_clusters))

    def __str__(self) -> str:
        lines = [
            f"AuditReport  n={self.n_audited}/{self.n_rows} audited  "
            f"calls={self.n_fresh_calls} (+{self.n_memo_hits} memo)  "
            f"bound={self.error_bound:g}  "
            f"{'BREACH' if self.breached else 'ok'}",
            f"  accuracy  {self.accuracy:.3f}  "
            f"[{self.accuracy_lo:.3f}, {self.accuracy_hi:.3f}]",
            f"  precision {self.precision:.3f}  "
            f"[{self.precision_lo:.3f}, {self.precision_hi:.3f}]",
            f"  recall    {self.recall:.3f}  "
            f"[{self.recall_lo:.3f}, {self.recall_hi:.3f}]",
            f"  f1        {self.f1:.3f}  "
            f"[{self.f1_lo:.3f}, {self.f1_hi:.3f}]",
        ]
        for fc in self.flagged_clusters:
            lines.append(
                f"  cluster {fc['cluster']}: {fc['disagree']}/{fc['n']} "
                f"disagree (rate {fc['rate']:.3f}) -> re-vote candidate")
        return "\n".join(lines)


# ------------------------------------------------------------ oracle side
def audit_labels(oracle, ids: np.ndarray):
    """Label ``ids`` with the real oracle **without perturbing it**.

    Memoized rows are answered from ``oracle._memo`` (the durable decision
    the query already paid for); the rest go through ``_evaluate`` directly
    — bypassing ``__call__`` so neither the memo nor ``oracle.stats`` move
    — with the oracle's RNG stream (synthetic flip noise) snapshotted and
    restored around the call.  Returns ``(labels, n_fresh, n_memo, tokens)``.
    """
    ids = np.asarray(ids, dtype=np.int64)
    out = np.zeros(len(ids), dtype=bool)
    memo = getattr(oracle, "_memo", {})
    missing: List[int] = []
    missing_pos: List[int] = []
    hits = 0
    for pos, i in enumerate(ids):
        v = memo.get(int(i))
        if v is None:
            missing.append(int(i))
            missing_pos.append(pos)
        else:
            out[pos] = v
            hits += 1
    tokens = 0
    if missing:
        mids = np.asarray(missing, dtype=np.int64)
        rng = getattr(oracle, "rng", None)
        state = rng.bit_generator.state if rng is not None else None
        try:
            labels = np.asarray(oracle._evaluate(mids), dtype=bool)
        finally:
            if state is not None:
                rng.bit_generator.state = state
        out[np.asarray(missing_pos, dtype=np.int64)] = labels
        try:
            tokens = int(oracle._tokens_of(mids))
        except Exception:
            tokens = 0
    return out, len(missing), hits, tokens


def _eval_expr(expr, leaf_labels: Dict[str, np.ndarray]) -> np.ndarray:
    """Ground-truth composition of the query expression over per-leaf
    oracle labels (the logical semantics the cascade implements)."""
    # lazy import: repro.plan transitively imports repro.core, which
    # imports repro.obs — a module-level import here would be circular
    from repro.plan.expr import And, Not, Or, Pred
    if isinstance(expr, Pred):
        return leaf_labels[expr.name]
    if isinstance(expr, Not):
        return ~_eval_expr(expr.child, leaf_labels)
    if isinstance(expr, And):
        out = _eval_expr(expr.children[0], leaf_labels)
        for c in expr.children[1:]:
            out = out & _eval_expr(c, leaf_labels)
        return out
    if isinstance(expr, Or):
        out = _eval_expr(expr.children[0], leaf_labels)
        for c in expr.children[1:]:
            out = out | _eval_expr(c, leaf_labels)
        return out
    raise TypeError(f"cannot audit expression node {type(expr).__name__}")


# ------------------------------------------------------------- the auditor
def stratified_sample(assign: np.ndarray, rate: float, max_rows: int,
                      seed: int) -> np.ndarray:
    """Proportional per-cluster draw from an independent seeded stream.

    Every non-empty cluster contributes at least one row (so small
    clusters — where CSV's vote is weakest — are always represented);
    allocation is otherwise proportional to cluster size, capped at
    ``max_rows`` total.
    """
    n = len(assign)
    target = min(max_rows, max(1, int(math.ceil(rate * n))))
    rng = np.random.default_rng([seed, _AUDIT_STREAM])
    picks: List[np.ndarray] = []
    for c in np.unique(assign):
        ids = np.nonzero(assign == c)[0]
        k = min(len(ids), max(1, int(round(target * len(ids) / n))))
        picks.append(ids[rng.choice(len(ids), size=k, replace=False)])
    sample = np.unique(np.concatenate(picks))
    if len(sample) > max_rows:
        sample = sample[rng.choice(len(sample), size=max_rows,
                                   replace=False)]
        sample = np.sort(sample)
    return sample


def audit_query_result(handle, expr, pol,
                       mask: np.ndarray) -> Optional[AuditReport]:
    """Run the online audit for one collected filter query.

    Draws the stratified sample over ``handle``'s clustering (the same
    ``(n_clusters, seed)`` partition the driver used), labels it per leaf
    via :func:`audit_labels`, composes ground truth through the expression,
    and scores the voted ``mask`` against it.  Emits ``audit.*`` /
    ``quality.*`` metrics on the ambient tracer's registry.
    """
    n = len(mask)
    if n == 0 or pol.audit_rate <= 0.0:
        return None
    assign = np.asarray(handle.precluster(pol.n_clusters, pol.seed))
    sample = stratified_sample(assign, pol.audit_rate, pol.audit_max_rows,
                               pol.audit_seed)
    # ---- ground truth per leaf, composed through the expression ----
    leaf_labels: Dict[str, np.ndarray] = {}
    n_fresh = n_memo = tokens = 0
    for leaf in expr.leaves():
        if leaf.name in leaf_labels:
            continue
        labels, fresh, hits, tok = audit_labels(leaf.oracle, sample)
        leaf_labels[leaf.name] = labels
        n_fresh += fresh
        n_memo += hits
        tokens += tok
    truth = _eval_expr(expr, leaf_labels)
    voted = np.asarray(mask, dtype=bool)[sample]
    agree = voted == truth
    k, m = int(agree.sum()), len(sample)
    acc = k / m
    acc_lo, acc_hi = wilson_interval(k, m)
    # ---- precision/recall/F1 against the audited ground truth ----
    tp = int(np.sum(voted & truth))
    fp = int(np.sum(voted & ~truth))
    fn = int(np.sum(~voted & truth))
    prec = tp / (tp + fp) if tp + fp else 1.0
    rec = tp / (tp + fn) if tp + fn else 1.0
    p_lo, p_hi = wilson_interval(tp, tp + fp) if tp + fp else (0.0, 1.0)
    r_lo, r_hi = wilson_interval(tp, tp + fn) if tp + fn else (0.0, 1.0)
    bound = (pol.audit_error_bound if pol.audit_error_bound is not None
             else (pol.epsilon if pol.epsilon is not None else 0.05))
    # ---- per-cluster disagreement -> re-vote candidates ----
    flagged: List[Dict[str, Any]] = []
    s_assign = assign[sample]
    for c in np.unique(s_assign):
        in_c = s_assign == c
        n_c = int(in_c.sum())
        dis = int(np.sum(~agree[in_c]))
        rate = dis / n_c
        if n_c >= MIN_CLUSTER_AUDIT and rate > bound:
            flagged.append({"cluster": int(c), "n": n_c, "disagree": dis,
                            "rate": rate})
    report = AuditReport(
        n_rows=n, n_audited=m, n_agree=k, n_fresh_calls=n_fresh,
        n_memo_hits=n_memo, input_tokens=tokens, error_bound=float(bound),
        accuracy=acc, accuracy_lo=acc_lo, accuracy_hi=acc_hi,
        precision=prec, precision_lo=p_lo, precision_hi=p_hi,
        recall=rec, recall_lo=r_lo, recall_hi=r_hi,
        f1=_f1(prec, rec), f1_lo=_f1(p_lo, r_lo), f1_hi=_f1(p_hi, r_hi),
        flagged_clusters=flagged, sample_ids=sample)
    metrics = get_tracer().metrics
    metrics.inc("audit.calls", n_fresh)
    metrics.inc("audit.cached", n_memo)
    metrics.inc("audit.input_tokens", tokens)
    metrics.inc("quality.audited_rows", m)
    metrics.inc("quality.disagreements", m - k)
    metrics.set("quality.accuracy", acc)
    metrics.set("quality.accuracy_lo", acc_lo)
    if flagged:
        metrics.inc("quality.flagged_clusters", len(flagged))
    if report.breached:
        metrics.inc("quality.audit_breaches")
    return report
