"""Export sinks: JSONL span log, Chrome/Perfetto trace, Prometheus text.

All sinks are pure functions over a span list / registry snapshot — no
background threads, no buffering — so a run can export the same tracer to
several formats.  ``write_run_profile`` is the one-call bundle the serve
driver's ``--trace-dir`` flag uses:

    trace_dir/
      spans.jsonl     one span per line (span_id/parent_id/name/attrs)
      trace.json      Chrome trace-event JSON — load in ui.perfetto.dev
      metrics.prom    Prometheus text exposition of the registry
      metrics.json    registry snapshot (counters/gauges/histograms)
      ticks.jsonl     one line per dispatch_wave span (per-tick snapshot)
"""
from __future__ import annotations

import json
import pathlib
from typing import Iterable, List

import numpy as np


def _jsonable(v):
    """Attrs may carry numpy scalars/arrays; make them JSON-clean."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


# ------------------------------------------------------------------- JSONL
def write_spans_jsonl(spans: Iterable, path) -> int:
    """One span per line; returns the number of spans written."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    n = 0
    with path.open("w") as f:
        for sp in spans:
            f.write(json.dumps(_jsonable(sp.to_dict()), sort_keys=True))
            f.write("\n")
            n += 1
    return n


# ---------------------------------------------------------------- Perfetto
def spans_to_perfetto(spans: List, epoch_mono: float = 0.0,
                      pid: int = 1) -> dict:
    """Chrome trace-event JSON (``ph: "X"`` complete events).

    Timestamps are microseconds relative to the tracer epoch; each OS
    thread becomes a Perfetto track (named via metadata events), so nesting
    inside a thread is rendered by containment and cross-thread edges stay
    inspectable through the ``parent_id`` arg on every slice.
    """
    events = []
    tids: dict = {}
    for sp in spans:
        tid = tids.setdefault(sp.thread, len(tids) + 1)
        t1 = sp.t1 if sp.t1 is not None else sp.t0
        events.append({
            "name": sp.name, "cat": sp.kind, "ph": "X", "pid": pid,
            "tid": tid,
            "ts": (sp.t0 - epoch_mono) * 1e6,
            "dur": max(0.0, (t1 - sp.t0) * 1e6),
            "args": _jsonable({"span_id": sp.span_id,
                               "parent_id": sp.parent_id, **sp.attrs}),
        })
    for thread, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": thread}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(spans: List, path, epoch_mono: float = 0.0) -> int:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = spans_to_perfetto(spans, epoch_mono=epoch_mono)
    path.write_text(json.dumps(doc) + "\n")
    return len(doc["traceEvents"])


# -------------------------------------------------------------- Prometheus
def _prom_name(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def registry_to_prometheus(registry) -> str:
    """Prometheus text exposition format (HELP/TYPE comments + samples)."""
    lines: List[str] = []
    for name, m in registry._iter_instruments():
        pname = _prom_name(name)
        lines.append(f"# HELP {pname} repro metric {name}")
        if m.kind == "histogram":
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for bound, c in zip([*m.bounds, float("inf")], m.counts):
                cum += c
                le = "+Inf" if bound == float("inf") else format(bound, "g")
                lines.append(f'{pname}_bucket{{le="{le}"}} {cum}')
            lines.append(f"{pname}_sum {m.sum}")
            lines.append(f"{pname}_count {m.count}")
        else:
            try:
                value = float(m.value)
            except (TypeError, ValueError):
                # non-numeric gauge (someone .set() a string): expose it
                # through the info idiom rather than crashing the scrape
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f'{pname}{{value="{m.value}"}} 1')
                continue
            lines.append(f"# TYPE {pname} {m.kind}")
            lines.append(f"{pname} {value}")
    for name, v in registry._iter_info():
        pname = _prom_name(name)
        lines.append(f"# HELP {pname} repro info {name}")
        lines.append(f"# TYPE {pname} gauge")
        lines.append(f'{pname}{{value="{v}"}} 1')
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(registry, path) -> str:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    text = registry_to_prometheus(registry)
    path.write_text(text)
    return text


# ------------------------------------------------------------- run bundles
def write_ticks_jsonl(spans: List, path) -> int:
    """Per-tick snapshots: one JSON line per ``dispatch_wave`` span."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    n = 0
    with path.open("w") as f:
        for sp in spans:
            if sp.kind != "dispatch_wave":
                continue
            rec = {"span_id": sp.span_id, "wall_s": sp.duration_s,
                   **sp.attrs}
            f.write(json.dumps(_jsonable(rec), sort_keys=True))
            f.write("\n")
            n += 1
    return n


def write_run_profile(trace_dir, tracer, registry=None) -> dict:
    """Write the full artifact set for one run; returns written counts."""
    trace_dir = pathlib.Path(trace_dir)
    trace_dir.mkdir(parents=True, exist_ok=True)
    spans = tracer.spans()
    registry = registry if registry is not None else tracer.metrics
    out = {
        "spans": write_spans_jsonl(spans, trace_dir / "spans.jsonl"),
        "trace_events": write_perfetto(spans, trace_dir / "trace.json",
                                       epoch_mono=tracer.epoch_mono),
        "ticks": write_ticks_jsonl(spans, trace_dir / "ticks.jsonl"),
    }
    write_prometheus(registry, trace_dir / "metrics.prom")
    (trace_dir / "metrics.json").write_text(
        json.dumps(_jsonable(registry.snapshot()), indent=2, sort_keys=True)
        + "\n")
    return out
