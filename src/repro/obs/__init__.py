"""Unified observability: hierarchical tracing, metrics, export sinks.

See docs/observability.md for the span taxonomy and metric naming scheme.
"""
from repro.obs.export import (
    registry_to_prometheus,
    spans_to_perfetto,
    write_perfetto,
    write_prometheus,
    write_run_profile,
    write_spans_jsonl,
    write_ticks_jsonl,
)
from repro.obs.metrics import (
    DEFAULT_BOUNDS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_REGISTRY,
    NullRegistry,
)
from repro.obs.trace import (
    NULL_SPAN,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    get_tracer,
    set_tracer,
    use_tracer,
)

__all__ = [
    "Counter",
    "DEFAULT_BOUNDS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NULL_SPAN",
    "NULL_TRACER",
    "NullRegistry",
    "NullTracer",
    "Span",
    "Tracer",
    "get_tracer",
    "registry_to_prometheus",
    "set_tracer",
    "spans_to_perfetto",
    "use_tracer",
    "write_perfetto",
    "write_prometheus",
    "write_run_profile",
    "write_spans_jsonl",
    "write_ticks_jsonl",
]
