"""Unified observability: tracing, metrics, audit, health, status, export.

See docs/observability.md for the span taxonomy, metric naming scheme,
online quality auditing, health rules, and the flight recorder.
"""
from repro.obs.audit import (
    AuditReport,
    audit_labels,
    audit_query_result,
    stratified_sample,
    wilson_interval,
)
from repro.obs.export import (
    registry_to_prometheus,
    spans_to_perfetto,
    write_perfetto,
    write_prometheus,
    write_run_profile,
    write_spans_jsonl,
    write_ticks_jsonl,
)
from repro.obs.flight import (
    FlightRecorder,
    get_flight_recorder,
    set_flight_recorder,
)
from repro.obs.health import (
    Alert,
    CallbackAlertSink,
    HealthMonitor,
    HealthRule,
    JsonlAlertSink,
    LogAlertSink,
    NULL_MONITOR,
    default_rules,
    get_monitor,
    set_monitor,
)
from repro.obs.metrics import (
    DEFAULT_BOUNDS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_REGISTRY,
    NullRegistry,
)
from repro.obs.status import (
    StatusHub,
    start_status_server,
)
from repro.obs.trace import (
    NULL_SPAN,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    get_tracer,
    set_tracer,
    use_tracer,
)

__all__ = [
    "Alert",
    "AuditReport",
    "CallbackAlertSink",
    "Counter",
    "DEFAULT_BOUNDS",
    "FlightRecorder",
    "Gauge",
    "HealthMonitor",
    "HealthRule",
    "Histogram",
    "JsonlAlertSink",
    "LogAlertSink",
    "MetricsRegistry",
    "NULL_MONITOR",
    "NULL_REGISTRY",
    "NULL_SPAN",
    "NULL_TRACER",
    "NullRegistry",
    "NullTracer",
    "Span",
    "StatusHub",
    "Tracer",
    "audit_labels",
    "audit_query_result",
    "default_rules",
    "get_flight_recorder",
    "get_monitor",
    "get_tracer",
    "registry_to_prometheus",
    "set_flight_recorder",
    "set_monitor",
    "set_tracer",
    "spans_to_perfetto",
    "start_status_server",
    "stratified_sample",
    "use_tracer",
    "wilson_interval",
    "write_perfetto",
    "write_prometheus",
    "write_run_profile",
    "write_spans_jsonl",
    "write_ticks_jsonl",
]
