"""Flight recorder: a crash-time debug bundle for long-running processes.

Keeps a bounded window of recent activity — the tail of the tracer's span
list plus periodic metric *deltas* (what moved since the last heartbeat)
— and dumps a ``debug-bundle/`` directory when the process dies badly:

- **unhandled exception** — ``install()`` chains ``sys.excepthook``;
- **fatal signal** — registered with the existing
  ``repro.service.lifecycle.GracefulShutdown`` (the dump only fires when
  a signal actually triggered the shutdown, never on a clean exit);
- **critical alert** — the health monitor's ``on_critical`` hook.

The bundle is small, self-contained, and parseable offline:

    debug-bundle/
      manifest.json       reason, wall time, exception/signal, file inventory
      spans.jsonl         the span ring (same schema as --trace-dir output)
      metrics.json        full registry snapshot at dump time
      metric_deltas.jsonl one line per heartbeat: counters that moved
      policy.json         execution-policy fingerprint (when attached)
      wal.json            session-log tail summary (when attached)
      alerts.jsonl        recent health alerts (when a monitor is attached)

Dumping is observation-only and idempotent per reason: re-dumps overwrite
in place, so the newest crash context wins.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import threading
import time
import traceback
from collections import deque
from typing import Any, Dict, List, Optional

from repro.obs.export import _jsonable, write_spans_jsonl
from repro.obs.trace import get_tracer


class FlightRecorder:
    """Bounded recent-activity window + crash-time bundle writer."""

    def __init__(self, bundle_dir="debug-bundle", tracer=None, registry=None,
                 span_capacity: int = 512, delta_capacity: int = 128):
        self.bundle_dir = pathlib.Path(bundle_dir)
        self._tracer = tracer
        self._registry = registry
        self.span_capacity = int(span_capacity)
        self._deltas: deque = deque(maxlen=int(delta_capacity))
        self._alerts: deque = deque(maxlen=64)
        self._last_snap: Dict[str, float] = {}
        self._policy = None
        self._log_store = None
        self._lock = threading.Lock()
        self._prev_excepthook = None
        self.dumps = 0

    # ----------------------------------------------------------- plumbing
    @property
    def tracer(self):
        return self._tracer if self._tracer is not None else get_tracer()

    @property
    def registry(self):
        return (self._registry if self._registry is not None
                else self.tracer.metrics)

    def attach_policy(self, policy) -> "FlightRecorder":
        self._policy = policy
        return self

    def attach_log(self, log_store) -> "FlightRecorder":
        self._log_store = log_store
        return self

    # ---------------------------------------------------------- heartbeat
    def record_delta(self) -> Dict[str, float]:
        """One heartbeat: record which scalar metrics moved since the last
        call.  Cheap (one snapshot + dict diff) — call it from the same
        tick loop that evaluates health rules."""
        snap = self.registry.snapshot()
        flat: Dict[str, float] = {}
        for k, v in snap.items():
            if isinstance(v, dict):
                v = v.get("count")
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            flat[k] = float(v)
        with self._lock:
            delta = {k: v - self._last_snap.get(k, 0.0)
                     for k, v in flat.items()
                     if v != self._last_snap.get(k, 0.0)}
            self._last_snap = flat
            if delta:
                self._deltas.append(
                    {"wall_time": time.time(),  # noqa: TID251 — postmortem
                     "delta": delta})
        return delta

    def note_alert(self, alert) -> None:
        """Health-monitor hook: remember the alert; dump on critical."""
        with self._lock:
            self._alerts.append(alert)
        if (getattr(alert, "severity", None) == "critical"
                and getattr(alert, "kind", "breach") == "breach"):
            self.dump(reason=f"critical-alert:{alert.rule}")

    # --------------------------------------------------------------- dump
    def dump(self, reason: str = "manual", exc_info=None,
             signum: Optional[int] = None) -> pathlib.Path:
        """Write the bundle; returns its directory.  Never raises — a
        failing dump prints and returns (the process is already dying)."""
        d = self.bundle_dir
        try:
            d.mkdir(parents=True, exist_ok=True)
            tracer = self.tracer
            spans = (tracer.spans()[-self.span_capacity:]
                     if getattr(tracer, "enabled", False) else [])
            n_spans = write_spans_jsonl(spans, d / "spans.jsonl")
            (d / "metrics.json").write_text(
                json.dumps(_jsonable(self.registry.snapshot()), indent=2,
                           sort_keys=True) + "\n")
            with self._lock:
                deltas = list(self._deltas)
                alerts = list(self._alerts)
            with (d / "metric_deltas.jsonl").open("w") as f:
                for rec in deltas:
                    f.write(json.dumps(_jsonable(rec), sort_keys=True) + "\n")
            with (d / "alerts.jsonl").open("w") as f:
                for a in alerts:
                    rec = (a.to_dict() if hasattr(a, "to_dict")
                           else dataclasses.asdict(a))
                    f.write(json.dumps(_jsonable(rec), sort_keys=True) + "\n")
            files = ["manifest.json", "spans.jsonl", "metrics.json",
                     "metric_deltas.jsonl", "alerts.jsonl"]
            if self._policy is not None:
                (d / "policy.json").write_text(
                    json.dumps(_jsonable(dataclasses.asdict(self._policy)),
                               indent=2, sort_keys=True) + "\n")
                files.append("policy.json")
            if self._log_store is not None:
                try:
                    wal = self._log_store.tail_summary()
                except Exception as e:
                    wal = {"error": repr(e)}
                (d / "wal.json").write_text(
                    json.dumps(_jsonable(wal), indent=2, sort_keys=True)
                    + "\n")
                files.append("wal.json")
            manifest: Dict[str, Any] = {
                "reason": reason,
                "wall_time": time.time(),  # noqa: TID251 — postmortem
                "n_spans": n_spans,
                "n_deltas": len(deltas),
                "files": sorted(files),
            }
            if signum is not None:
                manifest["signal"] = int(signum)
            if exc_info is not None:
                manifest["exception"] = "".join(
                    traceback.format_exception(*exc_info)).strip()
            (d / "manifest.json").write_text(
                json.dumps(manifest, indent=2, sort_keys=True) + "\n")
            self.dumps += 1
            print(f"[flight] debug bundle ({reason}) -> {d}")
        except Exception as e:
            print(f"[flight] bundle dump failed: {e!r}", file=sys.stderr)
        return d

    # ------------------------------------------------------------ install
    def install(self, shutdown=None) -> "FlightRecorder":
        """Arm the crash triggers: chain ``sys.excepthook`` and (when a
        ``GracefulShutdown`` is given) register a signal-only dump — the
        callback checks ``shutdown.signum`` so clean ``close()`` exits
        never leave a bundle behind."""
        if self._prev_excepthook is None:
            prev = sys.excepthook

            def hook(tp, val, tb):
                self.dump(reason="unhandled-exception",
                          exc_info=(tp, val, tb))
                prev(tp, val, tb)

            self._prev_excepthook = prev
            sys.excepthook = hook
        if shutdown is not None:
            def on_signal():
                signum = getattr(shutdown, "signum", None)
                if signum is not None:
                    self.dump(reason="fatal-signal", signum=signum)

            shutdown.register("flight-recorder", on_signal)
        return self

    def uninstall(self) -> None:
        if self._prev_excepthook is not None:
            sys.excepthook = self._prev_excepthook
            self._prev_excepthook = None


_active: Optional[FlightRecorder] = None


def get_flight_recorder() -> Optional[FlightRecorder]:
    return _active


def set_flight_recorder(recorder: Optional[FlightRecorder]) -> None:
    global _active
    _active = recorder
