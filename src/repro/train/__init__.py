from repro.train.optimizer import adamw_init, adamw_update, OptConfig
from repro.train.trainer import make_train_step, loss_fn
