"""AdamW from scratch (no optax offline): fp32 master weights + moments.

Optimizer state shards exactly like the parameters (ZeRO-3 via the same
logical axes), so memory per device is params*(2 + 12)/n_shards bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # "cosine" | "linear" | "const"
    keep_master: bool = True  # fp32 master copy when params are bf16


def lr_at(oc: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, oc.warmup_steps))
    t = jnp.clip((step - oc.warmup_steps) /
                 max(1, oc.total_steps - oc.warmup_steps), 0.0, 1.0)
    if oc.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif oc.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = 1.0
    return oc.lr * warm * decay


def adamw_init(params, oc: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }
    if oc.keep_master:
        # copy=True: when params are already fp32 an astype would alias the
        # buffer, and donating (params, opt_state) together must not donate
        # the same buffer twice
        state["master"] = jax.tree_util.tree_map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def adamw_update(params, grads, state, oc: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if oc.grad_clip > 0 else jnp.float32(1.0)
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(nhat) + oc.eps)
                           + oc.weight_decay * base)
        return new.astype(p.dtype), mu, nu, new

    masters = state.get("master",
                        jax.tree_util.tree_map(lambda _: None, params))
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ma = treedef.flatten_up_to(masters)
    outs = [upd(p, g, mu, nu, ma)
            for p, g, mu, nu, ma in zip(flat_p, flat_g, flat_mu, flat_nu, flat_ma)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "step": step,
        "mu": jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]),
        "nu": jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs]),
    }
    if oc.keep_master:
        new_state["master"] = jax.tree_util.tree_unflatten(
            treedef, [o[3] for o in outs])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_logical_axes(param_axes, oc: OptConfig):
    """Optimizer-state logical axes mirroring the params tree."""
    state = {
        "step": (),
        "mu": param_axes,
        "nu": param_axes,
    }
    if oc.keep_master:
        state["master"] = param_axes
    return state
