"""Gradient compression for the cross-data-axis reduction.

Two schemes, both with *error feedback* (the compression residual is carried
in module-level state folded into the next step under jit via a stateless
formulation: compress(g + e) and return the new residual alongside):

- int8: per-tensor symmetric quantization (scale = max|g|/127).  On a real
  ICI fabric this shrinks the all-reduce payload 4x (bf16->int8 plus scale).
- topk: keep the largest-|g| fraction per tensor (default 10%), zero the
  rest.  Sparse payloads compose with reduce-scatter on TPU via static
  masks (values stay dense here — XLA has no sparse collectives — but the
  zeroed entries compress losslessly at the ICI link layer when paired with
  the run-length encoder in the launch scripts; the *algorithmic* effect —
  convergence under error feedback — is what we test on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _int8_roundtrip(g):
    a = jnp.max(jnp.abs(g))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_mask(g, frac=0.1):
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_grads(grads, method: str = "int8", topk_frac: float = 0.1):
    """Stateless (per-step) compression round-trip; see compress_with_feedback
    for the error-feedback variant used by the training loop."""
    f = _int8_roundtrip if method == "int8" else lambda g: _topk_mask(g, topk_frac)
    return jax.tree_util.tree_map(
        lambda g: f(g.astype(jnp.float32)).astype(g.dtype), grads)


def compress_with_feedback(grads, residuals, method: str = "int8",
                           topk_frac: float = 0.1):
    """Error-feedback compression: compress(g + e); e' = (g + e) - compressed.

    Returns (compressed_grads, new_residuals).  Residuals shard like grads.
    """
    f = _int8_roundtrip if method == "int8" else lambda g: _topk_mask(g, topk_frac)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        c = f(x)
        return c.astype(g.dtype), x - c

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(residuals)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
