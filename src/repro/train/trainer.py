"""Train step: loss, grad, microbatched accumulation, optional compression."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import lm
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, adamw_update
from repro.train.grad_compression import compress_grads


def _ce_from_hidden(cfg, params, h, targets, chunk: int):
    """CE over final hidden states; optionally chunked along S so the
    (B, S, V) logits tensor never materializes (recomputed in backward)."""
    B, S, _ = h.shape
    mask = (targets >= 0).astype(jnp.float32)

    def ce(hc, tc, mc):
        logits = lm._logits(cfg, params, hc)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tl = jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return -jnp.sum(tl * mc)

    if chunk <= 0 or S <= chunk or S % chunk != 0:
        total = ce(h, targets, mask)
    else:
        nch = S // chunk
        hs = h.reshape(B, nch, chunk, -1).transpose(1, 0, 2, 3)
        ts = targets.reshape(B, nch, chunk).transpose(1, 0, 2)
        ms = mask.reshape(B, nch, chunk).transpose(1, 0, 2)

        def body(acc, xs):
            hc, tc, mc = xs
            return acc + ce(hc, tc, mc), None

        body = jax.checkpoint(body)
        total, _ = lax.scan(body, jnp.float32(0.0), (hs, ts, ms))
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg: ModelConfig, params, batch, aux_weight: float = 0.01):
    """Causal-LM cross entropy (fp32 log-softmax; sequence-chunked)."""
    h, aux = lm.forward_hidden(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_frames=batch.get("enc_frames"))
    targets = batch["targets"]
    P = cfg.num_prefix_embeds
    if P:
        h = h[:, P:]
    loss = _ce_from_hidden(cfg, params, h, targets,
                           getattr(cfg, "loss_chunk", 0))
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, oc: OptConfig, microbatches: int = 1,
                    compression: Optional[str] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    - microbatches > 1: gradient accumulation via lax.scan over batch splits
      (bounds activation memory independently of global batch).
    - compression: None | "int8" | "topk" — error-feedback gradient
      compression applied before the cross-data-axis reduction.
    """

    def grads_of(params, batch):
        (l, m), g = jax.value_and_grad(
            partial(loss_fn, cfg), has_aux=True)(params, batch)
        return l, m, g

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            B = batch["tokens"].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = {k: v.reshape(microbatches, B // microbatches, *v.shape[1:])
                  for k, v in batch.items()}

            def acc_step(carry, mbatch):
                gsum, lsum = carry
                l, m, g = grads_of(params, mbatch)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = lax.scan(acc_step, (g0, jnp.float32(0.0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compression:
            grads = compress_grads(grads, method=compression)

        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, oc)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
