"""Standing semantic queries over live streams (docs/streaming.md).

Continuous ingestion (``StreamSource`` + ``RateBudget``), incremental
evaluation of registered predicates via dirty-cluster re-votes
(``StandingQuery`` inside a ``StreamWatcher``), newly-matching-row deltas
with content dedup (``DeltaTracker``), and pluggable notification sinks
with retry + dead-letter (``SinkRunner``).  Checkpoint/restore rides on
``repro.service.store.SessionStore``.
"""
from repro.stream.delta import DeltaTracker, row_key
from repro.stream.sinks import (CallbackSink, JsonlSink, Sink, SinkRunner,
                                SinkStats, StdoutSink)
from repro.stream.source import (RateBudget, ReplayFileSource, StreamRow,
                                 StreamSource, SyntheticSource)
from repro.stream.watcher import StandingQuery, StreamStats, StreamWatcher

__all__ = [
    "DeltaTracker", "row_key",
    "CallbackSink", "JsonlSink", "Sink", "SinkRunner", "SinkStats",
    "StdoutSink",
    "RateBudget", "ReplayFileSource", "StreamRow", "StreamSource",
    "SyntheticSource",
    "StandingQuery", "StreamStats", "StreamWatcher",
]
