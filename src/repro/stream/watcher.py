"""Standing-query watcher: continuous ingestion + incremental evaluation.

``StreamWatcher`` is the control loop tying the stream layer to the
PR 4-7 stack.  Once per **tick** it:

1. polls every registered ``StreamSource`` (deterministic arrivals) and
   drains each up to its ``RateBudget`` — excess rows stay in the
   source's backlog (deferred, never dropped);
2. ingests the drained rows through ONE ``TableHandle.coalescing_appends``
   block, so a tick pays one precluster patch and one dirty-set union no
   matter how many sources contributed;
3. evaluates every registered ``StandingQuery`` — each is a lazy
   ``FilterQuery`` kept warm across ticks, so the session memo replays
   clean clusters and re-votes only the clusters this tick's rows
   touched: per-tick oracle cost is proportional to *touched clusters*,
   not table size.  Evaluation goes through the session's
   ``QueryScheduler`` (cross-query oracle batching) or, when a
   ``FilterService`` + tenant is attached, through tenant admission on
   top;
4. diffs each query's mask against its last acknowledged mask
   (``DeltaTracker``), content-dedups, and pushes exactly the
   newly-matching rows to the query's sink via its retrying
   ``SinkRunner``;
5. optionally checkpoints: ``SessionStore.save`` (decisions, clustering,
   oracle memos) plus a stream sidecar (tick counter, per-source
   cursors, per-query acked masks and seen-sets).

**Restart contract** (tests/test_stream.py): a killed watcher rebuilt
over the same sources and queries calls ``restore()``, which replays the
*ingestion* of ticks 1..k (pure row appends — zero oracle calls, no
clustering), binds the checkpointed session state back on, and restores
the delta trackers; ticks k+1..n then notify exactly the rows the
unkilled run would have, with no duplicate notifications and near-zero
oracle replay.  See docs/streaming.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.checkpoint.manager import load_pytree, save_pytree
from repro.obs.flight import get_flight_recorder
from repro.obs.health import get_monitor
from repro.obs.trace import get_tracer
from repro.stream.delta import DeltaTracker, row_key
from repro.stream.sinks import Sink, SinkRunner, StdoutSink
from repro.stream.source import RateBudget, StreamSource

STREAM_SCHEMA = 1


@dataclasses.dataclass
class StreamStats:
    """Watcher-level accounting (per-query spend stays on the oracles)."""
    n_ticks: int = 0
    n_rows_arrived: int = 0
    n_rows_ingested: int = 0
    n_rows_deferred: int = 0      # backlog rows left waiting by quotas
    n_oracle_calls: int = 0       # cumulative across standing queries
    n_notifications: int = 0
    n_checkpoints: int = 0

    def metrics_view(self) -> dict:
        return {
            "stream.ticks": self.n_ticks,
            "stream.rows_ingested": self.n_rows_ingested,
            "stream.rows_deferred": self.n_rows_deferred,
            "stream.oracle_calls": self.n_oracle_calls,
            "stream.notifications": self.n_notifications,
            "stream.checkpoints": self.n_checkpoints,
        }


class StandingQuery:
    """One registered predicate: a lazy query kept warm across ticks,
    its delta tracker, and its sink runner."""

    def __init__(self, name: str, predicate, runner: SinkRunner,
                 policy=None):
        self.name = name
        self.predicate = predicate    # str (registered oracle) or Expr
        self.runner = runner
        self.policy = policy
        self.delta = DeltaTracker()
        self.query = None             # built when the table exists

    def bind(self, handle) -> None:
        if self.query is None:
            self.query = handle.filter(self.predicate, policy=self.policy)


class StreamWatcher:
    """Tick loop over sources, standing queries, sinks, and checkpoints.

        watcher = StreamWatcher(session, table_name="feed", store=store)
        watcher.add_source(src, RateBudget(rows_per_tick=32))
        watcher.register("positive", sink=JsonlSink("hits.jsonl"))
        watcher.run(n_ticks=50)

    ``register`` predicates name oracles registered on the session
    (``session.register_oracle``) — the durable identity the
    ``SessionStore`` needs for zero-replay restarts.
    """

    def __init__(self, session, table_name: str = "stream",
                 store=None, tag: str = "watch",
                 checkpoint_every: Optional[int] = None,
                 service=None, tenant: Optional[str] = None,
                 use_scheduler: bool = True):
        self.session = session
        self.table_name = table_name
        self.store = store
        self.tag = tag
        self.checkpoint_every = checkpoint_every
        self.service = service
        self.tenant = tenant
        if service is not None and tenant is None:
            raise ValueError("a FilterService watcher needs tenant=")
        self.use_scheduler = use_scheduler
        self.stats = StreamStats()
        self.handle = session._tables.get(table_name)
        self.row_keys: List[str] = []
        if self.handle is not None:
            self._rekey_existing_rows()
        self._sources: List[tuple] = []          # (source, budget)
        self._queries: Dict[str, StandingQuery] = {}
        self._tick = 0
        self._evaluated_version = -1
        self._shutdown_done = False

    # ------------------------------------------------------------- wiring
    def add_source(self, source: StreamSource,
                   budget: Optional[RateBudget] = None) -> StreamSource:
        if any(s.name == source.name for s, _ in self._sources):
            raise ValueError(f"source {source.name!r} already added")
        self._sources.append((source, budget or RateBudget()))
        return source

    def register(self, predicate, sink: Optional[Sink] = None,
                 name: Optional[str] = None, retries: int = 2,
                 policy=None) -> StandingQuery:
        """Register a standing query.  ``predicate`` is a session oracle
        name (recommended: durable across restarts) or a plan ``Expr``."""
        name = name or (predicate if isinstance(predicate, str)
                        else f"q{len(self._queries)}")
        if name in self._queries:
            raise ValueError(f"standing query {name!r} already registered")
        dl_path = (self.store.dir / f"{self.tag}-deadletter.jsonl"
                   if self.store is not None else None)
        runner = SinkRunner(sink or StdoutSink(), retries=retries,
                            dead_letter_path=dl_path)
        sq = StandingQuery(name, predicate, runner, policy=policy)
        if self.handle is not None:
            sq.bind(self.handle)
        self._queries[name] = sq
        return sq

    @property
    def queries(self) -> Dict[str, StandingQuery]:
        return dict(self._queries)

    def _rekey_existing_rows(self) -> None:
        t = self.handle._table
        texts = t.texts
        emb = t._embeddings
        self.row_keys = [
            row_key(texts[i] if texts is not None else None,
                    emb[i] if texts is None else None)
            for i in range(len(self.handle))]

    # --------------------------------------------------------------- tick
    def _ingest_tick(self, tick: int) -> int:
        """Phase 1+2 of one tick: poll sources, drain within budgets,
        coalesced-append into the table.  Pure w.r.t. oracles — restart
        replay runs exactly this for ticks 1..k."""
        drained: List[tuple] = []     # (source, rows)
        deferred = 0
        for src, budget in self._sources:
            arrived_before = src.arrived
            backlog = src.poll(tick)
            self.stats.n_rows_arrived += src.arrived - arrived_before
            rows = src.take(budget.cap(backlog))
            deferred += src.backlog
            if rows:
                drained.append((src, rows))
        self.stats.n_rows_deferred = deferred
        n_ing = sum(len(rows) for _, rows in drained)
        if n_ing == 0:
            return 0
        batches = []
        for _src, rows in drained:
            texts = ([r.text for r in rows]
                     if all(r.text is not None for r in rows) else None)
            embs = (np.stack([r.embedding for r in rows])
                    if all(r.embedding is not None for r in rows) else None)
            batches.append((texts, embs))
            self.row_keys.extend(
                row_key(r.text, r.embedding) for r in rows)
        if self.handle is None:
            # first rows create the table; later ticks append into it
            first_t, first_e = batches[0]
            self.handle = self.session.table(
                texts=first_t, embeddings=first_e, name=self.table_name)
            batches = batches[1:]
            for sq in self._queries.values():
                sq.bind(self.handle)
        if batches:
            with self.handle.coalescing_appends():
                for texts, embs in batches:
                    self.handle.append(texts=texts, embeddings=embs)
        self.stats.n_rows_ingested += n_ing
        return n_ing

    def _evaluate(self) -> List[tuple]:
        """Phase 3: evaluate every standing query; returns
        ``[(sq, QueryResult), ...]``."""
        sqs = list(self._queries.values())
        for sq in sqs:
            sq.bind(self.handle)
        if self.service is not None:
            tickets = [self.service.submit(self.tenant, sq.query,
                                           policy=sq.policy, label=sq.name)
                       for sq in sqs]
            results = self.service.gather(*tickets)
        elif self.use_scheduler:
            with self.session.scheduler.holding():
                tickets = [self.session.submit(sq.query, policy=sq.policy)
                           for sq in sqs]
            results = [t.result() for t in tickets]
        else:
            results = [sq.query.collect(sq.policy) for sq in sqs]
        self._evaluated_version = self.handle.version
        return list(zip(sqs, results))

    def _notify(self, sq: StandingQuery, result) -> int:
        """Phase 4: delta -> dedup -> sink -> ack for one query."""
        emit_rows, deduped = sq.delta.delta(result.mask, self.row_keys)
        sq.runner.note_deduped(deduped)
        texts = self.handle._table.texts
        for i in emit_rows:
            sq.runner.deliver({
                "query": sq.name, "tick": self._tick, "row": int(i),
                "key": self.row_keys[i],
                "text": texts[i] if texts is not None else None})
        sq.delta.ack(result.mask)
        return len(emit_rows)

    def tick(self) -> dict:
        """Run one full tick; returns a summary dict."""
        if not self._sources:
            raise RuntimeError("no sources added")
        self._tick += 1
        tr = get_tracer()
        with tr.span("stream_tick", kind="stream_tick",
                     tick=self._tick) as sp:
            n_ing = self._ingest_tick(self._tick)
            calls = notified = 0
            fresh_rows = (self.handle is not None
                          and self.handle.version != self._evaluated_version)
            if self.handle is not None and (n_ing or fresh_rows):
                for sq, result in self._evaluate():
                    calls += int(result.n_llm_calls)
                    notified += self._notify(sq, result)
            self.stats.n_ticks += 1
            self.stats.n_oracle_calls += calls
            self.stats.n_notifications += notified
            backlog = sum(s.backlog for s, _ in self._sources)
            tr.metrics.inc("stream.ticks")
            tr.metrics.inc("stream.rows_ingested", n_ing)
            tr.metrics.inc("stream.oracle_calls", calls)
            tr.metrics.inc("stream.notifications", notified)
            # tick lag: rows the budgeted sources are still holding back —
            # a growing gauge means ticks are not draining arrivals
            tr.metrics.set("stream.tick_lag_rows", backlog)
            if tr.enabled and n_ing:
                self._export_centroid_drift(n_ing, tr)
            sp.set(rows=n_ing, oracle_calls=calls, notified=notified,
                   n_rows=0 if self.handle is None else len(self.handle))
        # health heartbeat + flight-recorder metric deltas (null defaults)
        get_monitor().maybe_evaluate()
        fr = get_flight_recorder()
        if fr is not None:
            fr.record_delta()
        if (self.checkpoint_every and self.store is not None
                and self._tick % self.checkpoint_every == 0):
            self.checkpoint()
        return {"tick": self._tick, "rows": n_ing, "oracle_calls": calls,
                "notified": notified, "backlog": backlog}

    def _export_centroid_drift(self, n_new: int, tr) -> None:
        """Relative distance between this tick's new rows and the table's
        running mean embedding.  The stream table's cluster centroids are
        frozen at creation (docs/streaming.md), so sustained drift means
        the 4-way partition is degrading — the ``stream-centroid-drift``
        health rule alerts on this gauge."""
        if self.handle is None:
            return
        emb = self.handle._table._embeddings
        if emb is None or len(emb) == 0 or n_new > len(emb):
            return
        center = emb.mean(axis=0)
        drift = float(np.linalg.norm(emb[-n_new:].mean(axis=0) - center)
                      / (np.linalg.norm(center) + 1e-9))
        tr.metrics.set("stream.centroid_drift", drift)

    def status_view(self) -> dict:
        """statusz section: tick progress, backlog, per-query delivery."""
        return {
            "tick": self._tick,
            "n_rows": 0 if self.handle is None else len(self.handle),
            "backlog": sum(s.backlog for s, _ in self._sources),
            "drained": self.drained,
            "ticks": self.stats.n_ticks,
            "oracle_calls": self.stats.n_oracle_calls,
            "notifications": self.stats.n_notifications,
            "queries": sorted(self._queries),
        }

    @property
    def drained(self) -> bool:
        """Every source fully arrived AND ingested (no pending work)."""
        return all(s.exhausted for s, _ in self._sources)

    def run(self, n_ticks: Optional[int] = None,
            shutdown=None) -> List[dict]:
        """Tick until sources drain (or ``n_ticks``); between ticks honor
        a flag-mode ``GracefulShutdown``.  Returns per-tick summaries."""
        out = []
        while n_ticks is None or len(out) < n_ticks:
            if shutdown is not None and shutdown.requested:
                break
            out.append(self.tick())
            if n_ticks is None and self.drained:
                break
        return out

    # --------------------------------------------------------- checkpoint
    def _sidecar_dir(self):
        return self.store.dir / f"{self.tag}-stream"

    def has_checkpoint(self) -> bool:
        """A restorable stream sidecar exists in the store directory."""
        return (self.store is not None
                and (self._sidecar_dir() / "MANIFEST.json").exists())

    def checkpoint(self) -> None:
        """Durable snapshot: session state + stream sidecar."""
        if self.store is None:
            raise ValueError("StreamWatcher built without store=")
        if self.handle is not None:
            self.store.save(self.session, tag=self.tag)
        arrays = {}
        queries = {}
        for name, sq in self._queries.items():
            arrays[f"acked/{name}"] = sq.delta.acked.astype(bool)
            queries[name] = {"n_acked": int(len(sq.delta.acked)),
                             **sq.delta.state()}
        meta = {"stream_schema": STREAM_SCHEMA, "tick": int(self._tick),
                "table": self.table_name,
                "n_rows": 0 if self.handle is None else len(self.handle),
                "sources": {s.name: s.state() for s, _ in self._sources},
                "queries": queries,
                "stats": dataclasses.asdict(self.stats)}
        save_pytree(arrays, self._sidecar_dir(), extra_meta=meta)
        self.stats.n_checkpoints += 1
        get_tracer().metrics.inc("stream.checkpoints")

    def restore(self):
        """Rebuild mid-stream state from the last checkpoint.

        Call on a FRESH watcher whose session has the same oracles
        registered and whose sources/queries match the killed run;
        replays ingestion ticks 1..k (deterministic, zero oracle calls),
        then binds the session checkpoint back on.  Returns the
        ``RestoreReport`` from ``SessionStore.load``."""
        if self.store is None:
            raise ValueError("StreamWatcher built without store=")
        by_key, meta = load_pytree(self._sidecar_dir())
        if meta.get("stream_schema") != STREAM_SCHEMA:
            raise ValueError(
                f"stream sidecar schema {meta.get('stream_schema')!r} "
                f"does not match this build ({STREAM_SCHEMA})")
        if self._tick or self.handle is not None and len(self.handle):
            raise RuntimeError("restore() needs a fresh watcher")
        # 1. replay ingestion (rows only — no queries, no clustering)
        for t in range(1, meta["tick"] + 1):
            self._ingest_tick(t)
        self._tick = meta["tick"]
        n_rows = 0 if self.handle is None else len(self.handle)
        if n_rows != meta["n_rows"]:
            raise ValueError(
                f"ingestion replay rebuilt {n_rows} rows, checkpoint "
                f"recorded {meta['n_rows']} — sources or budgets differ "
                "from the killed run")
        for src, _ in self._sources:
            saved = meta["sources"].get(src.name)
            if saved is None or src.state() != saved:
                raise ValueError(
                    f"source {src.name!r} replay state {src.state()} != "
                    f"checkpointed {saved} — not the same stream schedule")
        # 2. session state: clustering, dirty versions, decisions, memos
        report = self.store.load(self.session, tag=self.tag) \
            if self.handle is not None else None
        # 3. delta trackers + cumulative stats
        for name, sq in self._queries.items():
            saved = meta["queries"].get(name)
            if saved is None:
                continue
            acked = (np.asarray(by_key[f"acked/{name}"], dtype=bool)
                     if saved["n_acked"] else np.zeros(0, dtype=bool))
            sq.delta.restore_state(saved, acked)
        st = meta["stats"]
        self.stats = StreamStats(**st)
        return report

    # ----------------------------------------------------------- shutdown
    def shutdown(self) -> None:
        """Final checkpoint + sink flush (idempotent) — the cleanup a
        ``GracefulShutdown`` registers for SIGINT/SIGTERM."""
        if self._shutdown_done:
            return
        self._shutdown_done = True
        for sq in self._queries.values():
            sq.runner.flush()
        if self.store is not None:
            self.checkpoint()
        for sq in self._queries.values():
            sq.runner.close()

    # ------------------------------------------------------------ metrics
    def metrics_view(self) -> dict:
        """Unified-name view (stream counters + summed sink counters) for
        ``MetricsRegistry.sync_from``."""
        view = self.stats.metrics_view()
        agg = {"sink.delivered": 0, "sink.deduped": 0,
               "sink.dead_lettered": 0, "sink.retries": 0}
        for sq in self._queries.values():
            for k, v in sq.runner.stats.metrics_view().items():
                agg[k] += v
        view.update(agg)
        return view
