"""Notification sinks: pluggable delivery with retry and a dead-letter log.

A ``Sink`` receives one event dict per newly-matching row
(``{"query", "tick", "row", "key", "text"}``).  The watcher never calls a
sink directly — every sink is wrapped in a ``SinkRunner`` that

- retries a failing ``emit`` up to ``retries`` times (synchronously,
  within the tick — a stream tick is the natural retry horizon);
- **dead-letters** an event whose retries are exhausted: the event plus
  the final error is appended to an in-memory log and, when the runner
  has a ``dead_letter_path``, to a JSONL file.  A dead-lettered row is
  still acknowledged by the delta engine — notification is at-most-once
  per (query, content); the dead-letter log is the recovery record, not
  a retry queue (docs/streaming.md#delta--dedup-semantics);
- counts everything in ``SinkStats`` (``sink.delivered``,
  ``sink.deduped``, ``sink.dead_lettered``, ``sink.retries`` under the
  unified metric scheme) and mirrors the increments into the active
  tracer's metrics registry.

Concrete sinks: ``StdoutSink`` (one JSON line per event to stdout),
``JsonlSink`` (append to a file), ``CallbackSink`` (hand the event to a
function — the test/integration hook).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
from typing import Callable, List, Optional

from repro.obs.trace import get_tracer


@dataclasses.dataclass
class SinkStats:
    """Delivery accounting for one standing query's sink."""
    n_delivered: int = 0
    n_deduped: int = 0        # suppressed by the delta engine's seen-set
    n_dead_lettered: int = 0
    n_retries: int = 0

    def metrics_view(self) -> dict:
        return {
            "sink.delivered": self.n_delivered,
            "sink.deduped": self.n_deduped,
            "sink.dead_lettered": self.n_dead_lettered,
            "sink.retries": self.n_retries,
        }


class Sink:
    """Delivery target interface.  ``emit`` may raise (the runner
    retries); ``flush`` must make everything emitted so far durable —
    graceful shutdown calls it before the final checkpoint."""

    def emit(self, event: dict) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


class StdoutSink(Sink):
    def __init__(self, prefix: str = "match"):
        self.prefix = prefix

    def emit(self, event: dict) -> None:
        print(f"[{self.prefix}] {json.dumps(event, sort_keys=True)}")

    def flush(self) -> None:
        sys.stdout.flush()


class JsonlSink(Sink):
    """Append one JSON line per event; the file handle stays open across
    ticks and is flushed on ``flush()``/``close()``."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = None

    def emit(self, event: dict) -> None:
        if self._fh is None:
            self._fh = self.path.open("a")
        self._fh.write(json.dumps(event, sort_keys=True) + "\n")

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class CallbackSink(Sink):
    def __init__(self, fn: Callable[[dict], None],
                 flush_fn: Optional[Callable[[], None]] = None):
        self.fn = fn
        self.flush_fn = flush_fn

    def emit(self, event: dict) -> None:
        self.fn(event)

    def flush(self) -> None:
        if self.flush_fn is not None:
            self.flush_fn()


class SinkRunner:
    """Retry + dead-letter wrapper around one sink (see module doc)."""

    def __init__(self, sink: Sink, retries: int = 2,
                 dead_letter_path=None):
        self.sink = sink
        self.retries = max(0, int(retries))
        self.stats = SinkStats()
        self.dead_letters: List[dict] = []
        self.dead_letter_path = (pathlib.Path(dead_letter_path)
                                 if dead_letter_path is not None else None)

    def deliver(self, event: dict) -> bool:
        """Emit with retries; dead-letter on exhaustion.  Returns whether
        the event was delivered."""
        tr = get_tracer()
        err: Optional[BaseException] = None
        for attempt in range(self.retries + 1):
            try:
                self.sink.emit(event)
            except Exception as e:
                err = e
                if attempt < self.retries:
                    self.stats.n_retries += 1
                    tr.metrics.inc("sink.retries")
            else:
                self.stats.n_delivered += 1
                tr.metrics.inc("sink.delivered")
                return True
        self.stats.n_dead_lettered += 1
        tr.metrics.inc("sink.dead_lettered")
        rec = dict(event)
        rec["error"] = f"{type(err).__name__}: {err}"
        self.dead_letters.append(rec)
        if self.dead_letter_path is not None:
            self.dead_letter_path.parent.mkdir(parents=True, exist_ok=True)
            with self.dead_letter_path.open("a") as f:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return False

    def note_deduped(self, n: int) -> None:
        """Record rows the delta engine suppressed as duplicates."""
        if n:
            self.stats.n_deduped += int(n)
            get_tracer().metrics.inc("sink.deduped", int(n))

    def flush(self) -> None:
        self.sink.flush()

    def close(self) -> None:
        self.sink.close()
