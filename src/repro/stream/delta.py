"""Delta engine: newly-matching rows between ticks, with content dedup.

Each ``StandingQuery`` owns one ``DeltaTracker``.  After a tick's
evaluation produces the query's full-table mask, the tracker diffs it
against the *last acknowledged* mask and yields exactly the rows that
newly match:

- rows appended since the last ack default to "did not match" (the acked
  mask is padded with False), so a new row that matches notifies once;
- a row that flips True -> False is NOT notified (standing queries push
  matches, not retractions — the acked mask still records the flip, so a
  later flip back to True would re-emit *positionally*);
- **content-hash dedup** sits on top of the positional diff: every
  notified row's content key (``row_key``: text bytes if present, else
  embedding bytes) enters a per-query seen-set, and any later row with
  the same key — a replayed feed chunk, a duplicate submission, or a
  True->False->True flip of identical content — is counted as deduped
  instead of re-notified.  This is what makes notification exactly-once
  per (query, content) across duplicates AND across kill/restart: the
  seen-set and acked mask are checkpointed with the watcher
  (docs/streaming.md#restart-guarantees).

``delta()`` computes, ``ack()`` commits — the watcher acks only after
the tick's sink deliveries are resolved (delivered or dead-lettered), so
a crash between the two re-derives the same notification set on restart
rather than silently skipping it.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Tuple

import numpy as np


def row_key(text: Optional[str], embedding=None) -> str:
    """Content hash of one row: text bytes when present, else embedding
    bytes.  This is the dedup identity — two feed rows with equal content
    notify at most once per standing query."""
    h = hashlib.blake2b(digest_size=16)
    if text is not None:
        h.update(b"t:")
        h.update(text.encode("utf-8"))
    else:
        emb = np.ascontiguousarray(embedding, dtype=np.float32)
        h.update(b"e:")
        h.update(emb.tobytes())
    return h.hexdigest()


class DeltaTracker:
    """Acked-mask diff + content seen-set for one standing query."""

    def __init__(self):
        self.acked = np.zeros(0, dtype=bool)
        self.seen: set = set()

    def delta(self, mask: np.ndarray,
              row_keys: List[str]) -> Tuple[List[int], int]:
        """Rows of ``mask`` that newly match since the last ack.

        Returns ``(emit_rows, n_deduped)``: row ids to notify (their keys
        are committed to the seen-set immediately — a tick that emits a
        row and dead-letters it must not re-emit on the next tick) and
        the count suppressed by content dedup."""
        mask = np.asarray(mask, dtype=bool)
        if len(mask) < len(self.acked):
            raise ValueError(
                f"mask shrank ({len(mask)} < {len(self.acked)} acked rows);"
                " standing queries are append-only")
        base = np.zeros(len(mask), dtype=bool)
        base[:len(self.acked)] = self.acked
        emit, deduped = [], 0
        for i in np.nonzero(mask & ~base)[0]:
            key = row_keys[i]
            if key in self.seen:
                deduped += 1
            else:
                self.seen.add(key)
                emit.append(int(i))
        return emit, deduped

    def ack(self, mask: np.ndarray) -> None:
        """Commit ``mask`` as the delivered baseline for the next tick."""
        self.acked = np.asarray(mask, dtype=bool).copy()

    # -------------------------------------------------------- checkpoint
    def state(self) -> dict:
        return {"seen": sorted(self.seen)}

    def restore_state(self, st: dict, acked: np.ndarray) -> None:
        self.seen = set(st["seen"])
        self.acked = np.asarray(acked, dtype=bool).copy()
