"""Stream sources: deterministic row arrival with per-source rate budgets.

A ``StreamSource`` models one feed of rows entering a standing-query
watcher.  Two clocks matter:

- **arrival** — how many rows of the underlying record list have shown up
  by tick t.  Arrivals are a *deterministic function of the tick*
  (``arrivals(tick)``), never of wall time or call count, so a restarted
  watcher that replays ticks 1..k reconstructs exactly the rows — in
  exactly the order — the killed run ingested (docs/streaming.md).
- **ingestion** — how many arrived rows the watcher has actually drained
  into the table.  A ``RateBudget`` caps rows ingested per source per
  tick; rows past the cap stay in the source's backlog and are ingested
  on later ticks.  Quota exhaustion DEFERS rows, it never drops them —
  asserted in tests/test_stream.py.

The per-source budget layers under the service's per-tenant admission
(``FilterService``): the budget shapes how many rows reach the table per
tick, the tenant budget then gates the oracle spend of evaluating them.

Concrete sources:
- ``SyntheticSource`` — wraps an in-memory record list (e.g. a
  ``make_dataset`` slice) with a seeded, possibly bursty arrival
  schedule.
- ``ReplayFileSource`` — replays a recorded JSONL stream
  (``{"text": ..., "embedding": [...]}`` per line) at a fixed arrival
  rate; the bundled ``examples/watch_demo.py`` stream uses this form.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Callable, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamRow:
    """One feed row.  ``embedding`` may be None only when the session has
    an embedder; sources used with checkpointing should carry embeddings
    so the restored table fingerprint never depends on the encoder."""
    text: Optional[str] = None
    embedding: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.text is None and self.embedding is None:
            raise ValueError("a StreamRow needs text and/or embedding")


@dataclasses.dataclass(frozen=True)
class RateBudget:
    """Per-source ingestion quota: at most ``rows_per_tick`` rows drained
    from this source each tick (None = unmetered)."""
    rows_per_tick: Optional[int] = None

    def cap(self, available: int) -> int:
        if self.rows_per_tick is None:
            return available
        return min(available, int(self.rows_per_tick))


class StreamSource:
    """Deterministic replayable source over a fixed record list.

    ``arrive_fn(tick) -> int`` gives the number of NEW records arriving
    at that tick; it must be a pure function of the tick.  The watcher
    drives the two-phase protocol: ``poll(tick)`` advances the arrival
    cursor, ``take(limit)`` drains up to ``limit`` rows from the backlog.
    """

    def __init__(self, name: str, records: Sequence[StreamRow],
                 arrive_fn: Callable[[int], int]):
        self.name = name
        self.records: List[StreamRow] = list(records)
        self.arrive_fn = arrive_fn
        self.arrived = 0     # records visible by the last polled tick
        self.ingested = 0    # records drained into the table
        self.last_tick = 0

    # ------------------------------------------------------------ protocol
    def poll(self, tick: int) -> int:
        """Advance arrivals to ``tick`` (idempotent per tick, monotonic);
        returns the backlog size.  Catches up skipped ticks so a watcher
        resuming at tick k+1 sees every arrival of ticks <= k+1."""
        while self.last_tick < tick:
            self.last_tick += 1
            self.arrived = min(len(self.records),
                               self.arrived + int(self.arrive_fn(
                                   self.last_tick)))
        return self.backlog

    def take(self, limit: Optional[int] = None) -> List[StreamRow]:
        """Drain up to ``limit`` arrived-but-uningested rows, in order."""
        hi = self.arrived if limit is None else min(
            self.arrived, self.ingested + max(0, int(limit)))
        rows = self.records[self.ingested:hi]
        self.ingested = hi
        return rows

    # ------------------------------------------------------------ state
    @property
    def backlog(self) -> int:
        return self.arrived - self.ingested

    @property
    def exhausted(self) -> bool:
        """Every record has both arrived and been ingested."""
        return self.ingested >= len(self.records)

    def state(self) -> dict:
        return {"arrived": int(self.arrived),
                "ingested": int(self.ingested),
                "last_tick": int(self.last_tick),
                "n_records": len(self.records)}

    def restore_state(self, st: dict) -> None:
        if st["n_records"] != len(self.records):
            raise ValueError(
                f"source {self.name!r}: checkpoint recorded "
                f"{st['n_records']} records, this source has "
                f"{len(self.records)} — not the same stream")
        self.arrived = int(st["arrived"])
        self.ingested = int(st["ingested"])
        self.last_tick = int(st["last_tick"])

    def __repr__(self):
        return (f"{type(self).__name__}({self.name!r}, "
                f"{self.ingested}/{len(self.records)} ingested, "
                f"backlog={self.backlog})")


class SyntheticSource(StreamSource):
    """In-memory records with a seeded arrival schedule.

    ``arrive_per_tick`` is either a fixed int or an ``(lo, hi)`` burst
    range sampled per tick from a tick-keyed RNG — deterministic across
    restarts by construction (the RNG is seeded with ``(seed, tick)``,
    never shared state)."""

    def __init__(self, name: str, texts: Optional[Sequence[str]] = None,
                 embeddings=None, arrive_per_tick=8, seed: int = 0):
        if embeddings is None and texts is None:
            raise ValueError("SyntheticSource needs texts and/or embeddings")
        n = len(texts) if texts is not None else len(embeddings)
        emb = (np.asarray(embeddings, np.float32)
               if embeddings is not None else None)
        records = [StreamRow(
            text=texts[i] if texts is not None else None,
            embedding=emb[i] if emb is not None else None)
            for i in range(n)]
        if isinstance(arrive_per_tick, (tuple, list)):
            lo, hi = int(arrive_per_tick[0]), int(arrive_per_tick[1])

            def arrive_fn(tick: int) -> int:
                rng = np.random.default_rng((int(seed), int(tick)))
                return int(rng.integers(lo, hi + 1))
        else:
            rate = int(arrive_per_tick)

            def arrive_fn(tick: int) -> int:
                return rate
        super().__init__(name, records, arrive_fn)


class ReplayFileSource(StreamSource):
    """Replay a recorded JSONL stream file at a fixed arrival rate.

    Each line is ``{"text": str?, "embedding": [float]?}``; at least one
    of the two must be present.  The whole file is materialized up front —
    replay determinism needs the full record list regardless, and recorded
    streams are checkpoint-sized, not unbounded."""

    def __init__(self, path, name: Optional[str] = None,
                 arrive_per_tick: int = 8):
        path = pathlib.Path(path)
        records = []
        with path.open() as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                emb = rec.get("embedding")
                records.append(StreamRow(
                    text=rec.get("text"),
                    embedding=(np.asarray(emb, np.float32)
                               if emb is not None else None)))
        rate = int(arrive_per_tick)
        super().__init__(name or path.stem, records, lambda tick: rate)
