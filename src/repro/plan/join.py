"""CSV-backed semantic join: sem_join(L, R, predicate) without |L|x|R| calls.

Pair *embeddings and LLM calls* stay sublinear in |L| x |R|; decision state
(the output ``pair_mask`` and a ``decided`` tracker) is two dense bool
matrices — cheap to ~10^8 pairs, after which sparse bookkeeping is needed
(ROADMAP open item).  Both sides are clustered
offline (reusing each SemanticTable's precluster cache); every cluster pair
(A, B) becomes a *block* — a |A| x |B| grid of candidate pairs assumed to
share one predicate rate, the join analogue of a CSV cluster.  Each round:

1. **plan**: every block samples ``max(min_sample, ceil(xi * n_undecided))``
   still-undecided pairs (driver RNG, deterministic under the seed);
2. **oracle**: ALL blocks' sampled pair ids go out in ONE cross-block batch
   (``pair id = i * |R| + j``), the round-vectorized idiom of the filter
   executor;
3. **vote**: one segmented ``vote_clusters`` dispatch labels every block's
   remaining pairs — UniVote from the block's sample rate (default), or
   SimVote over concatenated ``[e_L(i); e_R(j)]`` pair embeddings (built
   lazily per block; quadratic in block side, so prefer "uni" for large
   blocks);
4. **refine**: undetermined blocks split their larger side by 2-means and
   re-enter the queue; blocks whose undecided remainder is small
   (<= min_sample)
   or whose refinement budget is exhausted fall back to direct oracle calls,
   so every pair is decided with bounded work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.core.clustering import kmeans
from repro.core.voting import vote_clusters
from repro.obs.trace import get_tracer
from repro.utils.timing import monotonic


@dataclasses.dataclass
class JoinConfig:
    n_clusters_left: int = 4
    n_clusters_right: int = 4
    xi: float = 0.005
    min_sample: int = 101
    lb: float = 0.15
    ub: Optional[float] = None  # default 1 - lb
    max_refine: int = 3
    vote: str = "uni"  # "uni" | "sim" (sim materializes per-block pair embs)
    sim_bandwidth: Optional[float] = None
    kmeans_iters: int = 50
    seed: int = 0

    @property
    def ub_(self) -> float:
        return self.ub if self.ub is not None else 1.0 - self.lb


def pair_ids(i: np.ndarray, j: np.ndarray, n_right: int) -> np.ndarray:
    """Flat pair id convention: id(i, j) = i * |R| + j (int64)."""
    return np.asarray(i, np.int64) * int(n_right) + np.asarray(j, np.int64)


@dataclasses.dataclass
class JoinBlock:
    """One cluster pair: the candidate grid left x right."""
    left: np.ndarray
    right: np.ndarray

    @property
    def n_pairs(self) -> int:
        return int(len(self.left)) * int(len(self.right))


@dataclasses.dataclass
class JoinRound:
    depth: int
    n_blocks: int
    n_sampled: int
    n_voted: int
    n_undetermined: int


@dataclasses.dataclass
class JoinResult:
    pair_mask: np.ndarray  # (|L|, |R|) bool — pairs satisfying the predicate
    n_llm_calls: int
    input_tokens: int
    output_tokens: int
    n_voted: int      # pairs decided by voting (no LLM call)
    n_fallback: int   # pairs decided by direct oracle fallback
    refine_rounds: int
    total_time_s: float
    round_log: list = dataclasses.field(default_factory=list)

    @property
    def pairs(self) -> np.ndarray:
        """(K, 2) int array of joined (left, right) index pairs."""
        return np.argwhere(self.pair_mask)


def _side_assign(emb: np.ndarray, k: int, seed: int,
                 precomputed: Optional[np.ndarray]) -> np.ndarray:
    if precomputed is not None:
        return np.asarray(precomputed)
    k = min(k, len(emb))
    _, assign, _ = kmeans(jax.random.key(seed), jnp.asarray(emb), k)
    return np.asarray(assign)


def _pair_embs(el: np.ndarray, er: np.ndarray, li: np.ndarray,
               rj: np.ndarray) -> np.ndarray:
    return np.concatenate([el[li], er[rj]], axis=1)


def _split_block(b: JoinBlock, el: np.ndarray, er: np.ndarray,
                 cfg: JoinConfig, depth: int) -> list:
    """Refine: 2-means split of the block's larger side."""
    split_left = len(b.left) >= len(b.right)
    side = b.left if split_left else b.right
    emb = el if split_left else er
    _, a, _ = kmeans(jax.random.key(cfg.seed + depth), jnp.asarray(emb[side]),
                     2, max_iters=cfg.kmeans_iters)
    a = np.asarray(a)
    parts = [side[a == 0], side[a == 1]]
    parts = [p for p in parts if len(p)]
    if len(parts) == 1:  # degenerate embeddings: halve deterministically
        h = len(side) // 2
        parts = [side[:h], side[h:]]
    if split_left:
        return [JoinBlock(p, b.right) for p in parts]
    return [JoinBlock(b.left, p) for p in parts]


def sem_join(emb_left: np.ndarray, emb_right: np.ndarray, oracle,
             cfg: Optional[JoinConfig] = None,
             assign_left: Optional[np.ndarray] = None,
             assign_right: Optional[np.ndarray] = None) -> JoinResult:
    """Join two embedding tables under a pair-level semantic predicate.

    oracle: callable over flat pair ids (``pair_ids``) -> bool array, with
    ``.stats`` accounting — e.g. a SyntheticOracle over flattened pair
    labels, or a ModelOracle whose prompt renders both tuple texts.
    """
    cfg = cfg or JoinConfig()
    tr = get_tracer()
    t0 = monotonic()
    rng = np.random.default_rng(cfg.seed)
    el = np.asarray(emb_left, np.float32)
    er = np.asarray(emb_right, np.float32)
    nl, nr = len(el), len(er)
    before = oracle.stats.clone()
    lb, ub = cfg.lb, cfg.ub_

    # both sides cluster under cfg.seed — identical to what the table API's
    # precluster cache produces, so reuse_clustering=False is bit-compatible
    al = _side_assign(el, cfg.n_clusters_left, cfg.seed, assign_left)
    ar = _side_assign(er, cfg.n_clusters_right, cfg.seed, assign_right)
    lclusters = [np.nonzero(al == c)[0] for c in range(int(al.max()) + 1)]
    rclusters = [np.nonzero(ar == c)[0] for c in range(int(ar.max()) + 1)]
    blocks = [JoinBlock(lc, rc) for lc in lclusters if len(lc)
              for rc in rclusters if len(rc)]

    mask = np.zeros((nl, nr), dtype=bool)
    decided = np.zeros((nl, nr), dtype=bool)
    n_voted = n_fallback = 0
    round_log: list = []
    depth = 0
    while blocks:
        with tr.span("round", kind="round", depth=depth,
                     n_blocks=len(blocks), executor="join") as rsp:
            t_round = monotonic()
            # ---- plan: sample still-undecided pairs in every block ----
            with tr.span("plan", kind="plan"):
                plans = []
                for b in blocks:
                    undec = np.nonzero(
                        ~decided[np.ix_(b.left, b.right)].ravel())[0]
                    if len(undec) == 0:
                        continue
                    n_s = theory.choose_sample_size(len(undec), cfg.xi,
                                                    cfg.min_sample)
                    pick = rng.choice(len(undec), size=n_s, replace=False)
                    flat = undec[pick]
                    rest = np.setdiff1d(undec, flat, assume_unique=False)
                    li = b.left[flat // len(b.right)]
                    rj = b.right[flat % len(b.right)]
                    plans.append((b, li, rj, rest))
            if not plans:
                break

            # ---- one cross-block oracle batch for the whole round ----
            with tr.span("oracle", kind="oracle") as osp:
                batch = np.concatenate([pair_ids(li, rj, nr)
                                        for (_, li, rj, _) in plans])
                flat_labels = oracle(batch)
                osp.set(batch=int(len(batch)))
            offsets = np.cumsum([len(li) for (_, li, rj, _) in plans])[:-1]
            labels_by_block = np.split(flat_labels, offsets)
            for (b, li, rj, _), lab in zip(plans, labels_by_block):
                mask[li, rj] = lab
                decided[li, rj] = True

            # ---- one segmented voting dispatch over live blocks ----
            with tr.span("vote", kind="vote", n_blocks=len(plans)):
                live = [i for i, p in enumerate(plans) if len(p[3])]
                rest_lr = {}
                for i in live:
                    b, _, _, rest = plans[i]
                    rest_lr[i] = (b.left[rest // len(b.right)],
                                  b.right[rest % len(b.right)])
                sim = cfg.vote == "sim"
                votes = vote_clusters(
                    cfg.vote, [labels_by_block[i] for i in live],
                    [len(plans[i][3]) for i in live], lb, ub,
                    emb_unsampled=[_pair_embs(el, er, *rest_lr[i])
                                   for i in live] if sim else None,
                    emb_sampled=[_pair_embs(el, er, plans[i][1],
                                            plans[i][2])
                                 for i in live] if sim else None,
                    bandwidth=cfg.sim_bandwidth)

                round_voted = n_undet = 0
                undet_blocks = []
                for pos, i in enumerate(live):
                    b = plans[i][0]
                    ri, rj = rest_lr[i]
                    vr = votes[pos]
                    tt, ff = vr.decided_true, vr.decided_false
                    mask[ri[tt], rj[tt]] = True
                    decided[ri[tt], rj[tt]] = True
                    decided[ri[ff], rj[ff]] = True
                    round_voted += len(tt) + len(ff)
                    if len(vr.undetermined):
                        n_undet += len(vr.undetermined)
                        undet_blocks.append(b)
            n_voted += round_voted
            round_log.append(JoinRound(
                depth=depth, n_blocks=len(plans),
                n_sampled=int(len(batch)), n_voted=round_voted,
                n_undetermined=n_undet))
            rsp.set(n_sampled=int(len(batch)), n_voted=round_voted,
                    n_undetermined=n_undet)
            tr.metrics.inc("driver.rounds")
            tr.metrics.observe("round.wall_s", monotonic() - t_round)

            if not undet_blocks:
                break
            # ---- refine or fall back ----
            depth += 1
            with tr.span("partition", kind="partition", depth=depth,
                         n_blocks=len(undet_blocks)):
                blocks = []
                for b in undet_blocks:
                    sub = ~decided[np.ix_(b.left, b.right)]
                    n_undec = int(sub.sum())
                    if depth > cfg.max_refine or n_undec <= cfg.min_sample:
                        ii, jj = np.nonzero(sub)
                        li, rj = b.left[ii], b.right[jj]
                        lab = oracle(pair_ids(li, rj, nr))
                        mask[li, rj] = lab
                        decided[li, rj] = True
                        n_fallback += len(li)
                    else:
                        blocks.extend(_split_block(b, el, er, cfg, depth))

    if not decided.all():
        raise RuntimeError(f"join left {int((~decided).sum())} pair(s) "
                           "undecided — refinement invariant violated")
    delta = oracle.stats.delta(before)
    tr.metrics.inc("oracle.calls", delta.n_calls)
    tr.metrics.inc("oracle.input_tokens", delta.input_tokens)
    tr.metrics.inc("oracle.output_tokens", delta.output_tokens)
    tr.metrics.inc("driver.voted", n_voted)
    tr.metrics.inc("driver.fallback", n_fallback)
    return JoinResult(
        pair_mask=mask, n_llm_calls=delta.n_calls,
        input_tokens=delta.input_tokens, output_tokens=delta.output_tokens,
        n_voted=n_voted, n_fallback=n_fallback, refine_rounds=depth,
        total_time_s=monotonic() - t0, round_log=round_log)
