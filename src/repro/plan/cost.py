"""Cost model for predicate ordering (pilot sampling + a CSV call estimate).

Two ingredients:

1. **Pilot statistics.**  Before ordering a multi-predicate plan, each unique
   leaf oracle is probed on one small shared id sample.  That yields a
   selectivity estimate ``s`` (fraction of live tuples passing) and a mean
   per-call token cost — the quantities the classic predicate-ordering rank
   needs.  Pilot calls hit the oracle memo, so ids re-drawn later by the CSV
   sampler are free; the executor still reports them (``pilot_calls``) and
   counts them against the optimized plan's total.

2. **A closed-form estimate of CSV oracle calls** on ``n`` live tuples:
   ``K`` clusters of ~``n/K`` tuples each pay
   ``max(min_sample, ceil(xi * n/K))`` first-round sampled calls, plus an
   n-proportional residual (``RESIDUAL_CALL_RATE``) for re-clustering
   rounds and the linear fallback, capped at ``n`` by memoization.  The
   model only needs to *rank* orders, not predict absolute counts.

Expected cascade cost of an order pi over conjuncts (short-circuit AND):

    cost(pi) = sum_i tokens_i * est_calls(n_i),   n_{i+1} = n_i * s_i

and for OR the survivors are the not-yet-accepted ``n_{i+1} = n_i (1-s_i)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence

import numpy as np

from repro.core.csv_filter import CSVConfig, _derive_xi
from repro.plan.expr import Pred


@dataclasses.dataclass
class PredStats:
    """Pilot-estimated properties of one leaf predicate."""
    name: str
    selectivity: float       # P(pred holds | live tuple), clamped to (0, 1)
    tokens_per_call: float   # mean input+output tokens per oracle call
    n_pilot: int             # pilot ids probed
    pilot_calls: int         # actual LLM calls spent (memo hits excluded)
    pilot_input_tokens: int = 0
    pilot_output_tokens: int = 0
    # where the selectivity came from: "pilot" (fresh probe), "observed"
    # (a previous run's actual pass rate — session memo), or "memo"
    # (decisions fully replayable at the current table version)
    source: str = "pilot"
    # True when the session memo can replay this leaf's decisions without
    # oracle calls: the optimizer then costs the leaf at zero, which orders
    # it first (free live-set shrinkage for everything downstream)
    replayable: bool = False


def pilot_predicates(leaves: Sequence[Pred], live_ids: np.ndarray,
                     rng: np.random.Generator, pilot_size: int
                     ) -> Dict[str, PredStats]:
    """Probe every unique leaf on one shared pilot sample of the live set.

    A single shared sample (a) keeps pilot cost at ``pilot_size`` calls per
    predicate and (b) estimates all selectivities on the same tuples, which
    is what the cascade's conditional survivor counts actually see.
    Selectivities are clamped away from {0, 1}: a pilot that happens to be
    unanimous must not make downstream conjuncts look free.
    """
    n = len(live_ids)
    take = min(pilot_size, n)
    ids = (rng.choice(live_ids, size=take, replace=False) if take < n
           else np.asarray(live_ids))
    out: Dict[str, PredStats] = {}
    for leaf in leaves:
        if leaf.name in out:
            continue
        with leaf.oracle.scope() as sc:
            labels = leaf.oracle(ids)
        d = sc.delta
        tokens = ((d.input_tokens + d.output_tokens) / d.n_calls
                  if d.n_calls else 64.0)
        lo = 1.0 / (take + 1)
        sel = min(1.0 - lo, max(lo, float(np.mean(labels))))
        out[leaf.name] = PredStats(name=leaf.name, selectivity=sel,
                                   tokens_per_call=tokens, n_pilot=take,
                                   pilot_calls=d.n_calls,
                                   pilot_input_tokens=d.input_tokens,
                                   pilot_output_tokens=d.output_tokens)
    return out


# n-proportional residual calls (re-clustering rounds, undetermined-vote
# follow-ups, linear fallback) on top of the first-round closed form.  On
# the Fig. 4 synthetic cases actual calls land at base + (0.1..0.3) * n;
# the conservative end is enough to *rank* orders, which is all the
# optimizer needs — it also keeps the model strictly decreasing in n, so
# shrinking the live set is never modelled as free-but-worthless.
RESIDUAL_CALL_RATE = 0.1


def est_oracle_calls(n: float, cfg: CSVConfig,
                     residual: float = RESIDUAL_CALL_RATE) -> float:
    """Expected CSV oracle calls for one pass over ``n`` live tuples."""
    if n <= 0:
        return 0.0
    if n <= cfg.min_sample:
        return float(n)
    # the same xi the driver will actually run with (epsilon-derived when set)
    xi = _derive_xi(cfg, sigma2=0.25)
    per = n / cfg.n_clusters
    first_round = cfg.n_clusters * max(cfg.min_sample, math.ceil(xi * per))
    # memoization caps any predicate's spend at one call per live tuple
    return float(min(n, first_round + residual * n))
