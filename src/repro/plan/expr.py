"""Expression AST over natural-language semantic predicates.

A query is a boolean tree whose leaves are ``Pred`` nodes — each binds one
predicate to the oracle that answers it (plus optional per-predicate CSV
config overrides).  ``And`` / ``Or`` / ``Not`` compose them; the operators
``&``, ``|``, ``~`` build the tree inline:

    expr = Pred("positive review", o1) & ~Pred("mentions price", o2)

The AST is *logical*: it fixes semantics, not evaluation order.  The
optimizer (``repro.plan.optimizer``) lowers it to a physical cascade by
reordering the children of every And/Or node; the executor
(``repro.plan.executor``) evaluates leaves on shrinking live subsets.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


class Expr:
    """Base node.  Supports ``&``, ``|``, ``~`` composition."""

    def __and__(self, other: "Expr") -> "And":
        return And(self, other)

    def __or__(self, other: "Expr") -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)

    def leaves(self) -> list["Pred"]:
        """All Pred leaves in left-to-right (naive evaluation) order."""
        raise NotImplementedError

    @property
    def label(self) -> str:
        raise NotImplementedError


@dataclasses.dataclass
class Pred(Expr):
    """One natural-language predicate bound to its oracle.

    name: unique identifier (used by the cost model's pilot table and in
    ``PlanResult.order``).
    oracle: callable(ids) -> bool array with ``.stats`` (repro.core.oracle).
    cfg: optional per-predicate ``CSVConfig`` override (e.g. a SimVote
    predicate inside a UniVote plan); None inherits the executor default.
    """
    name: str
    oracle: Any
    cfg: Optional[Any] = None

    def leaves(self) -> list["Pred"]:
        return [self]

    @property
    def label(self) -> str:
        return self.name


class _Nary(Expr):
    """Shared And/Or machinery: flattens nested same-type nodes."""

    _op = "?"

    def __init__(self, *children: Expr):
        flat: list[Expr] = []
        for c in children:
            if not isinstance(c, Expr):
                raise TypeError(f"expected Expr, got {type(c).__name__}")
            if type(c) is type(self):
                flat.extend(c.children)  # (a & b) & c == And(a, b, c)
            else:
                flat.append(c)
        if len(flat) < 1:
            raise ValueError(f"{type(self).__name__} needs >= 1 child")
        self.children: tuple[Expr, ...] = tuple(flat)

    def leaves(self) -> list[Pred]:
        return [leaf for c in self.children for leaf in c.leaves()]

    @property
    def label(self) -> str:
        inner = f" {self._op} ".join(c.label for c in self.children)
        return f"({inner})"

    def __repr__(self):
        return self.label


class And(_Nary):
    """All children must hold; evaluated as a short-circuit cascade."""
    _op = "AND"


class Or(_Nary):
    """Any child suffices; children only see tuples not yet accepted."""
    _op = "OR"


class Not(Expr):
    def __init__(self, child: Expr):
        if not isinstance(child, Expr):
            raise TypeError(f"expected Expr, got {type(child).__name__}")
        self.child = child

    def leaves(self) -> list[Pred]:
        return self.child.leaves()

    @property
    def label(self) -> str:
        return f"NOT {self.child.label}"

    def __repr__(self):
        return self.label


def needs_ordering(expr: Expr) -> bool:
    """True iff some And/Or node has >= 2 children — i.e. a pilot pass can
    actually change the evaluation order.  A bare Pred (or a pure Not chain)
    has a unique order, so the executor skips the pilot entirely and stays
    bit-identical to ``sem_filter``."""
    if isinstance(expr, _Nary):
        if len(expr.children) >= 2:
            return True
        return any(needs_ordering(c) for c in expr.children)
    if isinstance(expr, Not):
        return needs_ordering(expr.child)
    return False
