"""Physical evaluation of predicate expressions: short-circuit CSV cascades.

The executor walks the (optimizer-ordered) tree and runs one CSV filter per
leaf **restricted to the tuples still alive at that node**:

- ``And``: tuples rejected by an earlier conjunct are masked out of later
  runs (``semantic_filter(subset_ids=...)``), so later clusters shrink and
  their samples — hence oracle calls — shrink with them.
- ``Or``: symmetric — tuples already accepted by an earlier disjunct are
  masked out.
- ``Not``: inverts the child's decisions on the live subset (no extra calls).

Every leaf reuses the table's precluster cache: the full-table k-means
assignment is computed once per (n_clusters, seed) and restricted to each
node's live subset, so cascading adds zero clustering work.

A bare ``Pred`` takes the exact ``sem_filter`` path (same precomputed
assignment, no pilot, no subset) and is bit-identical to it — masks and call
counts match under a fixed seed (tests/test_plan.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.csv_filter import (CSVConfig, FilterResult, replay_result,
                                   semantic_filter)
from repro.obs.trace import get_tracer
from repro.plan.cost import PredStats, pilot_predicates
from repro.plan.expr import And, Expr, Not, Or, Pred, needs_ordering
from repro.plan.optimizer import PlanEstimate, optimize
from repro.utils.timing import monotonic

# decorrelates the pilot id draw from the CSV driver's cfg.seed stream
_PILOT_STREAM = 0x9E3779B9


@dataclasses.dataclass
class PreparedPlan:
    """Output of the planning phase (``PlanExecutor.prepare``).

    Splitting planning from execution lets ``repro.api``'s ``.explain()``
    pay the pilot once and hand the SAME pilot statistics to the subsequent
    ``.collect()``: the pilot's oracle calls are memoized, so a collect that
    reuses a PreparedPlan consumes the flip-RNG stream exactly as a cold
    run would (the cold run's own pilot replays the memo), and the reported
    ``pilot_calls`` stay identical to the single-shot path.
    """
    physical: Expr                     # optimizer-ordered (or logical) tree
    estimate: Optional[PlanEstimate]   # None when no ordering choice existed
    pilot_stats: Dict[str, PredStats]


@dataclasses.dataclass
class NodeRecord:
    """One executed leaf: where it ran in the cascade and what it cost."""
    name: str
    n_in: int            # live tuples entering the node
    n_out: int           # tuples the node passed
    n_llm_calls: int
    input_tokens: int
    output_tokens: int
    result: Optional[FilterResult]
    # live tuples decided by replaying session-memoized decisions (zero
    # oracle cost); n_in - n_replayed tuples went through the CSV driver
    n_replayed: int = 0


@dataclasses.dataclass
class PlanResult:
    """Outcome of one plan execution (the expression-level FilterResult)."""
    mask: np.ndarray           # (N,) bool — tuples satisfying the expression
    n_llm_calls: int           # all nodes + pilot probes
    pilot_calls: int
    input_tokens: int
    output_tokens: int
    order: list                # leaf names in executed (physical) order
    naive_order: list          # leaf names in logical left-to-right order
    node_log: list             # NodeRecord per executed leaf
    results: Dict[str, FilterResult]  # per-predicate FilterResult (by name)
    estimate: Optional[PlanEstimate]  # None when no ordering choice existed
    pilot_stats: Dict[str, PredStats]
    total_time_s: float

    @property
    def est_calls_saved(self) -> float:
        """Optimizer-predicted oracle calls avoided vs. naive order."""
        if self.estimate is None:
            return 0.0
        return self.estimate.est_calls_naive - self.estimate.est_calls_ordered

    @property
    def est_tokens_saved(self) -> float:
        if self.estimate is None:
            return 0.0
        return (self.estimate.est_tokens_naive
                - self.estimate.est_tokens_ordered)


class PlanExecutor:
    """Evaluates a ``repro.plan`` expression over one SemanticTable.

    table: anything with ``.embeddings``, ``.precluster(k, seed)``, ``len()``
    (duck-typed; ``repro.core.operators.SemanticTable`` in practice).
    optimize=False keeps the logical child order — the naive left-to-right
    cascade used as the benchmark baseline.
    """

    def __init__(self, table, cfg: Optional[CSVConfig] = None,
                 optimize: bool = True, pilot_size: int = 32,
                 reuse_clustering: bool = True, memo=None):
        self.table = table
        self.cfg = cfg or CSVConfig()
        self.optimize = optimize
        self.pilot_size = int(pilot_size)
        self.reuse_clustering = reuse_clustering
        # optional cross-query reuse hook (duck-typed; repro.api.memo binds
        # the session memo here): ``lookup(leaf, cfg) -> ReplayHit | None``
        # serves memoized decisions, ``record(leaf, cfg, fr, live)`` observes
        # executed leaves.  None keeps the executor fully standalone.
        self.memo = memo
        self.n = len(table)

    def pilot(self, expr: Expr, skip=()) -> Dict[str, PredStats]:
        """Probe every unique leaf on the seed-derived pilot sample.  The
        draw depends only on (cfg.seed, pilot_size, n) — callers may cache
        the result under that key and re-plan with different cost-model
        knobs without touching the oracle again.  ``skip`` names leaves
        whose statistics the caller already has (session memo): the id draw
        is unchanged (probes are independent per leaf), so skipping keeps
        the probed leaves bit-identical to a full pilot."""
        rng = np.random.default_rng([self.cfg.seed, _PILOT_STREAM])
        leaves = [lf for lf in expr.leaves() if lf.name not in set(skip)]
        return pilot_predicates(leaves, np.arange(self.n), rng,
                                self.pilot_size)

    def prepare(self, expr: Expr,
                pilot_stats: Optional[Dict[str, PredStats]] = None
                ) -> PreparedPlan:
        """Planning phase only: pilot-sample and cost-order, no cascade run.

        Pilot oracle calls are spent here (and memoized); execution through
        ``run(expr, prepared=...)`` reuses them so planning + execution is
        bit-identical — same masks, flip-stream consumption, and call
        counts — to a single ``run(expr)``.  Pass ``pilot_stats`` to reuse
        an earlier ``pilot()`` probe (same seed/pilot_size) and only redo
        the host-side ordering.
        """
        self._check_names(expr)
        if self.optimize and needs_ordering(expr):
            if pilot_stats is None:
                tr = get_tracer()
                with tr.span("pilot", kind="plan",
                             pilot_size=self.pilot_size) as sp:
                    pilot_stats = self.pilot(expr)
                    n_pilot = sum(s.pilot_calls for s in pilot_stats.values())
                    sp.set(calls=n_pilot)
                    tr.metrics.inc("oracle.calls", n_pilot)
                    tr.metrics.inc("oracle.input_tokens", sum(
                        s.pilot_input_tokens for s in pilot_stats.values()))
                    tr.metrics.inc("oracle.output_tokens", sum(
                        s.pilot_output_tokens for s in pilot_stats.values()))
            estimate = optimize(expr, self.n, pilot_stats, self.cfg)
            return PreparedPlan(physical=estimate.ordered, estimate=estimate,
                                pilot_stats=pilot_stats)
        return PreparedPlan(physical=expr, estimate=None, pilot_stats={})

    def run(self, expr: Expr,
            prepared: Optional[PreparedPlan] = None) -> PlanResult:
        t0 = monotonic()
        if prepared is None:
            prepared = self.prepare(expr)
        else:
            self._check_names(expr)
        self._node_log: list = []
        self._results: Dict[str, FilterResult] = {}
        self._order: list = []

        estimate = prepared.estimate
        pilot_stats = prepared.pilot_stats
        physical = prepared.physical

        mask = self._eval(physical, np.arange(self.n))

        pilot_calls = sum(s.pilot_calls for s in pilot_stats.values())
        calls = pilot_calls + sum(r.n_llm_calls for r in self._node_log)
        in_tok = (sum(s.pilot_input_tokens for s in pilot_stats.values())
                  + sum(r.input_tokens for r in self._node_log))
        out_tok = (sum(s.pilot_output_tokens for s in pilot_stats.values())
                   + sum(r.output_tokens for r in self._node_log))
        return PlanResult(
            mask=mask, n_llm_calls=calls, pilot_calls=pilot_calls,
            input_tokens=in_tok, output_tokens=out_tok,
            order=list(self._order),
            naive_order=[p.name for p in expr.leaves()],
            node_log=self._node_log, results=self._results,
            estimate=estimate, pilot_stats=pilot_stats,
            total_time_s=monotonic() - t0)

    @staticmethod
    def _check_names(expr: Expr) -> None:
        """Leaf names key the pilot table and per-node results: one name
        bound to two different oracles would silently cost/order the second
        with the first's statistics."""
        seen: Dict[str, int] = {}
        for leaf in expr.leaves():
            prev = seen.setdefault(leaf.name, id(leaf.oracle))
            if prev != id(leaf.oracle):
                raise ValueError(
                    f"predicate name {leaf.name!r} is bound to two different "
                    "oracles; give each predicate a unique name")

    # ---------------------------------------------------------- evaluation
    def _eval(self, node: Expr, live: np.ndarray) -> np.ndarray:
        """Returns a full-length bool mask, meaningful at ``live`` positions."""
        if isinstance(node, Pred):
            return self._eval_pred(node, live)
        if isinstance(node, Not):
            child = self._eval(node.child, live)
            out = np.zeros(self.n, dtype=bool)
            out[live] = ~child[live]
            return out
        if isinstance(node, And):
            cur = live
            for c in node.children:
                if len(cur) == 0:
                    break
                m = self._eval(c, cur)
                cur = cur[m[cur]]  # short-circuit: only passers continue
            out = np.zeros(self.n, dtype=bool)
            out[cur] = True
            return out
        assert isinstance(node, Or)
        out = np.zeros(self.n, dtype=bool)
        rem = live
        for c in node.children:
            if len(rem) == 0:
                break
            m = self._eval(c, rem)
            out[rem[m[rem]]] = True
            rem = rem[~m[rem]]  # accepted tuples never re-evaluated
        return out

    def _eval_pred(self, leaf: Pred, live: np.ndarray) -> np.ndarray:
        if len(live) == 0:
            return np.zeros(self.n, dtype=bool)
        cfg = leaf.cfg if leaf.cfg is not None else self.cfg
        hit = self.memo.lookup(leaf, cfg) if self.memo is not None else None
        if hit is not None:
            return self._replay_pred(leaf, cfg, live, hit)
        tr = get_tracer()
        with tr.span("plan_node", kind="plan_node", node=leaf.name,
                     n_in=int(len(live))) as sp:
            assign = (self.table.precluster(cfg.n_clusters, cfg.seed)
                      if self.reuse_clustering else None)
            subset = None if len(live) == self.n else live
            fr = semantic_filter(self.table.embeddings, leaf.oracle, cfg,
                                 precomputed_assign=assign,
                                 subset_ids=subset)
            sp.set(n_out=int(fr.mask.sum()), calls=int(fr.n_llm_calls))
        if self.memo is not None:
            self.memo.record(leaf, cfg, fr, live)
        self._log_node(leaf, live, fr)
        return fr.mask

    def _replay_pred(self, leaf: Pred, cfg: CSVConfig, live: np.ndarray,
                     hit) -> np.ndarray:
        """Serve a leaf from session-memoized decisions: clean-cluster rows
        replay the stored mask at zero oracle cost; rows of clusters dirtied
        by ``append``/``update`` since the memo's table version are re-voted
        through the normal driver, restricted to that dirty subset."""
        tr = get_tracer()
        t0 = monotonic()
        with tr.span("plan_node", kind="plan_node", node=leaf.name,
                     n_in=int(len(live)), replay=True) as sp:
            out = np.zeros(self.n, dtype=bool)
            replay = live[np.isin(live, hit.replay_rows)]
            out[replay] = hit.mask[replay]
            sub = None
            rerun = live[np.isin(live, hit.rerun_rows)]
            if len(rerun):
                assign = (self.table.precluster(cfg.n_clusters, cfg.seed)
                          if self.reuse_clustering else None)
                sub = semantic_filter(self.table.embeddings, leaf.oracle,
                                      cfg, precomputed_assign=assign,
                                      subset_ids=rerun)
                out[rerun] = sub.mask[rerun]
            sp.set(n_out=int(out.sum()), n_replayed=int(len(replay)))
            tr.metrics.inc("memo.replays")
            tr.metrics.inc("memo.replayed_rows", int(len(replay)))
            tr.metrics.inc("memo.dirty_clusters",
                           int(getattr(hit, "n_dirty_clusters", 0)))
        fr = replay_result(out, n_input=len(live), n_replayed=len(replay),
                           rerun=sub, total_time_s=monotonic() - t0)
        if self.memo is not None:
            self.memo.record(leaf, cfg, fr, live)
        self._log_node(leaf, live, fr)
        return out

    def _log_node(self, leaf: Pred, live: np.ndarray,
                  fr: FilterResult) -> None:
        self._order.append(leaf.name)
        self._results[leaf.name] = fr
        self._node_log.append(NodeRecord(
            name=leaf.name, n_in=int(len(live)),
            n_out=int(fr.mask.sum()), n_llm_calls=fr.n_llm_calls,
            input_tokens=fr.input_tokens, output_tokens=fr.output_tokens,
            result=fr, n_replayed=int(fr.n_replayed)))
