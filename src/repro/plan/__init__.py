"""Semantic query plans: composable predicate DAGs over CSV filters.

Public API:
    Pred / And / Or / Not            — expression AST (&, |, ~ operators)
    PlanExecutor / PlanResult        — cost-ordered short-circuit cascades
    optimize / PlanEstimate          — logical -> physical lowering
    pilot_predicates / est_oracle_calls — the cost model
    sem_join / JoinConfig / JoinResult / pair_ids — CSV-backed semantic join

Operator-layer entry points: ``SemanticTable.sem_filter_expr(expr)`` and
``SemanticTable.sem_join(right, oracle)``.  See docs/query_plans.md.
"""
from repro.plan.expr import And, Expr, Not, Or, Pred, needs_ordering
from repro.plan.cost import PredStats, est_oracle_calls, pilot_predicates
from repro.plan.optimizer import (NodeEstimate, PlanEstimate, node_estimates,
                                  optimize)
from repro.plan.executor import (NodeRecord, PlanExecutor, PlanResult,
                                 PreparedPlan)
from repro.plan.join import (JoinBlock, JoinConfig, JoinResult, JoinRound,
                             pair_ids, sem_join)

__all__ = [
    "And", "Expr", "Not", "Or", "Pred", "needs_ordering",
    "PredStats", "est_oracle_calls", "pilot_predicates",
    "NodeEstimate", "PlanEstimate", "node_estimates", "optimize",
    "NodeRecord", "PlanExecutor", "PlanResult", "PreparedPlan",
    "JoinBlock", "JoinConfig", "JoinResult", "JoinRound",
    "pair_ids", "sem_join",
]
