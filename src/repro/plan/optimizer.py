"""Logical -> physical lowering: cost-based reordering of And/Or children.

Given pilot statistics for every leaf (``repro.plan.cost``), the optimizer
rewrites each And/Or node so its children run cheapest-first *in expectation*:
small fan-ins (the common case) are solved exactly by enumerating all
permutations of the expected-cascade-cost objective; larger fan-ins fall back
to the classic rank heuristic ``cost / (1 - selectivity)`` (AND) resp.
``cost / selectivity`` (OR), which is optimal for independent linear-cost
predicates and a good seed order otherwise.

The objective is expected *token* cost (calls weighted by each predicate's
pilot-measured tokens per call), with expected calls as tie-break — for
uniform-token oracles the two coincide.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional

from repro.core.csv_filter import CSVConfig
from repro.plan.cost import PredStats, est_oracle_calls
from repro.plan.expr import And, Expr, Not, Pred, _Nary

# exact ordering up to this fan-in (6! = 720 cheap host-side evaluations);
# beyond it the rank heuristic keeps planning O(k log k)
MAX_EXHAUSTIVE = 6


@dataclasses.dataclass
class PlanEstimate:
    """Optimizer output: the reordered tree plus its predicted economics."""
    ordered: Expr
    order: list              # leaf names, physical (chosen) order
    naive_order: list        # leaf names, left-to-right logical order
    est_tokens_ordered: float
    est_tokens_naive: float
    est_calls_ordered: float
    est_calls_naive: float


def _leaf_cfg(leaf: Pred, default_cfg: CSVConfig) -> CSVConfig:
    return leaf.cfg if leaf.cfg is not None else default_cfg


def selectivity(expr: Expr, stats: Dict[str, PredStats]) -> float:
    """Estimated P(expr holds) assuming child independence."""
    if isinstance(expr, Pred):
        return stats[expr.name].selectivity
    if isinstance(expr, Not):
        return 1.0 - selectivity(expr.child, stats)
    sels = [selectivity(c, stats) for c in expr.children]
    prod = 1.0
    if isinstance(expr, And):
        for s in sels:
            prod *= s
        return prod
    for s in sels:
        prod *= (1.0 - s)
    return 1.0 - prod


def expected_cost(expr: Expr, n: float, stats: Dict[str, PredStats],
                  default_cfg: CSVConfig) -> tuple[float, float]:
    """(expected tokens, expected calls) of evaluating ``expr`` on ``n`` live
    tuples with its children in their CURRENT order (short-circuit cascade)."""
    if isinstance(expr, Pred):
        st = stats[expr.name]
        if st.replayable:
            return 0.0, 0.0  # session memo replays decisions for free
        calls = est_oracle_calls(n, _leaf_cfg(expr, default_cfg))
        return calls * st.tokens_per_call, calls
    if isinstance(expr, Not):
        return expected_cost(expr.child, n, stats, default_cfg)
    conj = isinstance(expr, And)
    tok = calls = 0.0
    live = float(n)
    for c in expr.children:
        t, k = expected_cost(c, live, stats, default_cfg)
        tok += t
        calls += k
        s = selectivity(c, stats)
        live *= s if conj else (1.0 - s)
    return tok, calls


def _reorder_node(node: _Nary, n: float, stats, default_cfg) -> _Nary:
    """Pick the child order minimizing the expected cascade cost."""
    kids = list(node.children)
    if len(kids) <= 1:
        return node
    conj = isinstance(node, And)
    if len(kids) <= MAX_EXHAUSTIVE:
        best = None
        for perm in itertools.permutations(range(len(kids))):
            tok = calls = 0.0
            live = float(n)
            for i in perm:
                t, k = expected_cost(kids[i], live, stats, default_cfg)
                tok += t
                calls += k
                s = selectivity(kids[i], stats)
                live *= s if conj else (1.0 - s)
            key = (tok, calls, perm)  # perm tie-break: deterministic plans
            if best is None or key < best:
                best = key
        order = best[2]
    else:
        def rank(i: int) -> tuple:
            tok, _ = expected_cost(kids[i], n, stats, default_cfg)
            s = selectivity(kids[i], stats)
            drop = (1.0 - s) if conj else s  # fraction short-circuited away
            return (tok / max(drop, 1e-9), i)
        order = sorted(range(len(kids)), key=rank)
    return type(node)(*[kids[i] for i in order])


def _lower(expr: Expr, n: float, stats, default_cfg) -> Expr:
    """Recursively reorder every And/Or node (children first, at the entry
    live-set size — survivor sizes inside siblings are second-order)."""
    if isinstance(expr, Pred):
        return expr
    if isinstance(expr, Not):
        return Not(_lower(expr.child, n, stats, default_cfg))
    kids = [_lower(c, n, stats, default_cfg) for c in expr.children]
    return _reorder_node(type(expr)(*kids), n, stats, default_cfg)


@dataclasses.dataclass
class NodeEstimate:
    """Predicted economics of one leaf at its position in the cascade."""
    name: str
    est_live_in: float       # live tuples expected to reach this node
    est_calls: float         # est_oracle_calls at that live-set size
    selectivity: Optional[float]  # pilot estimate; None without a pilot


def node_estimates(expr: Expr, n: float, stats: Dict[str, PredStats],
                   default_cfg: CSVConfig) -> list:
    """Per-leaf cost predictions for ``expr`` in its CURRENT child order.

    The walk mirrors ``expected_cost``'s short-circuit survivor arithmetic;
    leaves without pilot statistics assume selectivity 0.5 for survivor
    propagation but report ``selectivity=None``.  Powers ``.explain()`` in
    ``repro.api`` — pure arithmetic, zero oracle calls.
    """
    out: list = []

    def sel_of(node: Expr) -> float:
        if isinstance(node, Pred):
            st = stats.get(node.name)
            return st.selectivity if st is not None else 0.5
        if isinstance(node, Not):
            return 1.0 - sel_of(node.child)
        sels = [sel_of(c) for c in node.children]
        prod = 1.0
        if isinstance(node, And):
            for s in sels:
                prod *= s
            return prod
        for s in sels:
            prod *= (1.0 - s)
        return 1.0 - prod

    def walk(node: Expr, live: float) -> None:
        if isinstance(node, Pred):
            st = stats.get(node.name)
            est = (0.0 if st is not None and st.replayable
                   else est_oracle_calls(live, _leaf_cfg(node, default_cfg)))
            out.append(NodeEstimate(
                name=node.name, est_live_in=float(live), est_calls=est,
                selectivity=st.selectivity if st is not None else None))
            return
        if isinstance(node, Not):
            walk(node.child, live)
            return
        conj = isinstance(node, And)
        cur = float(live)
        for c in node.children:
            walk(c, cur)
            s = sel_of(c)
            cur *= s if conj else (1.0 - s)

    walk(expr, float(n))
    return out


def optimize(expr: Expr, n: int, stats: Dict[str, PredStats],
             default_cfg: Optional[CSVConfig] = None) -> PlanEstimate:
    """Lower a logical expression to its cost-ordered physical form."""
    default_cfg = default_cfg or CSVConfig()
    ordered = _lower(expr, float(n), stats, default_cfg)
    tok_o, calls_o = expected_cost(ordered, float(n), stats, default_cfg)
    tok_n, calls_n = expected_cost(expr, float(n), stats, default_cfg)
    return PlanEstimate(
        ordered=ordered,
        order=[p.name for p in ordered.leaves()],
        naive_order=[p.name for p in expr.leaves()],
        est_tokens_ordered=tok_o, est_tokens_naive=tok_n,
        est_calls_ordered=calls_o, est_calls_naive=calls_n)
