"""whisper-base [audio] — enc-dec; conv frontend STUB [arXiv:2212.04356; unverified].

``input_specs()`` supplies 1500 precomputed frame embeddings (post-conv stem);
decoder sequence length follows the declared shape.  LayerNorm + sinusoidal
positions + GELU MLP per the original architecture.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    encoder_layers=6,
    encoder_len=1500,
    norm_type="ln",
    pos_type="sinusoidal",
    mlp_type="gelu",
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = CONFIG.replace(
    n_layers=2, encoder_layers=2, encoder_len=12, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512, dtype="float32",
    attn_chunk_q=16, attn_chunk_kv=16,
)
