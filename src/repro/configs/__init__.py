"""Architecture registry: the 10 assigned archs + the paper's own backbones.

``get_config(name)`` returns the full-scale config (dry-run only);
``smoke_config(name)`` returns a reduced same-family config that runs a real
forward/train step on CPU.
"""
from __future__ import annotations

from repro.configs.registry import (ARCHS, get_config, list_archs,
                                    smoke_config, input_specs,
                                    LONG_CONTEXT_OK, long_context_skip_reason)
