"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].

Period-8 superblock: attention at index 4, Mamba elsewhere; MoE FFN on odd
indices, dense FFN on even (Jamba applies MoE every other layer).
"""
from repro.models.config import LayerSpec, ModelConfig


def _layer(i: int) -> LayerSpec:
    kind = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(kind=kind, ffn=ffn)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    pattern=tuple(_layer(i) for i in range(8)),
    n_experts=16,
    top_k=2,
    ssm_state=16,
    moe_chunk=1024,
    source="[arXiv:2403.19887; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    n_experts=4, top_k=2, dtype="float32", moe_chunk=0, ssm_chunk=16,
    attn_chunk_q=16, attn_chunk_kv=16,
)
