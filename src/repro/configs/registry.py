"""Central registry gluing per-arch config modules to the launcher."""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SHAPES, ShapeCell

_ARCH_MODULES = [
    "falcon_mamba_7b",
    "mixtral_8x22b",
    "dbrx_132b",
    "internvl2_26b",
    "gemma3_12b",
    "stablelm_12b",
    "codeqwen15_7b",
    "qwen15_05b",
    "jamba_v01_52b",
    "whisper_base",
    # the paper's own backbones (oracle LLM + proxy + embedder)
    "llama31_8b",
    "llama32_3b_proxy",
    "e5_encoder",
]

ARCHS: Dict[str, "object"] = {}
for m in _ARCH_MODULES:
    mod = importlib.import_module(f"repro.configs.{m}")
    ARCHS[mod.CONFIG.name] = mod


def list_archs():
    return list(ARCHS.keys())


def get_config(name: str) -> ModelConfig:
    return ARCHS[name].CONFIG


def smoke_config(name: str) -> ModelConfig:
    return ARCHS[name].SMOKE


# ---------------------------------------------------------------------------
# long-context applicability (see DESIGN.md §5)
# ---------------------------------------------------------------------------

LONG_CONTEXT_OK = {
    "falcon-mamba-7b": "O(1) SSM state",
    "jamba-v0.1-52b": "hybrid: 4/32 attention layers, rest O(1) Mamba state",
    "mixtral-8x22b": "SWA: ring KV bounded by window=4096",
    "gemma3-12b": "5:1 local(1024-ring):global; 8 global layers keep full KV "
                  "(sharded); beyond its 128k design point — boundary case",
}

_LONG_SKIP = {
    "dbrx-132b": "pure full attention: unbounded 500k KV on all 40 layers",
    "internvl2-26b": "pure full attention on all 48 layers",
    "stablelm-12b": "pure full attention on all 40 layers",
    "codeqwen1.5-7b": "pure full attention (MHA kv=32) on all 32 layers",
    "qwen1.5-0.5b": "pure full attention (MHA kv=16) on all 24 layers",
    "whisper-base": "enc-dec with 448-token decoder design limit",
}


def long_context_skip_reason(name: str):
    return _LONG_SKIP.get(name)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """Abstract inputs for the step function selected by shape.kind.

    train/prefill: token batch (+ modality stubs).  decode: one new token per
    sequence + a KV cache covering shape.seq_len.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        P = cfg.num_prefix_embeds
        spec = {"tokens": sds((B, S - P), i32)}
        if shape.kind == "train":
            spec["targets"] = sds((B, S - P), i32)
        if P:
            spec["prefix_embeds"] = sds((B, P, cfg.d_model), dt)
        if cfg.is_encdec:
            spec["enc_frames"] = sds((B, cfg.encoder_len, cfg.d_model), dt)
        return spec

    # decode: 1 new token against a cache of S
    from repro.models import lm
    cache = jax.eval_shape(lambda: lm.make_cache(cfg, B, S))
    return {
        "tokens": sds((B,), i32),
        "pos": sds((B,), i32),
        "cache": cache,
    }
