"""qwen1.5-0.5b [dense] — QKV bias, MHA kv=16 [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    qkv_bias=True,
    source="[hf:Qwen/Qwen1.5-0.5B; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
)
