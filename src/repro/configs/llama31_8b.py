"""llama3.1-8b — the paper's oracle LLM backbone [arXiv:2302.13971 lineage; hf]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    rope_theta=5e5,
    source="[hf:meta-llama/Llama-3.1-8B; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
)
