"""e5-large-style embedding encoder — the paper's default embedding model
[arXiv:2212.03533].  Used bidirectionally with mean pooling (see
repro.embeddings.encoder)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="e5-large",
    family="encoder",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30592,  # bert-style vocab, padded
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    norm_type="ln",
    pos_type="sinusoidal",
    mlp_type="gelu",
    source="[arXiv:2212.03533; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    dtype="float32",
)
