"""dbrx-132b [moe] — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    pattern=(LayerSpec(kind="attn", ffn="moe"),),
    n_experts=16,
    top_k=4,
    rope_theta=5e5,
    moe_chunk=1024,
    source="[hf:databricks/dbrx-base; unverified]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512,
    n_experts=4, top_k=2, dtype="float32", moe_chunk=0,
    attn_chunk_q=16, attn_chunk_kv=16,
)
