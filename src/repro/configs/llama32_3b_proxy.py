"""llama3.2-3b — the paper's cascade *proxy* model (Lotus/BARGAIN baselines)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b-proxy",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    rope_theta=5e5,
    source="[hf:meta-llama/Llama-3.2-3B; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512,
    dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
)
