"""mixtral-8x22b [moe] — 8-expert top-2 MoE with SWA [arXiv:2401.04088; hf]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec(kind="attn", window=4096, ffn="moe"),),
    n_experts=8,
    top_k=2,
    rope_theta=1e6,
    moe_chunk=1024,
    source="[arXiv:2401.04088; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    n_experts=4, top_k=2, dtype="float32", moe_chunk=0,
    pattern=(LayerSpec(kind="attn", window=16, ffn="moe"),),
    attn_chunk_q=16, attn_chunk_kv=16,
)
