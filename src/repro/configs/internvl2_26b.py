"""internvl2-26b [vlm] — InternViT (stub) + InternLM2 backbone [arXiv:2404.16821; hf].

The vision frontend is a STUB per assignment: ``input_specs()`` supplies 256
precomputed patch embeddings prepended to the text tokens; declared seq_len
counts the combined sequence.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,  # padded to 92672 for TP divisibility
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    num_prefix_embeds=256,
    source="[arXiv:2404.16821; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    num_prefix_embeds=4, dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
)
