"""codeqwen1.5-7b [dense] — qwen1.5 arch, QKV bias, MHA kv=32 [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    qkv_bias=True,
    rope_theta=1e6,
    source="[hf:Qwen/CodeQwen1.5-7B; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
)
