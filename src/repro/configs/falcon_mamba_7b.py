"""falcon-mamba-7b [ssm] — attention-free Mamba-1 LM [arXiv:2410.05355; unverified]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=32,  # unused (attention-free); kept for interface uniformity
    n_kv_heads=8,
    d_ff=0,
    vocab_size=65024,
    pattern=(LayerSpec(kind="mamba", ffn="none"),),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    source="[arXiv:2410.05355; unverified]",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, vocab_size=512,
    dtype="float32", ssm_chunk=16,
)
