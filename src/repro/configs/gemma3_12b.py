"""gemma3-12b [dense] — 5:1 local(1024):global attention, 262k vocab
[hf:google/gemma-3-1b-pt; unverified].  head_dim=256 explicit (≠ d/H)."""
from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=1024, ffn="dense")
_GLOBAL = LayerSpec(kind="attn", window=None, ffn="dense")

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    rope_theta=1e6,
    source="[hf:google/gemma-3-1b-pt; unverified]",
)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
    pattern=(LayerSpec(kind="attn", window=16, ffn="dense"),) * 5 + (_GLOBAL,),
)
