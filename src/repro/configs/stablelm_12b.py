"""stablelm-12b [dense] — GQA kv=8 [hf:stabilityai/stablelm-2-1_6b; hf]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    source="[hf:stabilityai/stablelm-2-1_6b; hf]",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    dtype="float32", attn_chunk_q=16, attn_chunk_kv=16,
)
