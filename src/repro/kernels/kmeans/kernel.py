"""Pallas TPU kernel: tiled pairwise squared-L2 + fused argmin.

TPU adaptation of the scikit-learn CPU assignment step: the (N,K) distance
matrix is never materialized in HBM.  Each grid step streams a (BN, D) tile
of points through VMEM, forms the (BN, K) distance tile on the MXU via
-2 x @ c^T (+ norms), and reduces to (assign, dmin) in-register.  K and D
are kept whole per tile: K <= 256 clusters and D <= 4096 embedding dims fit
VMEM comfortably (BN*D*4 + K*D*4 + BN*K*4 ~ 8.5 MB at BN=256, D=4096, K=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, csq_ref, assign_ref, dmin_ref):
    x = x_ref[...].astype(jnp.float32)  # (BN, D)
    c = c_ref[...].astype(jnp.float32)  # (K, D)
    csq = csq_ref[...]  # (1, K)
    xsq = jnp.sum(x * x, axis=-1, keepdims=True)  # (BN, 1)
    scores = lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (BN, K)
    d = jnp.maximum(xsq - 2.0 * scores + csq, 0.0)
    dmin = jnp.min(d, axis=-1)
    k = d.shape[-1]
    iota = lax.broadcasted_iota(jnp.int32, d.shape, 1)
    amin = jnp.min(jnp.where(d == dmin[:, None], iota, k), axis=-1)
    assign_ref[...] = amin.astype(jnp.int32)
    dmin_ref[...] = dmin


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def assign_clusters_pallas(x, cents, block_n: int = 256, interpret: bool = False):
    """x (N,D), cents (K,D) -> (assign (N,), dmin (N,)); N padded to block_n."""
    n, d = x.shape
    k = cents.shape[0]
    n_pad = (n + block_n - 1) // block_n * block_n
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    csq = jnp.sum(jnp.square(cents.astype(jnp.float32)), axis=-1)[None, :]

    assign, dmin = pl.pallas_call(
        _assign_kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(x, cents, csq)
    return assign[:n], dmin[:n]
