"""Pure-jnp oracle for the k-means assignment step."""
from __future__ import annotations

import jax.numpy as jnp


def assign_clusters_ref(x, cents):
    """x (N,D), cents (K,D) -> (assign (N,) int32, dmin (N,) f32).

    Squared-L2 distances via the expansion ||x||^2 - 2 x.c + ||c||^2.
    """
    xf = x.astype(jnp.float32)
    cf = cents.astype(jnp.float32)
    xsq = jnp.sum(jnp.square(xf), axis=-1, keepdims=True)  # (N,1)
    csq = jnp.sum(jnp.square(cf), axis=-1)  # (K,)
    d = xsq - 2.0 * (xf @ cf.T) + csq[None, :]  # (N,K)
    d = jnp.maximum(d, 0.0)
    return jnp.argmin(d, axis=-1).astype(jnp.int32), jnp.min(d, axis=-1)
