from repro.kernels.kmeans.ops import assign_clusters
