"""jit'd wrapper: Pallas on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.kmeans.kernel import assign_clusters_pallas
from repro.kernels.kmeans.ref import assign_clusters_ref


def assign_clusters(x, cents):
    """(assign (N,) int32, dmin (N,) f32) — platform-dispatched."""
    if jax.default_backend() == "tpu":
        return assign_clusters_pallas(x, cents)
    return assign_clusters_ref(x, cents)
