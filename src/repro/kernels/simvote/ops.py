"""jit'd wrapper: Pallas on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.simvote.kernel import simvote_scores_pallas
from repro.kernels.simvote.ref import simvote_scores_ref


def simvote_scores(x, s, y, tau):
    if jax.default_backend() == "tpu":
        return simvote_scores_pallas(x, s, y, tau)
    return simvote_scores_ref(x, s, y, float(tau))
