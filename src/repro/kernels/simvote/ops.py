"""jit'd wrappers: Pallas on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.simvote.kernel import (simvote_scores_pallas,
                                          simvote_scores_segmented_pallas)
from repro.kernels.simvote.ref import (simvote_scores_ref,
                                       simvote_scores_segmented_ref)


def simvote_scores(x, s, y, tau):
    if jax.default_backend() == "tpu":
        return simvote_scores_pallas(x, s, y, tau)
    return simvote_scores_ref(x, s, y, float(tau))


def simvote_scores_segmented(x, counts, s_pad, y_pad, taus):
    """Segmented (per-cluster) scoring for a whole round in one dispatch.

    See ``simvote_scores_segmented_ref`` for the argument contract; on TPU the
    streamed Pallas kernel avoids materializing the (N x C*M) weight matrix.
    """
    if jax.default_backend() == "tpu":
        return simvote_scores_segmented_pallas(x, counts, s_pad, y_pad, taus)
    return simvote_scores_segmented_ref(x, counts, s_pad, y_pad, taus)
