from repro.kernels.simvote.ops import simvote_scores
