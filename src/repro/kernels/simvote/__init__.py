from repro.kernels.simvote.ops import simvote_scores, simvote_scores_segmented
