"""Pure-jnp oracle for SimVote scoring (paper Eq. 4), plain and segmented."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def simvote_scores_ref(x, s, y, tau: float):
    """x (N,D) unsampled, s (M,D) sampled, y (M,) in {0,1} -> scores (N,).

    score_i = sum_j w_ij y_j / sum_j w_ij,  w_ij = exp(-||x_i - s_j||^2 / 2 tau^2)
    """
    xf, sf = x.astype(jnp.float32), s.astype(jnp.float32)
    d2 = (jnp.sum(xf * xf, -1, keepdims=True)
          - 2.0 * xf @ sf.T + jnp.sum(sf * sf, -1)[None, :])  # (N,M)
    w = jnp.exp(-jnp.maximum(d2, 0.0) / (2.0 * tau * tau))
    num = w @ y.astype(jnp.float32)
    den = jnp.sum(w, axis=-1)
    return num / jnp.maximum(den, 1e-30)


def simvote_scores_segmented_ref(x, counts, s_pad, y_pad, taus):
    """Segmented SimVote scoring over all clusters of a round.

    x       (N, D)   unsampled rows, grouped by cluster (counts[c] rows each)
    counts  (C,)     host ints — rows of x belonging to each cluster
    s_pad   (C, M, D) per-cluster samples, zero-padded along M
    y_pad   (C, M)   labels in {0, 1}; -1 marks M-padding
    taus    (C,)     per-cluster Gaussian bandwidth
    -> scores (N,)

    Reference semantics = C independent ``simvote_scores_ref`` calls on each
    cluster's own (unpadded) slice, bit-identical to the sequential driver's
    per-cluster scoring and O(sum N_c*M_c) work/memory.  The single-launch
    version of this contract is the Pallas kernel
    (``simvote_scores_segmented_pallas``); a one-shot block-diagonally
    masked jnp formulation would burn C times the FLOPs and materialize an
    (N x C*M) weight matrix for no dispatch win on CPU.
    """
    counts = np.asarray(counts, np.int64)
    taus = np.asarray(taus, np.float64)
    out, start = [], 0
    for ci, n_c in enumerate(counts):
        if n_c == 0:
            continue
        m_c = int(np.sum(np.asarray(y_pad[ci]) >= 0.0))
        out.append(simvote_scores_ref(x[start:start + n_c],
                                      s_pad[ci, :m_c], y_pad[ci, :m_c],
                                      float(taus[ci])))
        start += int(n_c)
    if not out:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate(out)
