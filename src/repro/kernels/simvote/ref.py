"""Pure-jnp oracle for SimVote scoring (paper Eq. 4)."""
from __future__ import annotations

import jax.numpy as jnp


def simvote_scores_ref(x, s, y, tau: float):
    """x (N,D) unsampled, s (M,D) sampled, y (M,) in {0,1} -> scores (N,).

    score_i = sum_j w_ij y_j / sum_j w_ij,  w_ij = exp(-||x_i - s_j||^2 / 2 tau^2)
    """
    xf, sf = x.astype(jnp.float32), s.astype(jnp.float32)
    d2 = (jnp.sum(xf * xf, -1, keepdims=True)
          - 2.0 * xf @ sf.T + jnp.sum(sf * sf, -1)[None, :])  # (N,M)
    w = jnp.exp(-jnp.maximum(d2, 0.0) / (2.0 * tau * tau))
    num = w @ y.astype(jnp.float32)
    den = jnp.sum(w, axis=-1)
    return num / jnp.maximum(den, 1e-30)
