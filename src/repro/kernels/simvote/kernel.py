"""Pallas TPU kernels: streaming similarity-weighted voting (Algorithm 3).

TPU adaptation: the paper's torch implementation materializes the full
(N x M) similarity matrix.  Here each (BN x BM) tile lives only in VMEM;
running numerator/denominator accumulate across the M grid dimension
(flash-attention-style online reduction), so HBM traffic is O(N*D + M*D),
not O(N*M).  Numerics: exp(-d2/2tau^2) is bounded in (0,1], so no max
rebasing is needed — a plain two-accumulator sum is exact in fp32.

Two entry points:
- ``simvote_scores_pallas``: one cluster (the original kernel).
- ``simvote_scores_segmented_pallas``: all clusters of a re-clustering round
  in ONE kernel launch.  Rows are packed per cluster into block_n-aligned
  segments; a scalar-prefetched ``block_seg`` table maps each row block to
  its cluster, and the BlockSpec index maps use it to DMA that cluster's
  sample tile, label tile, and bandwidth — the grouped-matmul pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _simvote_kernel(x_ref, s_ref, y_ref, inv2t2_ref, num_ref, den_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    x = x_ref[...].astype(jnp.float32)  # (BN, D)
    s = s_ref[...].astype(jnp.float32)  # (BM, D)
    y = y_ref[...].astype(jnp.float32)  # (1, BM); 0/1 labels, -1 = pad
    inv2t2 = inv2t2_ref[0, 0]
    xsq = jnp.sum(x * x, axis=-1, keepdims=True)
    ssq = jnp.sum(s * s, axis=-1)[None, :]
    d2 = jnp.maximum(xsq - 2.0 * lax.dot_general(
        x, s, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + ssq, 0.0)  # (BN, BM)
    w = jnp.exp(-d2 * inv2t2)
    valid = (y >= 0.0)
    w = jnp.where(valid, w, 0.0)
    num_ref[...] += w @ jnp.where(valid, y, 0.0).reshape(-1, 1)  # (BN,1)
    den_ref[...] += jnp.sum(w, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def simvote_scores_pallas(x, s, y, tau, block_n: int = 256,
                          block_m: int = 256, interpret: bool = False):
    """x (N,D), s (M,D), y (M,) -> scores (N,)."""
    n, d = x.shape
    m = s.shape[0]
    n_pad = (n + block_n - 1) // block_n * block_n
    m_pad = (m + block_m - 1) // block_m * block_m
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    if m_pad != m:
        s = jnp.pad(s, ((0, m_pad - m), (0, 0)))
        y = jnp.pad(y.astype(jnp.float32), (0, m_pad - m),
                    constant_values=-1.0)  # -1 marks padding
    y2 = y.astype(jnp.float32).reshape(1, m_pad)
    inv2t2 = jnp.array([[1.0 / (2.0 * tau * tau)]], jnp.float32)

    num, den = pl.pallas_call(
        _simvote_kernel,
        grid=(n_pad // block_n, m_pad // block_m),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, s, y2, inv2t2)
    return (num[:n, 0] / jnp.maximum(den[:n, 0], 1e-30))


def _simvote_segmented_kernel(seg_ref, x_ref, s_ref, y_ref, inv2t2_ref,
                              num_ref, den_ref):
    # seg_ref is the scalar-prefetched block->cluster table; the index maps
    # already routed the right cluster tiles here, so the body is the plain
    # single-cluster accumulator.
    del seg_ref
    _simvote_kernel(x_ref, s_ref, y_ref, inv2t2_ref, num_ref, den_ref)


def simvote_scores_segmented_pallas(x, counts, s_pad, y_pad, taus,
                                    block_n: int = 256, block_m: int = 256,
                                    interpret: bool = False):
    """All clusters of a round in one launch.

    x       (N, D)    unsampled rows grouped by cluster (counts[c] rows each)
    counts  (C,)      host ints (concrete — drives the packing layout)
    s_pad   (C, M, D) per-cluster samples, zero-padded along M
    y_pad   (C, M)    labels in {0, 1}; -1 marks M-padding
    taus    (C,)      per-cluster bandwidth
    -> scores (N,) in the same row order as x.

    Each grid row block belongs to exactly one cluster (rows are re-packed
    with per-cluster padding), so a single BlockSpec tile per input suffices;
    the scalar-prefetched ``block_seg`` selects the cluster's sample tiles.
    """
    counts = np.asarray(counts, np.int64)
    c, m, d = s_pad.shape
    n = x.shape[0]
    assert int(counts.sum()) == n, (counts.sum(), n)

    nblocks = np.maximum(1, -(-counts // block_n))  # >=1 block even if empty
    nb_total = int(nblocks.sum())
    starts = np.zeros(c + 1, np.int64)
    np.cumsum(nblocks, out=starts[1:])
    block_seg = np.repeat(np.arange(c, dtype=np.int32), nblocks)

    # pack rows: cluster c occupies rows [starts[c]*block_n, ...+counts[c])
    row_idx = np.concatenate([
        np.arange(counts[i], dtype=np.int64) + starts[i] * block_n
        for i in range(c)]) if c else np.zeros(0, np.int64)
    x_pad = jnp.zeros((nb_total * block_n, d), jnp.float32)
    x_pad = x_pad.at[jnp.asarray(row_idx)].set(x.astype(jnp.float32))

    m_pad = (m + block_m - 1) // block_m * block_m
    mblocks = m_pad // block_m
    s_flat = jnp.pad(s_pad.astype(jnp.float32),
                     ((0, 0), (0, m_pad - m), (0, 0))).reshape(c * m_pad, d)
    y_flat = jnp.pad(y_pad.astype(jnp.float32), ((0, 0), (0, m_pad - m)),
                     constant_values=-1.0).reshape(1, c * m_pad)
    inv2t2 = (1.0 / (2.0 * jnp.asarray(taus, jnp.float32).reshape(c, 1) ** 2))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb_total, mblocks),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j, seg: (i, 0)),
            pl.BlockSpec((block_m, d),
                         lambda i, j, seg: (seg[i] * mblocks + j, 0)),
            pl.BlockSpec((1, block_m),
                         lambda i, j, seg: (0, seg[i] * mblocks + j)),
            pl.BlockSpec((1, 1), lambda i, j, seg: (seg[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j, seg: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j, seg: (i, 0)),
        ],
    )
    num, den = pl.pallas_call(
        _simvote_segmented_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb_total * block_n, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb_total * block_n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(block_seg), x_pad, s_flat, y_flat, inv2t2)
    gather = jnp.asarray(row_idx)
    return num[gather, 0] / jnp.maximum(den[gather, 0], 1e-30)
