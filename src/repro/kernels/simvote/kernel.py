"""Pallas TPU kernel: streaming similarity-weighted voting (Algorithm 3).

TPU adaptation: the paper's torch implementation materializes the full
(N x M) similarity matrix.  Here each (BN x BM) tile lives only in VMEM;
running numerator/denominator accumulate across the M grid dimension
(flash-attention-style online reduction), so HBM traffic is O(N*D + M*D),
not O(N*M).  Numerics: exp(-d2/2tau^2) is bounded in (0,1], so no max
rebasing is needed — a plain two-accumulator sum is exact in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _simvote_kernel(x_ref, s_ref, y_ref, inv2t2_ref, num_ref, den_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    x = x_ref[...].astype(jnp.float32)  # (BN, D)
    s = s_ref[...].astype(jnp.float32)  # (BM, D)
    y = y_ref[...].astype(jnp.float32)  # (1, BM); 0/1 labels, -1 = pad
    inv2t2 = inv2t2_ref[0, 0]
    xsq = jnp.sum(x * x, axis=-1, keepdims=True)
    ssq = jnp.sum(s * s, axis=-1)[None, :]
    d2 = jnp.maximum(xsq - 2.0 * lax.dot_general(
        x, s, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + ssq, 0.0)  # (BN, BM)
    w = jnp.exp(-d2 * inv2t2)
    valid = (y >= 0.0)
    w = jnp.where(valid, w, 0.0)
    num_ref[...] += w @ jnp.where(valid, y, 0.0).reshape(-1, 1)  # (BN,1)
    den_ref[...] += jnp.sum(w, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def simvote_scores_pallas(x, s, y, tau, block_n: int = 256,
                          block_m: int = 256, interpret: bool = False):
    """x (N,D), s (M,D), y (M,) -> scores (N,)."""
    n, d = x.shape
    m = s.shape[0]
    n_pad = (n + block_n - 1) // block_n * block_n
    m_pad = (m + block_m - 1) // block_m * block_m
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    if m_pad != m:
        s = jnp.pad(s, ((0, m_pad - m), (0, 0)))
        y = jnp.pad(y.astype(jnp.float32), (0, m_pad - m),
                    constant_values=-1.0)  # -1 marks padding
    y2 = y.astype(jnp.float32).reshape(1, m_pad)
    inv2t2 = jnp.array([[1.0 / (2.0 * tau * tau)]], jnp.float32)

    num, den = pl.pallas_call(
        _simvote_kernel,
        grid=(n_pad // block_n, m_pad // block_m),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, s, y2, inv2t2)
    return (num[:n, 0] / jnp.maximum(den[:n, 0], 1e-30))
