"""Pure-jnp oracle: single-token decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths):
    """q (B,H,hd); k/v (B,KV,L,hd); lengths (B,) valid prefix -> (B,H,hd)."""
    B, H, hd = q.shape
    KV, L = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bcgh,bclh->bcgl", qf, k.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(L)[None, :] < lengths[:, None]  # (B, L)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bcgl,bclh->bcgh", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
