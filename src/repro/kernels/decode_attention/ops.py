"""jit'd wrapper: Pallas on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, lengths):
    if jax.default_backend() == "tpu":
        return decode_attention_pallas(q, k, v, lengths)
    return decode_attention_ref(q, k, v, lengths)
