"""jit'd wrapper: Pallas on TPU, jnp reference elsewhere.

``impl`` selects explicitly: "auto" (Pallas on TPU, ref otherwise — the
historical behavior), "pallas" (always the kernel; interpret mode is
enabled automatically off-TPU so the same code path is testable on CPU),
or "ref" (always the jnp oracle).
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, lengths, *, impl: str = "auto",
                     block_l: int = 256, interpret=None):
    on_tpu = jax.default_backend() == "tpu"
    if impl == "ref" or (impl == "auto" and not on_tpu):
        return decode_attention_ref(q, k, v, lengths)
    if interpret is None:
        interpret = not on_tpu
    return decode_attention_pallas(q, k, v, lengths, block_l=block_l,
                                   interpret=interpret)
