"""Pallas TPU kernel: flash-decoding over a KV cache (one new token).

The decode hot loop of the oracle LLM: one query row per (batch, head)
against an L-long cache.  Memory-bound by the KV stream, so the kernel's
job is to keep the KV read perfectly sequential through VMEM while the
(1 x L) score row reduces online — grid (B, KV, nL), L innermost with
(m, l, acc) scratch carried across L tiles.  Per-sequence ``lengths``
masks both ragged prefixes and ring-buffer slots.

The cross-chip half of 500k-decode (sequence-sharded KV + 3-term softmax
merge) lives in models/layers.py / GSPMD; this kernel is the per-chip leaf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_l: int, n_l: int, G: int):
    lj = pl.program_id(2)

    @pl.when(lj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bl, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    length = len_ref[0]
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale  # (G, bl)
    pos = lj * block_l + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(lj == n_l - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def decode_attention_pallas(q, k, v, lengths, *, block_l: int = 256,
                            interpret: bool = False):
    """q (B,H,hd); k/v (B,KV,L,hd); lengths (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    KV, L = k.shape[1], k.shape[2]
    G = H // KV
    bl = min(block_l, L)
    L_pad = (L + bl - 1) // bl * bl
    if L_pad != L:
        pad = ((0, 0), (0, 0), (0, L_pad - L), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    n_l = L_pad // bl
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_decode_kernel, scale=scale, block_l=bl,
                               n_l=n_l, G=G)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_l),
        in_specs=[
            pl.BlockSpec((1,), lambda b, c, lj: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, c, lj: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, bl, hd), lambda b, c, lj: (b, c, lj, 0)),
            pl.BlockSpec((1, 1, bl, hd), lambda b, c, lj: (b, c, lj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, c, lj: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, k, v)
    return out.reshape(B, H, hd)
