"""jit'd wrapper: Pallas on TPU, jnp reference elsewhere.

``impl`` selects explicitly: "auto" (Pallas on TPU, ref otherwise — the
historical behavior), "pallas" (always the kernel; interpret mode is
enabled automatically off-TPU so the same code path is testable on CPU),
or "ref" (always the jnp oracle).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    impl: str = "auto", block_q: int = 128,
                    block_k: int = 128, interpret=None):
    on_tpu = jax.default_backend() == "tpu"
    if impl == "ref" or (impl == "auto" and not on_tpu):
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    if interpret is None:
        interpret = not on_tpu
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
