"""jit'd wrapper: Pallas on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window=None):
    if jax.default_backend() == "tpu":
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    return flash_attention_ref(q, k, v, causal=causal, window=window)
