"""Pure-jnp oracle: causal (optionally sliding-window) GQA attention."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q (B,H,Sq,hd); k/v (B,KV,Sk,hd); H % KV == 0 -> out (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bcgqh,bckh->bcgqk", qf, kf) / math.sqrt(hd)
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(k.shape[2])[None, :]
        m = kpos <= qpos
        if window is not None:
            m &= kpos > qpos - window
        s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bcgqk,bckh->bcgqh", p, vf)
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
