"""Pallas TPU kernel: causal/SWA GQA flash attention (prefill path).

TPU adaptation of FlashAttention-2 for the serving engine's prefill:
- grid (B*H, nQ, nK); K innermost so the online-softmax state (m, l, acc)
  lives in VMEM scratch across the K sweep of one Q tile;
- GQA via BlockSpec index_map: KV tiles are addressed at head h // G —
  no KV head replication in HBM (same trick as the jnp path);
- causal + sliding-window masking from absolute positions; fully-masked
  tiles still stream (Pallas grids are static) — the banded *schedule*
  optimization lives one level up in models/layers.py where block indices
  are static.
MXU-aligned tiles: block_q x hd and block_k x hd with hd in {64,128,256}.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_k: int, n_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kj * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q (B,H,Sq,hd); k/v (B,KV,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    n_k = Sk // bk
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B * H, Sq, hd)
    kr = k.reshape(B * KV, Sk, hd)
    vr = v.reshape(B * KV, Sk, hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, kj: (bh // G, kj, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, kj: (bh // G, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, hd)
