"""Pallas TPU kernels for the paper's compute hot-spots.

Four kernels, each a package with kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with platform dispatch) and ref.py (pure-jnp oracle):

- kmeans:            tiled pairwise ||x-c||^2 + fused argmin (CSV phase 1)
- simvote:           streaming similarity-weighted vote (Algorithm 3) -- the
                     N x M similarity matrix never hits HBM
- flash_attention:   causal/SWA GQA prefill attention (serving the oracle LLM)
- decode_attention:  single-token flash-decoding over a KV cache

On non-TPU backends the ops fall back to the jnp reference; kernels are
validated against refs in interpret mode (tests/kernels/).
"""
