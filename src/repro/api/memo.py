"""Cross-query session memo: decisions, pilot probes, observed selectivities.

CSV's sublinear oracle complexity is per-query; a session filtering the same
table repeatedly can do better by amortizing three things across queries
(the Larch-style multi-query optimization named in ROADMAP.md):

- **decisions** — a predicate evaluated over the full table leaves a
  complete per-tuple mask behind.  Re-running the same predicate (same
  oracle object, same semantic config) on an unchanged table *replays* that
  mask at zero oracle cost, bit-identically.  After ``append``/``update``
  only the clusters the mutation touched are re-voted; clean-cluster rows
  still replay.
- **pilot probes** — per-(predicate, table-version) pilot statistics are
  kept, so a later multi-predicate query re-plans without re-probing leaves
  it has already seen.
- **observed selectivities** — after a leaf actually runs, its real pass
  rate replaces the pilot estimate for every later query's cost ordering
  (observed beats a 32-sample probe).

Everything here is *reused observation*, never new spend: with an empty
memo the planner and executor behave bit-identically to a cold session
(asserted in tests/test_session_reuse.py).  ``ExecutionPolicy.reuse_memo``
gates decision replay, ``reuse_stats`` gates pilot/selectivity reuse.

The memo keys predicates by ``(table name, id(oracle))`` and holds a strong
reference to every oracle it has seen, so CPython id reuse can never alias
two predicates.  Decision entries also carry a fingerprint of the
semantics-affecting ``CSVConfig`` fields: a different xi / vote / seed is a
different sampling process, so its decisions are not replayed (executor and
pipeline_depth are excluded — those are bit-identical by contract).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.csv_filter import CSVConfig, FilterResult
from repro.plan.cost import PredStats
from repro.plan.expr import Pred


def oracle_identity(oracle) -> Any:
    """The object whose ``id()`` keys memo entries.

    The service layer (``repro.service.scheduler``) wraps leaf oracles in
    batching proxies; a proxy advertises the oracle it stands in for via
    ``memo_target`` so a scheduled and a serial collection of the same
    predicate land on ONE memo identity — decisions recorded by either
    replay for both."""
    return getattr(oracle, "memo_target", oracle)


def cfg_fingerprint(cfg: CSVConfig) -> tuple:
    """Semantics-affecting CSVConfig fields (mask-identity equivalence
    class).  executor / pipeline_depth are physical knobs with a guarded
    bit-identity contract, so replay is valid across them."""
    return (cfg.n_clusters, cfg.xi, cfg.min_sample, cfg.lb, cfg.ub,
            cfg.max_recluster, cfg.vote, cfg.epsilon, cfg.theory_l,
            cfg.sim_v, cfg.sim_bandwidth, cfg.kmeans_iters, cfg.seed)


def join_fingerprint(cfg) -> tuple:
    """Semantics-affecting JoinConfig fields: any change is a different
    sampling process, so its pair decisions are not replayed."""
    return (cfg.n_clusters_left, cfg.n_clusters_right, cfg.xi,
            cfg.min_sample, cfg.lb, cfg.ub, cfg.max_refine, cfg.vote,
            cfg.sim_bandwidth, cfg.kmeans_iters, cfg.seed)


@dataclasses.dataclass
class DecisionMemo:
    """One predicate's full-table decisions at one table version."""
    version: int                  # table version the mask was decided at
    n: int                        # table length at that version
    mask: np.ndarray              # (n,) bool — the decided mask
    cluster_key: Tuple[int, int]  # (n_clusters, seed) clustering used
    fingerprint: tuple            # cfg_fingerprint of the run


@dataclasses.dataclass
class SelObservation:
    """Latest observed pass rate (and token cost) of one predicate."""
    version: int
    selectivity: float
    tokens_per_call: float


@dataclasses.dataclass
class JoinDecisionMemo:
    """One join predicate's full pair-mask at one (left, right) version
    pair.  Pair ids reindex under ANY mutation of either side, so these
    entries are cleared outright (never patched) — the versions are stored
    only as a defensive replay gate."""
    left_version: int
    right_version: int
    pair_mask: np.ndarray        # (|L|, |R|) bool
    fingerprint: tuple           # join_fingerprint of the run


@dataclasses.dataclass
class ReplayHit:
    """Executor-facing replay plan for one leaf.

    ``replay_rows``/``rerun_rows`` partition the current table: replay rows
    take their decision from ``mask`` (zero oracle cost), rerun rows — the
    members of clusters dirtied since the memo's version, including every
    appended row — go back through the CSV driver."""
    mask: np.ndarray
    replay_rows: np.ndarray
    rerun_rows: np.ndarray
    n_dirty_clusters: int = 0    # clusters whose members rerun (metrics)

    @property
    def full(self) -> bool:
        return len(self.rerun_rows) == 0


class SessionMemo:
    """Session-owned store behind the reuse views (one per Session)."""

    def __init__(self):
        # durability hook: called as hook(kind, **fields) whenever an
        # entry worth persisting is stored — kinds "decision",
        # "selectivity", "pilot", "join" (repro.service.log appends a
        # framed record per event; None costs nothing)
        self.hook = None
        self._decisions: Dict[tuple, DecisionMemo] = {}
        self._selectivity: Dict[tuple, SelObservation] = {}
        self._pilots: Dict[tuple, PredStats] = {}
        # join pair decisions keyed (left, right, oracle id, fingerprint) —
        # replayed whole, cleared whole (docs/caching.md invalidation rules)
        self._join_decisions: Dict[tuple, JoinDecisionMemo] = {}
        # strong refs ONLY for oracles with stored entries (decisions /
        # pilots / selectivities are keyed by id(), which must stay stable);
        # mere sightings are weak so a session that never stores anything —
        # reuse pinned off, or the legacy shims — doesn't retain every
        # oracle (and its labels + per-id memo) it ever saw
        self._oracles: Dict[int, Any] = {}
        self._sightings: Dict[str, Dict[int, Any]] = {}       # weak refs
        # join (pair-space) oracles per table: their memo keys are pair ids,
        # which reindex on mutation — they need full clears, not per-id drops
        self._pair_sightings: Dict[str, Dict[int, Any]] = {}  # weak refs

    # ----------------------------------------------------------- plumbing
    def _pred_key(self, table: str, oracle) -> tuple:
        """Key for STORING an entry: pins a strong oracle reference.

        Service-layer batching proxies resolve to the oracle they wrap
        (``oracle_identity``), so scheduled and serial collections share
        one identity."""
        oracle = oracle_identity(oracle)
        oid = id(oracle)
        self._oracles[oid] = oracle
        self.note_sighting(table, oracle)
        return (table, oid)

    @staticmethod
    def _note(store: Dict[str, Dict[int, Any]], table: str, oracle) -> None:
        try:
            ref = weakref.ref(oracle)
        except TypeError:           # unweakrefable oracle: keep it alive
            ref = (lambda o: (lambda: o))(oracle)
        store.setdefault(table, {})[id(oracle)] = ref

    @staticmethod
    def _live(store: Dict[str, Dict[int, Any]], table: str) -> list:
        refs = store.get(table, {})
        out = []
        for oid in list(refs):
            oracle = refs[oid]()
            if oracle is None:
                del refs[oid]       # collected: nothing left to invalidate
            else:
                out.append(oracle)
        return out

    def note_sighting(self, table: str, oracle) -> None:
        """Record that ``oracle`` answered tuple ids of ``table`` (weak)."""
        self._note(self._sightings, table, oracle_identity(oracle))

    def oracles_for(self, table: str) -> list:
        """Every live oracle this memo has seen touch ``table``
        (update-path per-id memo invalidation)."""
        return self._live(self._sightings, table)

    def note_pair_oracle(self, table: str, oracle) -> None:
        self._note(self._pair_sightings, table, oracle_identity(oracle))

    def pair_oracles_for(self, table: str) -> list:
        return self._live(self._pair_sightings, table)

    # -------------------------------------------------- join decisions
    def _join_key(self, left: str, right: str, oracle, cfg) -> tuple:
        oracle = oracle_identity(oracle)
        return (left, right, id(oracle), join_fingerprint(cfg))

    def lookup_join(self, left_handle, right_handle, oracle,
                    cfg) -> Optional[JoinDecisionMemo]:
        """Replayable pair decisions for one join, or None.

        Keyed by both table versions: mutations clear join entries
        outright (``drop_joins``), so a surviving entry always matches —
        the version check is a defensive invariant, not a patch path."""
        jm = self._join_decisions.get(
            self._join_key(left_handle.name, right_handle.name, oracle, cfg))
        if jm is None:
            return None
        if (jm.left_version != left_handle.version
                or jm.right_version != right_handle.version
                or jm.pair_mask.shape != (len(left_handle),
                                          len(right_handle))):
            return None
        return jm

    def record_join(self, left_handle, right_handle, oracle, cfg,
                    pair_mask: np.ndarray) -> None:
        key = self._join_key(left_handle.name, right_handle.name, oracle,
                             cfg)
        self._oracles[key[2]] = oracle_identity(oracle)  # pin id stability
        self._join_decisions[key] = JoinDecisionMemo(
            left_version=left_handle.version,
            right_version=right_handle.version,
            pair_mask=np.asarray(pair_mask, bool).copy(),
            fingerprint=key[3])
        if self.hook is not None:
            self.hook("join", left=left_handle.name,
                      right=right_handle.name,
                      ident=oracle_identity(oracle),
                      jm=self._join_decisions[key])

    def drop_joins(self, table: str) -> int:
        """Mutation of ``table``: drop every join decision touching it on
        either side (pair ids reindex / payloads changed — same rule as
        the pair-oracle memo clear).  Returns entries dropped."""
        stale = [k for k in self._join_decisions if table in k[:2]]
        for k in stale:
            del self._join_decisions[k]
        return len(stale)


class ReuseView:
    """Per-query binding of the session memo to one table handle.

    Implements the ``PlanExecutor`` memo protocol (``lookup``/``record``)
    plus the planning-side helpers the query layer uses (``pred_stats``,
    ``store_pilot``).  ``reuse_decisions`` / ``reuse_stats`` mirror the
    policy's ``reuse_memo`` / ``reuse_stats`` knobs; recording is always on
    (observations are free), reading is gated.
    """

    def __init__(self, session, handle, reuse_decisions: bool,
                 reuse_stats: bool):
        self.session = session
        self.handle = handle
        self.memo: SessionMemo = session.memo
        self.reuse_decisions = reuse_decisions
        self.reuse_stats = reuse_stats

    # ------------------------------------------------------ executor side
    def lookup(self, leaf: Pred, cfg: CSVConfig) -> Optional[ReplayHit]:
        if not self.reuse_decisions:
            return None
        # read-only: no strong ref is pinned (record()/store_pilot() pin
        # one the moment an entry is actually stored)
        key = (self.handle.name, id(oracle_identity(leaf.oracle)))
        # decisions are kept per config fingerprint: runs under different
        # semantics (xi, vote, seed, ...) never clobber each other
        dm = self.memo._decisions.get(key + (cfg_fingerprint(cfg),))
        if dm is None:
            return None
        n_now = len(self.handle)
        if dm.version == self.handle.version:
            if dm.n != n_now:  # defensive: version must imply same length
                return None
            return ReplayHit(mask=dm.mask, replay_rows=np.arange(dm.n),
                             rerun_rows=np.empty(0, dtype=np.int64))
        # table mutated since the memo: replay clean clusters, re-vote dirty
        ckey = (int(cfg.n_clusters), int(cfg.seed))
        if dm.cluster_key != ckey:
            return None
        dirty_version = self.handle._dirty.get(ckey)
        assign = self.session._assign_cache.get((self.handle.name, *ckey))
        if dirty_version is None or assign is None or len(assign) != n_now:
            return None
        clean = (dirty_version <= dm.version)[assign]
        replay_rows = np.nonzero(clean)[0]
        if len(replay_rows) == 0:
            return None  # everything dirty: the cold path is simpler
        if replay_rows[-1] >= dm.n:
            # a clean cluster contains a row newer than the memo — the dirty
            # bookkeeping was bypassed; fall back to a cold run
            return None
        # the executor incs memo.dirty_clusters when it consumes the hit —
        # planning probes call lookup() too and must not double-count
        return ReplayHit(mask=dm.mask, replay_rows=replay_rows,
                         rerun_rows=np.nonzero(~clean)[0],
                         n_dirty_clusters=int(
                             (dirty_version > dm.version).sum()))

    def record(self, leaf: Pred, cfg: CSVConfig, fr: FilterResult,
               live: np.ndarray) -> None:
        """Observe one executed leaf.  Only FULL-table runs update the
        selectivity observation and the decision memo: a cascade-restricted
        run measures a pass rate *conditional* on the upstream predicates
        (correlated predicates can make it arbitrarily far from the
        marginal), which would corrupt later cost orderings."""
        n_in = int(len(live))
        if n_in != len(self.handle):
            return
        key = self.memo._pred_key(self.handle.name, leaf.oracle)
        n_out = int(fr.mask.sum())
        lo = 1.0 / (n_in + 1)
        sel = min(1.0 - lo, max(lo, n_out / max(n_in, 1)))
        prev = self.memo._selectivity.get(key)
        tokens = ((fr.input_tokens + fr.output_tokens) / fr.n_llm_calls
                  if fr.n_llm_calls else
                  (prev.tokens_per_call if prev is not None else 64.0))
        self.memo._selectivity[key] = SelObservation(
            version=self.handle.version, selectivity=sel,
            tokens_per_call=tokens)
        fp = cfg_fingerprint(cfg)
        self.memo._decisions[key + (fp,)] = DecisionMemo(
            version=self.handle.version, n=n_in, mask=fr.mask.copy(),
            cluster_key=(int(cfg.n_clusters), int(cfg.seed)),
            fingerprint=fp)
        if self.memo.hook is not None:
            ident = oracle_identity(leaf.oracle)
            self.memo.hook("selectivity", table=self.handle.name,
                           ident=ident, obs=self.memo._selectivity[key])
            self.memo.hook("decision", table=self.handle.name, ident=ident,
                           dm=self.memo._decisions[key + (fp,)])

    # ------------------------------------------------------ planning side
    def pred_stats(self, leaf: Pred, cfg: CSVConfig, seed: int,
                   pilot_size: int) -> Optional[PredStats]:
        """Memoized PredStats for one leaf, or None to pilot-probe it.

        Served stats carry ``pilot_calls=0``: the spend happened (and was
        reported) in the query that originally paid it.

        Everything here is PLANNING-side reuse, so all of it — including
        costing a replayable leaf at zero — is gated on ``reuse_stats``:
        with it off the optimizer plans exactly like a cold session
        (pilot-probed, normally costed) and only the executor replays."""
        if not self.reuse_stats:
            return None
        key = (self.handle.name, id(oracle_identity(leaf.oracle)))
        hit = self.lookup(leaf, cfg)
        if hit is not None and hit.full:
            obs = self.memo._selectivity.get(key)
            sel = (obs.selectivity if obs is not None
                   else float(np.clip(hit.mask.mean(), 0.01, 0.99)))
            return PredStats(name=leaf.name, selectivity=sel,
                             tokens_per_call=0.0, n_pilot=0, pilot_calls=0,
                             source="memo", replayable=True)
        obs = self.memo._selectivity.get(key)
        if obs is not None and obs.version == self.handle.version:
            # version-gated: a mutation can shift the marginal pass rate,
            # so stale observations fall through to the pilot (also
            # version-keyed) or a fresh probe
            return PredStats(name=leaf.name, selectivity=obs.selectivity,
                             tokens_per_call=obs.tokens_per_call,
                             n_pilot=0, pilot_calls=0, source="observed")
        ps = self.memo._pilots.get(
            key + (self.handle.version, int(seed), int(pilot_size)))
        if ps is not None:
            return dataclasses.replace(
                ps, name=leaf.name, pilot_calls=0, pilot_input_tokens=0,
                pilot_output_tokens=0)
        return None

    def store_pilot(self, leaf: Pred, seed: int, pilot_size: int,
                    stats: PredStats) -> None:
        key = self.memo._pred_key(self.handle.name, leaf.oracle)
        self.memo._pilots[
            key + (self.handle.version, int(seed), int(pilot_size))] = stats
        if self.memo.hook is not None:
            self.memo.hook("pilot", table=self.handle.name,
                           ident=oracle_identity(leaf.oracle),
                           version=self.handle.version, seed=int(seed),
                           pilot_size=int(pilot_size), stats=stats)
