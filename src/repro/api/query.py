"""Lazy queries: logical plans that touch the oracle only at ``.collect()``.

``TableHandle.filter(...)`` and ``.join(...)`` return query objects holding
a *logical* description — a ``repro.plan`` expression (or a join predicate)
plus an optional ``ExecutionPolicy``.  Building, composing (``&``/``|``/
``~``), and ``.explain()``-ing queries issues zero semantic-filter oracle
calls beyond the optimizer's pilot; ``.collect()`` lowers to the existing
``PlanExecutor`` / ``sem_join`` / baseline machinery and returns a unified
``QueryResult``.

Explain/collect contract: ``.explain()`` runs the SAME pilot (same RNG
derivation) the collect-time optimizer would, caches the ``PreparedPlan``
on the query, and ``.collect()`` reuses it.  Pilot calls are memoized by
the oracle, so a collect preceded by explain consumes the flip-RNG stream
and reports the same call counts as a cold collect — bit-identity is
asserted in tests/test_api.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import numpy as np

from repro.api.memo import ReuseView, oracle_identity
from repro.api.policy import ExecutionPolicy, OracleBudgetError
from repro.core.baselines import (BaselineResult, bargain_filter,
                                  lotus_filter, reference_filter)
from repro.obs.audit import audit_query_result
from repro.obs.trace import get_tracer
from repro.plan.cost import est_oracle_calls
from repro.plan.executor import PlanExecutor, PlanResult, PreparedPlan
from repro.plan.expr import And, Expr, Not, Or, Pred, needs_ordering
from repro.plan.join import JoinResult, sem_join
from repro.plan.optimizer import NodeEstimate, node_estimates
from repro.utils.timing import monotonic


# ------------------------------------------------------------------ results
@dataclasses.dataclass
class QueryResult:
    """Unified outcome of ``Query.collect()`` across all five methods and
    joins.  ``raw`` keeps the underlying result object (``PlanResult``,
    ``BaselineResult``, or ``JoinResult``) for path-specific detail."""
    kind: str                      # "filter" | "baseline" | "join"
    n_llm_calls: int               # oracle calls, pilot included
    pilot_calls: int
    n_proxy_calls: int
    input_tokens: int
    output_tokens: int
    order: list                    # executed leaf order (filters)
    node_log: list                 # per-leaf NodeRecord (plan path)
    round_log: Dict[str, list]     # per-leaf driver round logs
    total_time_s: float
    policy: ExecutionPolicy
    raw: Any
    mask: Optional[np.ndarray] = None       # filters/baselines
    pair_mask: Optional[np.ndarray] = None  # joins
    # tuples decided by replaying session-memoized decisions (zero oracle
    # cost; docs/caching.md) — 0 on cold runs and non-reuse paths
    n_replayed: int = 0
    # optimizer NodeEstimate per leaf (physical order) captured at collect
    # time — the predictions profile() confronts with the observed truth
    node_estimates: list = dataclasses.field(default_factory=list)
    # online audit outcome (repro.obs.audit.AuditReport) — populated only
    # when the policy opted in via audit_rate > 0
    audit: Any = None

    def audit_report(self):
        """The online quality audit for this result (docs/observability.md).

        Requires the query to have run with ``ExecutionPolicy(audit_rate>0)``;
        the default policy never audits (and never spends audit calls).
        """
        if self.audit is None:
            raise ValueError(
                "no audit attached: run with ExecutionPolicy(audit_rate=...) "
                "> 0 to hold out a stratified audit sample at collect time")
        return self.audit

    @property
    def pairs(self) -> np.ndarray:
        if self.pair_mask is None:
            raise ValueError("pairs are only defined for join queries")
        return np.argwhere(self.pair_mask)

    def profile(self) -> str:
        """Estimated vs observed, per plan node.

        The ``explain()`` tree annotated with what actually happened: the
        optimizer's predicted oracle calls and selectivity next to the
        executed node's call count and observed pass rate (docs/observability.md).
        """
        lines = [f"QueryProfile({self.kind})  calls={self.n_llm_calls} "
                 f"(pilot {self.pilot_calls})  replayed={self.n_replayed}  "
                 f"wall={self.total_time_s:.3f}s"]
        est_by_name = {nd.name: nd for nd in self.node_estimates}
        for rec in self.node_log:
            nd = est_by_name.get(rec.name)
            obs_sel = rec.n_out / rec.n_in if rec.n_in else 0.0
            est_calls = "?" if nd is None else f"{nd.est_calls:.0f}"
            est_sel = ("?" if nd is None or nd.selectivity is None
                       else f"{nd.selectivity:.2f}")
            lines.append(
                f"  {rec.name:<16s} calls={rec.n_llm_calls:>6d} "
                f"(est {est_calls})  sel={obs_sel:.2f} (est {est_sel})  "
                f"in={rec.n_in} out={rec.n_out} "
                f"replayed={rec.n_replayed}")
        if not self.node_log:
            for nd in self.node_estimates:
                lines.append(f"  {nd.name:<16s} calls={self.n_llm_calls:>6d} "
                             f"(est {nd.est_calls:.0f})")
        return "\n".join(lines)


@dataclasses.dataclass
class Explain:
    """Rendered optimizer choice + per-node cost predictions (no cascade
    execution; the only oracle spend is the memoized pilot)."""
    kind: str
    method: str
    table: str
    n: int
    order: list
    naive_order: list
    nodes: list                    # NodeEstimate per leaf, physical order
    est_oracle_calls: float        # nodes + pilot
    pilot_calls: int
    estimate: Any                  # PlanEstimate | None
    text: str

    def __str__(self) -> str:
        return self.text


def _render_explain(ex: Explain, policy: ExecutionPolicy) -> str:
    lines = [f"Query({ex.kind}) on table {ex.table!r} (n={ex.n})  "
             f"method={ex.method} executor={policy.executor} "
             f"pipeline_depth={policy.pipeline_depth}"]
    if ex.order:
        lines.append("physical order: " + " -> ".join(ex.order)
                      + ("" if ex.order == ex.naive_order
                         else "   (naive: " + " -> ".join(ex.naive_order) + ")"))
    for nd in ex.nodes:
        sel = ("sel~?" if nd.selectivity is None
               else f"sel~{nd.selectivity:.2f}")
        lines.append(f"  {nd.name:<16s} est_in={nd.est_live_in:>8.0f}  "
                     f"est_oracle_calls={nd.est_calls:>8.0f}  {sel}")
    tail = f"est total {ex.est_oracle_calls:.0f} oracle calls"
    if ex.pilot_calls:
        tail += f" (incl. {ex.pilot_calls} pilot)"
    if ex.estimate is not None:
        tail += f"; naive order est {ex.estimate.est_calls_naive:.0f}"
    lines.append(tail)
    return "\n".join(lines)


def _snapshot(oracles: list) -> list:
    """(oracle, stats-clone) pairs for run-level accounting deltas."""
    return [(o, o.stats.clone()) for o in oracles
            if hasattr(o, "stats") and hasattr(o.stats, "clone")]


class Query:
    """Shared policy-resolution logic for filter and join queries."""

    def __init__(self, session, policy: Optional[ExecutionPolicy]):
        self.session = session
        self.policy = policy

    def _resolve(self, override: Optional[ExecutionPolicy]) -> ExecutionPolicy:
        pol = override or self.policy or self.session.policy
        if not isinstance(pol, ExecutionPolicy):
            raise TypeError(f"expected ExecutionPolicy, got {type(pol).__name__}")
        return pol

    def _check_budget(self, pol: ExecutionPolicy, est: float) -> None:
        if pol.max_oracle_calls is not None and est > pol.max_oracle_calls:
            raise OracleBudgetError(
                f"estimated {est:.0f} oracle calls exceed the policy budget "
                f"of {pol.max_oracle_calls} (closed-form pre-flight check; "
                "raise max_oracle_calls or shrink the query)")

    def worst_case_calls(self, policy: Optional[ExecutionPolicy] = None
                         ) -> float:
        """Closed-form worst-case oracle spend of ``collect`` under the
        resolved policy — zero oracle calls to compute.  This is the same
        estimate the ``max_oracle_calls`` pre-flight check uses; the
        service layer aggregates it per tenant for admission control."""
        pol = self._resolve(policy)
        self._validate(pol)
        return self._estimate_calls(pol)

    def _estimate_calls(self, pol: ExecutionPolicy) -> float:
        raise NotImplementedError


class FilterQuery(Query):
    """A lazy semantic filter over one table.

    ``expr`` is a ``repro.plan`` expression; composition with ``&``/``|``/
    ``~`` builds a bigger logical plan (same table required) without any
    execution.  ``collect()`` routes on the resolved policy's ``method``:
    csv/csv-sim lower through ``PlanExecutor`` (cost-ordered short-circuit
    cascades), the three linear baselines call the corresponding
    ``repro.core.baselines`` function on the single leaf's oracle.
    """

    def __init__(self, session, handle, expr: Expr,
                 policy: Optional[ExecutionPolicy] = None, proxy=None):
        super().__init__(session, policy)
        if not isinstance(expr, Expr):
            raise TypeError(f"expected a plan Expr, got {type(expr).__name__}")
        self.handle = handle
        self.expr = expr
        self.proxy = proxy
        # pilot probes keyed by (seed, pilot_size) — the only policy knobs
        # that change which ids the pilot draws; see _prepare()
        self._pilot_cache: Dict[tuple, Dict] = {}
        # raw fresh probes keyed by (seed, pilot_size, table version): the
        # truthful PredStats to reuse when a re-plan (different reuse
        # knobs, a scheduled clone) would otherwise re-probe a memo-warm
        # oracle and report pilot_calls=0 / default tokens (see _prepare)
        self._fresh_pilots: Dict[tuple, Dict] = {}

    # ------------------------------------------------------- composition
    def _combine(self, op, other: "FilterQuery") -> "FilterQuery":
        if not isinstance(other, FilterQuery):
            raise TypeError(f"cannot combine FilterQuery with "
                            f"{type(other).__name__}")
        if other.handle is not self.handle:
            raise ValueError("combined queries must target the same table "
                             f"({self.handle.name!r} vs {other.handle.name!r})")
        if (self.policy is not None and other.policy is not None
                and self.policy != other.policy):
            raise ValueError(
                "combined queries carry conflicting ExecutionPolicies; "
                "drop one or pass the policy to collect() instead")
        if (self.proxy is not None and other.proxy is not None
                and self.proxy is not other.proxy):
            raise ValueError("combined queries carry two different proxies")
        return FilterQuery(self.session, self.handle,
                           op(self.expr, other.expr),
                           policy=self.policy or other.policy,
                           proxy=self.proxy or other.proxy)

    def __and__(self, other: "FilterQuery") -> "FilterQuery":
        return self._combine(And, other)

    def __or__(self, other: "FilterQuery") -> "FilterQuery":
        return self._combine(Or, other)

    def __invert__(self) -> "FilterQuery":
        return FilterQuery(self.session, self.handle, Not(self.expr),
                           policy=self.policy, proxy=self.proxy)

    # -------------------------------------------------------- validation
    def _validate(self, pol: ExecutionPolicy) -> None:
        if pol.is_baseline:
            leaves = self.expr.leaves()
            if not isinstance(self.expr, Pred):
                raise ValueError(
                    f"method {pol.method!r} is a linear baseline and only "
                    f"supports a single bare predicate; this query composes "
                    f"{len(leaves)} leaves — use method='csv' or 'csv-sim'")
            if pol.method in ("lotus", "bargain") and self.proxy is None:
                raise ValueError(f"method {pol.method!r} requires a proxy "
                                 "model (pass proxy= to .filter())")

    def _reuse_view(self, pol: ExecutionPolicy) -> Optional[ReuseView]:
        """Session-memo binding for this query, or None when every reuse
        knob is off (or the method is a linear baseline)."""
        if pol.is_baseline or not (pol.reuse_memo or pol.reuse_stats):
            return None
        return ReuseView(self.session, self.handle,
                         reuse_decisions=pol.reuse_memo,
                         reuse_stats=pol.reuse_stats)

    def _estimate_calls(self, pol: ExecutionPolicy) -> float:
        """Closed-form worst case (no live-set shrinkage), zero oracle
        calls: per-leaf first-round estimate at full n, plus the pilot.

        Memo accounting: a leaf whose decisions replay from the session
        memo is budgeted at its *dirty-subset* size (zero on an unchanged
        table), and memoized pilot/observed statistics waive that leaf's
        pilot charge — so a warm replay fits budgets a cold run would
        blow."""
        n = len(self.handle)
        if pol.is_baseline:
            return float(n)
        cfg = pol.to_csv_config()
        view = self._reuse_view(pol)
        leaves = self.expr.leaves()
        est = 0.0
        need_pilot = set()
        for leaf in leaves:
            lcfg = leaf.cfg if leaf.cfg is not None else cfg
            hit = view.lookup(leaf, lcfg) if view is not None else None
            if hit is not None:
                est += est_oracle_calls(len(hit.rerun_rows), lcfg)
            else:
                est += est_oracle_calls(n, lcfg)
            # the pilot charge is waived only when planning actually has
            # memoized statistics for this leaf — a PARTIAL replay hit
            # (post-mutation) still re-probes, so it still pays
            if (view is None or view.pred_stats(leaf, lcfg, pol.seed,
                                                pol.pilot_size) is None):
                need_pilot.add(leaf.name)
        if pol.optimize and len(leaves) > 1:
            est += pol.pilot_size * len(need_pilot)
        return est

    # --------------------------------------------------------- planning
    def _executor(self, pol: ExecutionPolicy) -> PlanExecutor:
        return PlanExecutor(self.handle, cfg=pol.to_csv_config(),
                            optimize=pol.optimize, pilot_size=pol.pilot_size,
                            reuse_clustering=pol.reuse_clustering,
                            memo=self._reuse_view(pol))

    def _prepare(self, pol: ExecutionPolicy) -> PreparedPlan:
        """Plan (pilot + cost-ordering) under ``pol``.

        The pilot probe is cached by (seed, pilot_size) — the only knobs
        that change which ids it draws — so explain -> collect pays it
        exactly once even when the two resolve different policies; only the
        host-side cost-ordering is redone per policy.  Pilot oracle deltas
        are absorbed into the session aggregate HERE (collect's own
        snapshot window sees only the cascade).

        Session-memo reuse: leaves with memoized statistics (a replayable
        decision set, an observed selectivity, or a stored pilot probe at
        this table version) skip the fresh probe; only unknown leaves are
        piloted, and their fresh statistics are stored back into the memo
        for later queries.  With an empty memo every leaf is probed —
        bit-identical to a cold session."""
        ex = self._executor(pol)
        if not (pol.optimize and needs_ordering(self.expr)):
            return ex.prepare(self.expr)
        # the reuse knobs and the table version join the cache key:
        # memo-derived stats (replayable leaves, observed selectivities)
        # must never leak into a reuse-disabled prepare of the same query
        # object, and stats planned before an append()/update() must not
        # survive the mutation
        key = (pol.seed, pol.pilot_size, pol.reuse_memo, pol.reuse_stats,
               getattr(self.handle, "version", 0))
        pilot_stats = self._pilot_cache.get(key)
        if pilot_stats is None:
            view = self._reuse_view(pol)
            known: Dict[str, Any] = {}
            leaf_by_name: Dict[str, Any] = {}
            cfg = pol.to_csv_config()
            for leaf in self.expr.leaves():
                if leaf.name in leaf_by_name:
                    continue
                leaf_by_name[leaf.name] = leaf
                if view is not None:
                    ps = view.pred_stats(
                        leaf, leaf.cfg if leaf.cfg is not None else cfg,
                        pol.seed, pol.pilot_size)
                    if ps is not None:
                        known[leaf.name] = ps
            # pilot-accounting fix: a re-plan that resolves a different
            # cache key (reuse knobs toggled, a scheduled clone of the
            # query) must NOT probe again — by then the oracle memo is
            # warm, so a fresh probe would report pilot_calls=0 and fall
            # back to the default tokens_per_call, corrupting both the
            # cost ordering and the accounting.  Fresh probes are cached
            # under the only knobs that change the id draw and reused as
            # recorded (truthful calls/tokens).
            probed = self._fresh_pilots.setdefault(
                (pol.seed, pol.pilot_size,
                 getattr(self.handle, "version", 0)), {})
            tr = get_tracer()
            snap = _snapshot(self._oracles())
            with tr.span("pilot", kind="plan", pilot_size=pol.pilot_size,
                         n_fresh=len(leaf_by_name) - len(known)) as psp:
                fresh = ex.pilot(self.expr, skip=set(known) | set(probed))
            n_pilot = 0
            for oracle, before in snap:
                d = oracle.stats.delta(before)
                n_pilot += d.n_calls
                tr.metrics.inc("oracle.calls", d.n_calls)
                tr.metrics.inc("oracle.input_tokens", d.input_tokens)
                tr.metrics.inc("oracle.output_tokens", d.output_tokens)
                self.session._absorb(d)
            psp.set(calls=n_pilot)
            probed.update(fresh)
            if view is not None:
                for name, ps in probed.items():
                    if name not in known:
                        view.store_pilot(leaf_by_name[name], pol.seed,
                                         pol.pilot_size, ps)
            pilot_stats = {name: known.get(name) or probed[name]
                           for name in leaf_by_name}
            self._pilot_cache[key] = pilot_stats
        return ex.prepare(self.expr, pilot_stats=pilot_stats)

    def _oracles(self) -> list:
        """Distinct leaf oracles (LLM spend only; the proxy is accounted
        separately in ``session.proxy_stats``).  Dedup is by memo identity
        so two scheduler proxies over one oracle can never double-count a
        stats delta."""
        return list({id(oracle_identity(leaf.oracle)): leaf.oracle
                     for leaf in self.expr.leaves()}.values())

    def explain(self, policy: Optional[ExecutionPolicy] = None) -> Explain:
        """Render the optimizer's chosen ordering with pilot-based
        ``est_oracle_calls`` per node.  Pilot calls are memoized, so a
        subsequent ``.collect()`` is bit-identical to one without explain."""
        pol = self._resolve(policy)
        self._validate(pol)
        n = len(self.handle)
        if pol.is_baseline:
            name = self.expr.leaves()[0].name
            nodes = [NodeEstimate(name=name, est_live_in=float(n),
                                  est_calls=float(n), selectivity=None)]
            ex = Explain(kind="filter", method=pol.method,
                         table=self.handle.name, n=n, order=[name],
                         naive_order=[name], nodes=nodes,
                         est_oracle_calls=float(n), pilot_calls=0,
                         estimate=None, text="")
            ex.text = _render_explain(ex, pol)
            return ex
        prepared = self._prepare(pol)
        nodes = node_estimates(prepared.physical, n, prepared.pilot_stats,
                               pol.to_csv_config())
        pilot_calls = sum(s.pilot_calls
                          for s in prepared.pilot_stats.values())
        ex = Explain(kind="filter", method=pol.method, table=self.handle.name,
                     n=n, order=[p.name for p in prepared.physical.leaves()],
                     naive_order=[p.name for p in self.expr.leaves()],
                     nodes=nodes,
                     est_oracle_calls=sum(nd.est_calls for nd in nodes)
                     + pilot_calls,
                     pilot_calls=pilot_calls, estimate=prepared.estimate,
                     text="")
        ex.text = _render_explain(ex, pol)
        return ex

    # -------------------------------------------------------- execution
    def collect(self, policy: Optional[ExecutionPolicy] = None) -> QueryResult:
        pol = self._resolve(policy)
        self._validate(pol)
        self._check_budget(pol, self._estimate_calls(pol))
        tr = get_tracer()
        t0 = monotonic()
        with tr.span("query", kind="query", query="filter",
                     table=self.handle.name, method=pol.method) as qsp:
            # sight every leaf oracle as having touched this table EVEN when
            # reuse is off: TableHandle.update() must be able to invalidate
            # stale per-id oracle memos regardless of the policy the oracle
            # was used under.  Sightings are weak — they never extend oracle
            # lifetimes
            for oracle in self._oracles():
                self.session.memo.note_sighting(self.handle.name, oracle)
            # proxy spend is tracked separately (session.proxy_stats):
            # proxy calls are the cheap cascade model, not LLM-oracle spend
            proxy_snap = _snapshot([self.proxy]
                                   if self.proxy is not None else [])
            if pol.is_baseline:
                name = self.expr.leaves()[0].name
                n = len(self.handle)
                ests = [NodeEstimate(name=name, est_live_in=float(n),
                                     est_calls=float(n), selectivity=None)]
                snap = _snapshot(self._oracles())
                raw = self._run_baseline(pol, self.expr.leaves()[0].oracle)
            else:
                # plan first: _prepare absorbs any fresh pilot spend into
                # the session aggregate, so the snapshot below covers the
                # cascade
                prepared = self._prepare(pol)
                ests = node_estimates(prepared.physical, len(self.handle),
                                      prepared.pilot_stats,
                                      pol.to_csv_config())
                snap = _snapshot(self._oracles())
                raw = self._executor(pol).run(self.expr, prepared=prepared)
            for oracle, before in snap:
                self.session._absorb(oracle.stats.delta(before))
            for proxy, before in proxy_snap:
                self.session._absorb_proxy(proxy.stats.delta(before))
            res = self._to_result(pol, raw, monotonic() - t0, ests)
            if pol.audit_rate > 0.0 and res.mask is not None:
                # observation-only: audit spend lands under audit.* metrics
                # and the report — oracle stats/memo/RNG are untouched, so
                # the masks above (and every later query) stay bit-identical
                with tr.span("audit", kind="audit", table=self.handle.name):
                    res.audit = audit_query_result(self.handle, self.expr,
                                                   pol, res.mask)
            qsp.set(calls=res.n_llm_calls, n_replayed=res.n_replayed)
            tr.metrics.inc("query.collects")
        return res

    def _run_baseline(self, pol: ExecutionPolicy, oracle) -> BaselineResult:
        n = len(self.handle)
        if pol.method == "reference":
            return reference_filter(n, oracle)
        fn = lotus_filter if pol.method == "lotus" else bargain_filter
        return fn(n, self.proxy, oracle, **dict(pol.baseline))

    def _to_result(self, pol, raw, dt: float,
                   ests: Optional[list] = None) -> QueryResult:
        ests = ests or []
        if isinstance(raw, BaselineResult):
            name = self.expr.leaves()[0].name
            return QueryResult(
                kind="baseline", mask=raw.mask,
                n_llm_calls=raw.n_oracle_calls, pilot_calls=0,
                n_proxy_calls=raw.n_proxy_calls,
                input_tokens=raw.input_tokens,
                output_tokens=raw.output_tokens, order=[name], node_log=[],
                round_log={}, total_time_s=dt, policy=pol, raw=raw,
                node_estimates=ests)
        assert isinstance(raw, PlanResult)
        return QueryResult(
            kind="filter", mask=raw.mask, n_llm_calls=raw.n_llm_calls,
            pilot_calls=raw.pilot_calls, n_proxy_calls=0,
            input_tokens=raw.input_tokens, output_tokens=raw.output_tokens,
            order=list(raw.order), node_log=list(raw.node_log),
            round_log={name: fr.round_log for name, fr in raw.results.items()},
            total_time_s=dt, policy=pol, raw=raw,
            n_replayed=sum(rec.n_replayed for rec in raw.node_log),
            node_estimates=ests)


class JoinQuery(Query):
    """A lazy CSV-backed semantic join between two tables of one session."""

    def __init__(self, session, left, right, oracle,
                 policy: Optional[ExecutionPolicy] = None):
        super().__init__(session, policy)
        self.left = left
        self.right = right
        self.oracle = oracle

    def _validate(self, pol: ExecutionPolicy) -> None:
        if pol.method not in ("csv", "csv-sim"):
            raise ValueError(
                f"method {pol.method!r} is not supported for joins; the "
                "CSV-backed join runs under 'csv' (UniVote) or 'csv-sim' "
                "(SimVote pair embeddings)")

    def _estimate_calls(self, pol: ExecutionPolicy) -> float:
        """First-round closed form: every cluster-pair block pays at least
        one ``min_sample`` probe, capped by the total pair count.  A join
        whose pair decisions replay from the session memo is budgeted at
        zero (same accounting rule as replayable filter leaves)."""
        if (pol.reuse_memo and self.session.memo.lookup_join(
                self.left, self.right, self.oracle,
                pol.to_join_config()) is not None):
            return 0.0
        cfg = pol.to_join_config()
        n_pairs = len(self.left) * len(self.right)
        n_blocks = (min(cfg.n_clusters_left, len(self.left))
                    * min(cfg.n_clusters_right, len(self.right)))
        per = n_pairs / max(n_blocks, 1)
        return float(min(n_pairs, n_blocks
                         * max(cfg.min_sample, math.ceil(cfg.xi * per))))

    def explain(self, policy: Optional[ExecutionPolicy] = None) -> Explain:
        pol = self._resolve(policy)
        self._validate(pol)
        est = self._estimate_calls(pol)
        n_pairs = len(self.left) * len(self.right)
        name = f"{self.left.name} JOIN {self.right.name}"
        nodes = [NodeEstimate(name=name, est_live_in=float(n_pairs),
                              est_calls=est, selectivity=None)]
        ex = Explain(kind="join", method="csv-join", table=name, n=n_pairs,
                     order=[name], naive_order=[name], nodes=nodes,
                     est_oracle_calls=est, pilot_calls=0, estimate=None,
                     text="")
        ex.text = _render_explain(ex, pol)
        return ex

    def collect(self, policy: Optional[ExecutionPolicy] = None) -> QueryResult:
        pol = self._resolve(policy)
        self._validate(pol)
        self._check_budget(pol, self._estimate_calls(pol))
        tr = get_tracer()
        t0 = monotonic()
        name = f"{self.left.name} JOIN {self.right.name}"
        ests = [NodeEstimate(
            name=name, est_live_in=float(len(self.left) * len(self.right)),
            est_calls=self._estimate_calls(pol), selectivity=None)]
        with tr.span("query", kind="query", query="join",
                     table=name, method=pol.method) as qsp:
            # pair-oracle sightings: mutations of either side must clear
            # this oracle's memo outright (pair ids reindex; see
            # docs/caching.md)
            self.session.memo.note_pair_oracle(self.left.name, self.oracle)
            self.session.memo.note_pair_oracle(self.right.name, self.oracle)
            cfg = pol.to_join_config()
            if pol.reuse_memo:
                jm = self.session.memo.lookup_join(self.left, self.right,
                                                   self.oracle, cfg)
                if jm is not None:
                    # replay: same predicate, same join semantics, both
                    # tables unchanged — zero oracle calls, bit-identical
                    # pair mask
                    raw = JoinResult(
                        pair_mask=jm.pair_mask.copy(), n_llm_calls=0,
                        input_tokens=0, output_tokens=0, n_voted=0,
                        n_fallback=0, refine_rounds=0,
                        total_time_s=monotonic() - t0, round_log=[])
                    qsp.set(calls=0, n_replayed=int(raw.pair_mask.size))
                    tr.metrics.inc("query.collects")
                    tr.metrics.inc("memo.replays")
                    return QueryResult(
                        kind="join", pair_mask=raw.pair_mask, n_llm_calls=0,
                        pilot_calls=0, n_proxy_calls=0, input_tokens=0,
                        output_tokens=0, order=[name],
                        node_log=[], round_log={"join": []},
                        total_time_s=raw.total_time_s, policy=pol, raw=raw,
                        n_replayed=int(raw.pair_mask.size),
                        node_estimates=ests)
            assign_l = assign_r = None
            if pol.reuse_clustering:
                assign_l = self.left.precluster(cfg.n_clusters_left,
                                                cfg.seed)
                assign_r = self.right.precluster(cfg.n_clusters_right,
                                                 cfg.seed)
            snap = _snapshot([self.oracle])
            raw: JoinResult = sem_join(self.left.embeddings,
                                       self.right.embeddings, self.oracle,
                                       cfg, assign_left=assign_l,
                                       assign_right=assign_r)
            for oracle, before in snap:
                self.session._absorb(oracle.stats.delta(before))
            if pol.reuse_memo:
                # record for later replay (mirrors the filter-side rule:
                # recording is skipped only when reuse is pinned off — the
                # legacy shim sessions must never accumulate state)
                self.session.memo.record_join(self.left, self.right,
                                              self.oracle, cfg,
                                              raw.pair_mask)
            qsp.set(calls=raw.n_llm_calls)
            tr.metrics.inc("query.collects")
        return QueryResult(
            kind="join", pair_mask=raw.pair_mask,
            n_llm_calls=raw.n_llm_calls, pilot_calls=0, n_proxy_calls=0,
            input_tokens=raw.input_tokens, output_tokens=raw.output_tokens,
            order=[name], node_log=[],
            round_log={"join": raw.round_log},
            total_time_s=monotonic() - t0, policy=pol, raw=raw,
            node_estimates=ests)
