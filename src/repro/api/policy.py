"""ExecutionPolicy: one dataclass for every physical-execution knob.

The legacy surface scattered its knobs across ``sem_filter``'s keyword
arguments (``method``, ``executor``, ``pipeline_depth``, ``proxy``, baseline
``**kw``), ``CSVConfig``, ``JoinConfig``, and ``PlanExecutor``'s constructor.
``ExecutionPolicy`` absorbs all of them into a single frozen value object
that the lazy query layer resolves at ``.collect()`` time:

    Session default  <  Query policy  <  collect(policy=...) override

Conversion is lossless in both directions: ``to_csv_config`` /
``to_join_config`` produce exactly the config the legacy machinery expects
(so results stay bit-identical), and ``from_csv_config`` /
``from_join_config`` lift a legacy config into a policy (the deprecation
shims use this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

from repro.core.csv_filter import CSVConfig
from repro.plan.join import JoinConfig

METHODS = ("csv", "csv-sim", "reference", "lotus", "bargain")
BASELINE_METHODS = ("reference", "lotus", "bargain")
EXECUTORS = ("round", "sequential")


class OracleBudgetError(RuntimeError):
    """Raised before execution when the estimated oracle spend of a query
    exceeds ``ExecutionPolicy.max_oracle_calls``.  The guard is closed-form
    (``repro.plan.cost.est_oracle_calls``-style, worst-case live sets) so it
    never consumes oracle calls itself."""


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """Declarative physical-execution choices for one query (or session).

    method: "csv" (UniVote CSV), "csv-sim" (SimVote CSV), or one of the
        linear baselines "reference" / "lotus" / "bargain" — all five route
        through the same ``Query.collect()``.
    executor / pipeline_depth: round-vectorized vs. sequential CSV driver,
        and the number of overlapped oracle waves per round.  The service
        scheduler generalizes the same depth to barrier ticks: each tick
        splits into up to ``pipeline_depth`` packed waves so engine prefill
        of wave k+1 overlaps host-side voting on wave k (docs/serving.md).
    epsilon: user error tolerance; when set, the sampling rate xi is derived
        via the paper's Thm 3.3/3.6 instead of taken from ``xi``.
    max_oracle_calls: advisory pre-flight budget; ``collect()`` raises
        ``OracleBudgetError`` when the closed-form estimate exceeds it.
    baseline: extra keyword arguments for the lotus/bargain baselines
        (``sample_size``, ``recall_target``, ``accuracy_target``, ...).
    """

    # ---- logical routing ----
    method: str = "csv"
    # ---- CSV driver (mirrors CSVConfig) ----
    executor: str = "round"
    pipeline_depth: int = 1
    # shards: split each round's sample/oracle/vote wave across N mesh
    # hosts (repro.distributed.round); bit-identical to shards=1 — a
    # physical knob like executor/pipeline_depth, excluded from the memo
    # fingerprint (docs/distributed.md)
    shards: int = 1
    n_clusters: int = 4
    xi: float = 0.005
    epsilon: Optional[float] = None   # error tolerance; derives xi when set
    min_sample: int = 101
    lb: float = 0.15
    ub: Optional[float] = None
    max_recluster: int = 3
    vote: Optional[str] = None        # None -> derived from method
    theory_l: float = 0.9996
    sim_v: float = 2.0
    sim_bandwidth: Optional[float] = None
    kmeans_iters: int = 50
    seed: int = 0
    # ---- plan lowering (multi-predicate expressions) ----
    optimize: bool = True
    pilot_size: int = 32
    reuse_clustering: bool = True
    # ---- session-level reuse (docs/caching.md) ----
    # reuse_memo: replay memoized per-tuple decisions for a predicate the
    # session has already evaluated on this table (zero oracle calls on an
    # unchanged table; after append()/update() only dirty clusters re-vote).
    # reuse_stats: plan later queries with memoized pilot probes and
    # observed (post-run) selectivities instead of fresh pilot calls.
    # Both are pure reuse: with an empty memo, behavior is bit-identical
    # to a cold session.
    reuse_memo: bool = True
    reuse_stats: bool = True
    # ---- joins ----
    n_clusters_right: Optional[int] = None  # None -> n_clusters
    max_refine: int = 3
    # ---- baselines (lotus/bargain keyword arguments) ----
    baseline: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # ---- budget ----
    max_oracle_calls: Optional[int] = None
    # ---- durability (repro.service.log; docs/distributed.md) ----
    # log_dir: when set, FilterService(policy=...) opens an append-only
    # session log there instead of whole-session snapshots; restart =
    # snapshot + log-tail replay.  Compaction triggers when either
    # threshold is crossed (checked at quiescent points).
    log_dir: Optional[str] = None
    log_compact_bytes: int = 4 << 20
    log_compact_records: int = 10_000
    # ---- online quality auditing (repro.obs.audit; docs/observability.md) --
    # audit_rate: fraction of the table held out as a stratified, seeded
    # audit sample after each collect(); the sample is labeled by the real
    # oracle and compared against the CSV-voted mask.  Audit spend is
    # accounted under ``audit.*`` metrics only — never ``oracle.*``, memo
    # state, or the oracle's RNG stream — so the default 0.0 is bit-identical
    # and auditing never perturbs the query it measures.  Excluded from
    # to_csv_config()/the memo fingerprint (a pure observation knob).
    audit_rate: float = 0.0
    audit_seed: int = 0
    audit_max_rows: int = 256
    # audit_error_bound: tolerated disagreement rate before a cluster is
    # flagged for re-vote/re-cluster; None derives epsilon (if set) else 0.05.
    audit_error_bound: Optional[float] = None

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}; "
                             f"expected one of {METHODS}")
        if self.executor not in EXECUTORS:
            raise ValueError(f"unknown executor {self.executor!r}; "
                             f"expected one of {EXECUTORS}")
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if self.shards < 1:
            raise ValueError("shards must be >= 1")
        if self.shards > 1 and self.executor != "round":
            raise ValueError("shards > 1 requires executor='round'")
        if self.log_compact_bytes < 1 or self.log_compact_records < 1:
            raise ValueError("log compaction thresholds must be >= 1")
        if self.vote not in (None, "uni", "sim"):
            raise ValueError(f"unknown vote {self.vote!r}; "
                             "expected 'uni' or 'sim'")
        if not 0.0 <= self.audit_rate <= 1.0:
            raise ValueError("audit_rate must be in [0, 1]")
        if self.audit_max_rows < 1:
            raise ValueError("audit_max_rows must be >= 1")
        if self.audit_error_bound is not None and not (
                0.0 < self.audit_error_bound < 1.0):
            raise ValueError("audit_error_bound must be in (0, 1)")

    # ------------------------------------------------------------ derived
    @property
    def vote_(self) -> str:
        """Effective voting algorithm: csv-sim forces SimVote (matching the
        legacy ``sem_filter`` dispatch); otherwise the explicit ``vote``."""
        if self.method == "csv-sim":
            return "sim"
        return self.vote if self.vote is not None else "uni"

    @property
    def is_baseline(self) -> bool:
        return self.method in BASELINE_METHODS

    # -------------------------------------------------------- conversions
    def to_csv_config(self) -> CSVConfig:
        return CSVConfig(
            n_clusters=self.n_clusters, xi=self.xi,
            min_sample=self.min_sample, lb=self.lb, ub=self.ub,
            max_recluster=self.max_recluster, vote=self.vote_,
            epsilon=self.epsilon, theory_l=self.theory_l, sim_v=self.sim_v,
            sim_bandwidth=self.sim_bandwidth, kmeans_iters=self.kmeans_iters,
            seed=self.seed, executor=self.executor,
            pipeline_depth=self.pipeline_depth, shards=self.shards)

    def to_join_config(self) -> JoinConfig:
        right = (self.n_clusters_right if self.n_clusters_right is not None
                 else self.n_clusters)
        return JoinConfig(
            n_clusters_left=self.n_clusters, n_clusters_right=right,
            xi=self.xi, min_sample=self.min_sample, lb=self.lb, ub=self.ub,
            max_refine=self.max_refine, vote=self.vote_,
            sim_bandwidth=self.sim_bandwidth, kmeans_iters=self.kmeans_iters,
            seed=self.seed)

    @classmethod
    def from_csv_config(cls, cfg: CSVConfig, **overrides) -> "ExecutionPolicy":
        fields = dict(
            n_clusters=cfg.n_clusters, xi=cfg.xi, min_sample=cfg.min_sample,
            lb=cfg.lb, ub=cfg.ub, max_recluster=cfg.max_recluster,
            vote=cfg.vote, epsilon=cfg.epsilon, theory_l=cfg.theory_l,
            sim_v=cfg.sim_v, sim_bandwidth=cfg.sim_bandwidth,
            kmeans_iters=cfg.kmeans_iters, seed=cfg.seed,
            executor=cfg.executor, pipeline_depth=cfg.pipeline_depth,
            shards=cfg.shards)
        fields.update(overrides)
        return cls(**fields)

    @classmethod
    def from_join_config(cls, cfg: JoinConfig, **overrides) -> "ExecutionPolicy":
        fields = dict(
            n_clusters=cfg.n_clusters_left,
            n_clusters_right=cfg.n_clusters_right, xi=cfg.xi,
            min_sample=cfg.min_sample, lb=cfg.lb, ub=cfg.ub,
            max_refine=cfg.max_refine, vote=cfg.vote,
            sim_bandwidth=cfg.sim_bandwidth, kmeans_iters=cfg.kmeans_iters,
            seed=cfg.seed)
        fields.update(overrides)
        return cls(**fields)

    def replace(self, **changes) -> "ExecutionPolicy":
        return dataclasses.replace(self, **changes)
