"""Session: the shared-resource scope of the declarative query API.

A ``Session`` owns everything that outlives a single query:

- the **precluster cache**, keyed by ``(table id, n_clusters, seed)`` so two
  tables in one session can never share a k-means assignment (the legacy
  per-table cache was keyed by ``(n_clusters, seed)`` only, which was safe
  per instance but impossible to share safely across tables);
- an **oracle registry** (name -> oracle [+ proxy]) so queries can refer to
  predicates declaratively by name;
- a run-level **OracleStats** aggregate — every ``collect()`` folds its
  per-oracle deltas (``BaseOracle.scope`` semantics) into ``session.stats``;
- an optional default **embedder** applied to text-only tables, and an
  optional ``ServingEngine`` for real-backbone oracles.

``Session.table(...)`` returns a ``TableHandle`` whose ``.filter()`` /
``.join()`` build lazy queries (see ``repro.api.query``).  Handles satisfy
the ``PlanExecutor`` table protocol (``embeddings``, ``precluster``,
``len``), so the plan layer runs on them unchanged.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.api.policy import ExecutionPolicy
from repro.api.query import FilterQuery, JoinQuery
from repro.core.oracle import OracleStats
from repro.core.operators import SemanticTable
from repro.plan.expr import Expr, Pred


class TableHandle:
    """A table registered in a session.  Cheap, immutable identity object:
    the data lives in the wrapped ``SemanticTable``; clustering lives in the
    session cache."""

    def __init__(self, session: "Session", table: SemanticTable, name: str):
        self.session = session
        self.name = name
        self._table = table

    def __len__(self) -> int:
        return len(self._table)

    def __repr__(self) -> str:
        return f"TableHandle({self.name!r}, n={len(self)})"

    @property
    def embeddings(self) -> np.ndarray:
        return self._table.embeddings

    @property
    def texts(self):
        return self._table.texts

    def precluster(self, n_clusters: int, seed: int = 0) -> np.ndarray:
        """Offline clustering via the session cache (PlanExecutor protocol)."""
        return self.session._precluster(self, n_clusters, seed)

    # ------------------------------------------------------------ queries
    def filter(self, predicate, oracle=None, *, proxy=None,
               policy: Optional[ExecutionPolicy] = None,
               name: Optional[str] = None) -> FilterQuery:
        """Build a lazy filter query (no oracle calls until ``collect``).

        Accepted forms:
        - ``filter(expr)`` — a ``repro.plan`` expression (``Pred``/``And``/
          ``Or``/``Not``); each leaf carries its own oracle.
        - ``filter("name", oracle)`` — single predicate bound inline.
        - ``filter("name")`` — predicate looked up in the session's oracle
          registry (``register_oracle``); a registered proxy rides along.
        - ``filter(oracle, name="...")`` — bare oracle; the name defaults to
          ``"<table>.p<k>"``.
        """
        if isinstance(predicate, Expr):
            if oracle is not None:
                raise TypeError("filter(expr) does not take a second oracle "
                                "argument; bind oracles on the Pred leaves")
            expr = predicate
        elif isinstance(predicate, str):
            if oracle is None:
                oracle, reg_proxy = self.session._lookup_oracle(predicate)
                proxy = proxy if proxy is not None else reg_proxy
            expr = Pred(predicate, oracle)
        elif callable(predicate) or hasattr(predicate, "stats"):
            pred_name = name or self.session._anon_pred_name(self)
            expr = Pred(pred_name, predicate)
        else:
            raise TypeError(
                f"unsupported predicate {type(predicate).__name__}; expected "
                "a plan Expr, a predicate name, or an oracle callable")
        return FilterQuery(self.session, self, expr, policy=policy,
                           proxy=proxy)

    def join(self, right, oracle, *,
             policy: Optional[ExecutionPolicy] = None) -> JoinQuery:
        """Build a lazy semantic join against another table.

        oracle: callable over flat pair ids ``i * len(right) + j`` (see
        ``repro.plan.join.pair_ids``) with ``.stats`` accounting.
        """
        if isinstance(right, SemanticTable):
            right = self.session.table(table=right)
        if not isinstance(right, TableHandle):
            raise TypeError(f"join target must be a TableHandle or "
                            f"SemanticTable, got {type(right).__name__}")
        if right.session is not self.session:
            raise ValueError("join requires both tables in the same session")
        return JoinQuery(self.session, self, right, oracle, policy=policy)


class Session:
    """Scope object for the lazy query API (the canonical entry point)."""

    def __init__(self, policy: Optional[ExecutionPolicy] = None,
                 embedder: Optional[Callable] = None, engine=None):
        self.policy = policy or ExecutionPolicy()
        self.embedder = embedder
        self.engine = engine  # optional ServingEngine for ModelOracles
        self.stats = OracleStats()        # LLM-oracle spend across collects
        self.proxy_stats = OracleStats()  # cheap cascade-proxy spend, apart
        self._tables: Dict[str, TableHandle] = {}
        self._by_table_id: Dict[int, TableHandle] = {}
        self._assign_cache: Dict[Tuple[str, int, int], np.ndarray] = {}
        self._oracles: Dict[str, Tuple[Any, Any]] = {}
        self._anon_tables = 0
        self._anon_preds = 0

    # -------------------------------------------------------------- tables
    def table(self, texts: Optional[Sequence[str]] = None, embeddings=None,
              embedder: Optional[Callable] = None,
              name: Optional[str] = None,
              table: Optional[SemanticTable] = None) -> TableHandle:
        """Register a table and return its handle.

        Either pass raw data (``texts``/``embeddings``/``embedder``) or wrap
        an existing ``SemanticTable`` via ``table=``.  Wrapping the same
        SemanticTable twice returns the existing handle.
        """
        if table is not None:
            if texts is not None or embeddings is not None:
                raise TypeError("pass either table= or texts=/embeddings=, "
                                "not both")
            existing = self._by_table_id.get(id(table))
            if existing is not None:
                if name is not None and name != existing.name:
                    raise ValueError(
                        f"table already registered as {existing.name!r}")
                return existing
        else:
            table = SemanticTable(texts=texts, embeddings=embeddings,
                                  embedder=embedder or self.embedder)
        if name is None:
            name = f"t{self._anon_tables}"
            self._anon_tables += 1
        if name in self._tables:
            raise ValueError(f"table name {name!r} already registered")
        handle = TableHandle(self, table, name)
        self._tables[name] = handle
        self._by_table_id[id(table)] = handle
        return handle

    def __getitem__(self, name: str) -> TableHandle:
        return self._tables[name]

    # ------------------------------------------------------------- oracles
    def register_oracle(self, name: str, oracle, proxy=None) -> None:
        """Bind a predicate name to an oracle (and optional baseline proxy)
        so queries can say ``handle.filter("name")``."""
        if name in self._oracles:
            raise ValueError(f"oracle {name!r} already registered")
        self._oracles[name] = (oracle, proxy)

    def oracle(self, name: str):
        return self._lookup_oracle(name)[0]

    def _lookup_oracle(self, name: str) -> Tuple[Any, Any]:
        try:
            return self._oracles[name]
        except KeyError:
            raise KeyError(f"no oracle registered under {name!r}; call "
                           "session.register_oracle(name, oracle) or pass "
                           "the oracle to .filter() directly") from None

    def _anon_pred_name(self, handle: TableHandle) -> str:
        name = f"{handle.name}.p{self._anon_preds}"
        self._anon_preds += 1
        return name

    # ---------------------------------------------------------- clustering
    def _precluster(self, handle: TableHandle, n_clusters: int,
                    seed: int) -> np.ndarray:
        """Cross-table-safe precluster cache.

        Keyed by (table name, k, seed) — table names are unique per session
        (the session-visible table id), so two tables can never share an
        assignment entry.  Computation delegates to the wrapped table's own
        per-instance memoized ``precluster``: that second layer is what
        keeps a SemanticTable shared with legacy call sites (deprecation
        shims, direct ``sem_filter``) on one consistent assignment.
        """
        key = (handle.name, int(n_clusters), int(seed))
        if key not in self._assign_cache:
            self._assign_cache[key] = handle._table.precluster(
                n_clusters, seed)
        return self._assign_cache[key]

    # ---------------------------------------------------------- accounting
    def _absorb(self, delta: OracleStats) -> None:
        self.stats.merge(delta)

    def _absorb_proxy(self, delta: OracleStats) -> None:
        self.proxy_stats.merge(delta)
