"""Session: the shared-resource scope of the declarative query API.

A ``Session`` owns everything that outlives a single query:

- the **precluster cache**, keyed by ``(table id, n_clusters, seed)`` so two
  tables in one session can never share a k-means assignment (the legacy
  per-table cache was keyed by ``(n_clusters, seed)`` only, which was safe
  per instance but impossible to share safely across tables);
- an **oracle registry** (name -> oracle [+ proxy]) so queries can refer to
  predicates declaratively by name;
- a run-level **OracleStats** aggregate — every ``collect()`` folds its
  per-oracle deltas (``BaseOracle.scope`` semantics) into ``session.stats``;
- an optional default **embedder** applied to text-only tables, and an
  optional ``ServingEngine`` for real-backbone oracles.

``Session.table(...)`` returns a ``TableHandle`` whose ``.filter()`` /
``.join()`` build lazy queries (see ``repro.api.query``).  Handles satisfy
the ``PlanExecutor`` table protocol (``embeddings``, ``precluster``,
``len``), so the plan layer runs on them unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.memo import SessionMemo
from repro.api.policy import ExecutionPolicy
from repro.api.query import FilterQuery, JoinQuery
from repro.core.oracle import OracleStats
from repro.core.operators import SemanticTable
from repro.embeddings.cache import CachingEmbedder, EmbeddingCache
from repro.obs.trace import get_tracer
from repro.plan.expr import Expr, Pred


class TableHandle:
    """A table registered in a session.  Cheap identity object: the data
    lives in the wrapped ``SemanticTable``; clustering lives in the session
    cache.  ``append``/``update`` mutate the table *incrementally*: new or
    changed rows are embedded through the session's embedding cache,
    assigned to the nearest existing centroid, and only the touched
    clusters are marked dirty — the next ``collect`` of a memoized
    predicate re-votes exactly those clusters (docs/caching.md).

    ``version`` counts mutations; ``_dirty[(k, seed)][c]`` is the version
    at which cluster ``c`` of that cached clustering last changed.
    """

    def __init__(self, session: "Session", table: SemanticTable, name: str):
        self.session = session
        self.name = name
        self._table = table
        self.version = 0
        self._dirty: Dict[Tuple[int, int], np.ndarray] = {}
        # micro-batch ingestion buffer: non-None while inside a
        # ``coalescing_appends()`` block (list of (texts, embeddings))
        self._append_buffer: Optional[List[tuple]] = None

    def __len__(self) -> int:
        return len(self._table)

    def __repr__(self) -> str:
        return f"TableHandle({self.name!r}, n={len(self)})"

    @property
    def embeddings(self) -> np.ndarray:
        return self._table.embeddings

    @property
    def texts(self):
        return self._table.texts

    def precluster(self, n_clusters: int, seed: int = 0) -> np.ndarray:
        """Offline clustering via the session cache (PlanExecutor protocol)."""
        return self.session._precluster(self, n_clusters, seed)

    # ------------------------------------------------- incremental updates
    def _resolve_embeddings(self, texts, embeddings) -> Optional[np.ndarray]:
        """Rows to add/patch: given embeddings win; else embed texts through
        the session cache (only while the table's embeddings are
        materialized — a still-lazy table defers to its embedder)."""
        if embeddings is not None:
            return np.asarray(embeddings, np.float32)
        if self._table._embeddings is None:
            return None  # still lazy: the (caching) embedder runs later
        embedder = self._table._embedder or self.session.embedder
        if embedder is None:
            raise ValueError(f"table {self.name!r} has materialized "
                             "embeddings but no embedder; pass embeddings=")
        if not (isinstance(embedder, CachingEmbedder)
                and embedder.cache is self.session.embedding_cache):
            # tables registered with embeddings= carry a raw embedder (the
            # table() wrap only covers lazy-text tables) — route mutations
            # through THIS session's cache regardless
            embedder = CachingEmbedder(self.session.embedding_cache, embedder)
        return np.asarray(embedder(list(texts)), np.float32)

    def _apply_touched(self, touched: Dict) -> None:
        """Fold a SemanticTable patch report into the session cache and the
        per-cluster dirty versions (at the freshly bumped version)."""
        for (k, seed), (assign, touched_clusters) in touched.items():
            self.session._assign_cache[(self.name, k, seed)] = assign
            dirty = self._dirty.setdefault(
                (k, seed), np.full(k, self.version, dtype=np.int64))
            dirty[touched_clusters] = self.version

    def append(self, texts: Optional[Sequence[str]] = None,
               embeddings=None) -> "TableHandle":
        """Add rows without invalidating the precluster cache: new rows are
        embedded through the session's embedding cache and assigned to the
        nearest existing centroids; only the clusters that received rows
        are marked dirty (memoized predicates re-vote exactly those).

        Note: oracles index tuples by id — an oracle bound to this table
        must cover the grown id range (synthetic oracles: build them over
        the post-append labels).
        """
        if texts is None and embeddings is None:
            raise TypeError("append needs texts= and/or embeddings=")
        n_new = len(texts) if texts is not None else len(embeddings)
        if n_new == 0:
            return self  # no rows: don't bump the version for a no-op
        if self._append_buffer is not None:
            # micro-batch mode: park the rows; one _append_rows call (one
            # precluster patch, one dirty-set union, one version bump)
            # happens at coalescing_appends() exit.  Embedding resolution
            # is deferred too, so buffered text rows still embed through
            # the session cache exactly as the per-append path would.
            self._append_buffer.append(
                (list(texts) if texts is not None else None,
                 np.asarray(embeddings, np.float32)
                 if embeddings is not None else None))
            return self
        new_emb = self._resolve_embeddings(texts, embeddings)
        touched = self._table._append_rows(
            list(texts) if texts is not None else None, new_emb)
        self.version += 1
        self._apply_touched(touched)
        get_tracer().metrics.inc("session.append_rows", n_new)
        # growing a table reindexes pair ids of joins against it
        self.session._clear_pair_oracles(self.name)
        self.session._log_mutation(
            "append", self, texts=list(texts) if texts is not None else None,
            embeddings=new_emb)
        return self

    @contextlib.contextmanager
    def coalescing_appends(self):
        """Micro-batch ingestion: coalesce every ``append()`` inside the
        block into ONE table mutation at exit.

        High-frequency small appends (a stream tick draining several
        sources) pay one nearest-centroid precluster patch, one dirty-set
        union, and one version bump instead of one of each per call.
        Bit-identity to the per-append path: centroids do not move during
        a patch, so per-row nearest-centroid assignment is independent of
        batch composition, and the rerun set of a later memoized collect —
        members of clusters dirtied since the memo's version — is exactly
        the union the per-append path would dirty (asserted in
        tests/test_stream.py).  Reads inside the block (``len``,
        ``embeddings``, ``collect``) see the PRE-append table; reentrant
        blocks coalesce into the outermost one.
        """
        if self._append_buffer is not None:
            yield self   # nested: the outermost block owns the flush
            return
        self._append_buffer = []
        try:
            yield self
        finally:
            buf, self._append_buffer = self._append_buffer, None
            self._flush_appends(buf)

    def _flush_appends(self, buf: List[tuple]) -> None:
        """Apply buffered appends as one mutation (see coalescing_appends)."""
        if not buf:
            return
        has_texts = [t is not None for t, _ in buf]
        if any(has_texts) != all(has_texts):
            raise ValueError(
                "coalesced appends mix texts= and embeddings-only rows; "
                "a single micro-batch must use one form")
        texts: Optional[List[str]] = None
        if all(has_texts):
            texts = [s for t, _ in buf for s in t]
        # resolve each buffered batch exactly as append() would have (given
        # embeddings win; text rows embed through the session cache), then
        # concatenate into one patch
        embs = [self._resolve_embeddings(t, e) for t, e in buf]
        if any(e is None for e in embs) != all(e is None for e in embs):
            raise ValueError(
                "coalesced appends mix lazy-embedding and materialized "
                "rows; a single micro-batch must use one form")
        new_emb = (np.concatenate(embs)
                   if embs[0] is not None else None)
        touched = self._table._append_rows(texts, new_emb)
        self.version += 1
        self._apply_touched(touched)
        n_new = len(texts) if texts is not None else len(new_emb)
        get_tracer().metrics.inc("session.append_rows", n_new)
        self.session._clear_pair_oracles(self.name)
        self.session._log_mutation("append", self, texts=texts,
                                   embeddings=new_emb)

    def update(self, ids, texts: Optional[Sequence[str]] = None,
               embeddings=None) -> "TableHandle":
        """Replace rows in place (§3.1 update handling): changed rows are
        re-embedded through the session cache and re-assigned to the
        nearest centroid; their old and new clusters are marked dirty, and
        every oracle the session has seen touch this table drops its per-id
        memo entries for ``ids`` (the tuple content changed under them).
        """
        ids = np.asarray(ids, dtype=np.int64)
        if len(ids) == 0:
            return self
        if texts is None and embeddings is None:
            raise TypeError("update needs texts= and/or embeddings=")
        new_emb = self._resolve_embeddings(texts, embeddings)
        touched = self._table._update_rows(ids, texts, new_emb)
        self.version += 1
        self._apply_touched(touched)
        self.session._invalidate_oracles(self.name, ids)
        self.session._log_mutation(
            "update", self, ids=ids,
            texts=list(texts) if texts is not None else None,
            embeddings=new_emb)
        return self

    # ------------------------------------------------------------ queries
    def filter(self, predicate, oracle=None, *, proxy=None,
               policy: Optional[ExecutionPolicy] = None,
               name: Optional[str] = None) -> FilterQuery:
        """Build a lazy filter query (no oracle calls until ``collect``).

        Accepted forms:
        - ``filter(expr)`` — a ``repro.plan`` expression (``Pred``/``And``/
          ``Or``/``Not``); each leaf carries its own oracle.
        - ``filter("name", oracle)`` — single predicate bound inline.
        - ``filter("name")`` — predicate looked up in the session's oracle
          registry (``register_oracle``); a registered proxy rides along.
        - ``filter(oracle, name="...")`` — bare oracle; the name defaults to
          ``"<table>.p<k>"``.
        """
        if isinstance(predicate, Expr):
            if oracle is not None:
                raise TypeError("filter(expr) does not take a second oracle "
                                "argument; bind oracles on the Pred leaves")
            expr = predicate
        elif isinstance(predicate, str):
            if oracle is None:
                oracle, reg_proxy = self.session._lookup_oracle(predicate)
                proxy = proxy if proxy is not None else reg_proxy
            expr = Pred(predicate, oracle)
        elif callable(predicate) or hasattr(predicate, "stats"):
            pred_name = name or self.session._anon_pred_name(self)
            expr = Pred(pred_name, predicate)
        else:
            raise TypeError(
                f"unsupported predicate {type(predicate).__name__}; expected "
                "a plan Expr, a predicate name, or an oracle callable")
        return FilterQuery(self.session, self, expr, policy=policy,
                           proxy=proxy)

    def join(self, right, oracle, *,
             policy: Optional[ExecutionPolicy] = None) -> JoinQuery:
        """Build a lazy semantic join against another table.

        oracle: callable over flat pair ids ``i * len(right) + j`` (see
        ``repro.plan.join.pair_ids``) with ``.stats`` accounting.
        """
        if isinstance(right, SemanticTable):
            right = self.session.table(table=right)
        if not isinstance(right, TableHandle):
            raise TypeError(f"join target must be a TableHandle or "
                            f"SemanticTable, got {type(right).__name__}")
        if right.session is not self.session:
            raise ValueError("join requires both tables in the same session")
        return JoinQuery(self.session, self, right, oracle, policy=policy)


class Session:
    """Scope object for the lazy query API (the canonical entry point)."""

    def __init__(self, policy: Optional[ExecutionPolicy] = None,
                 embedder: Optional[Callable] = None, engine=None,
                 embedding_cache: Optional[EmbeddingCache] = None,
                 coordinator=None):
        self.policy = policy or ExecutionPolicy()
        self.embedder = embedder
        self.engine = engine  # optional ServingEngine for ModelOracles
        # optional repro.distributed.DispatchCoordinator: several sessions'
        # schedulers feed one merged dispatch lane (docs/distributed.md)
        self.coordinator = coordinator
        # content-hash keyed embedding store: per-session by default; pass
        # one cache to several sessions to share embeddings explicitly
        # explicit None check: an empty cache is falsy (__len__ == 0), so
        # ``or`` would silently drop a freshly shared cache
        self.embedding_cache = (embedding_cache if embedding_cache is not None
                                else EmbeddingCache())
        # cross-query memo: decisions, pilot probes, observed selectivities
        # (docs/caching.md; gated per query by ExecutionPolicy.reuse_*)
        self.memo = SessionMemo()
        self.stats = OracleStats()        # LLM-oracle spend across collects
        self.proxy_stats = OracleStats()  # cheap cascade-proxy spend, apart
        self._tables: Dict[str, TableHandle] = {}
        self._by_table_id: Dict[int, TableHandle] = {}
        self._assign_cache: Dict[Tuple[str, int, int], np.ndarray] = {}
        self._oracles: Dict[str, Tuple[Any, Any]] = {}
        self._anon_tables = 0
        self._anon_preds = 0
        # shared-state guard for concurrent collects (repro.service): the
        # precluster cache and the run-level stats aggregates are the only
        # session state written from query threads
        self._lock = threading.Lock()
        self._scheduler = None  # lazy repro.service.QueryScheduler
        # attached repro.service.log.SessionLogStore recorder (None when
        # the session is not log-backed); table mutations and precluster
        # fits notify it through _log_mutation/_log_precluster
        self._session_log = None

    # -------------------------------------------------------------- tables
    def table(self, texts: Optional[Sequence[str]] = None, embeddings=None,
              embedder: Optional[Callable] = None,
              name: Optional[str] = None,
              table: Optional[SemanticTable] = None) -> TableHandle:
        """Register a table and return its handle.

        Either pass raw data (``texts``/``embeddings``/``embedder``) or wrap
        an existing ``SemanticTable`` via ``table=``.  Wrapping the same
        SemanticTable twice returns the existing handle.
        """
        if table is not None:
            if texts is not None or embeddings is not None:
                raise TypeError("pass either table= or texts=/embeddings=, "
                                "not both")
            existing = self._by_table_id.get(id(table))
            if existing is not None:
                if name is not None and name != existing.name:
                    raise ValueError(
                        f"table already registered as {existing.name!r}")
                return existing
        else:
            emb_fn = embedder or self.embedder
            if emb_fn is not None and texts is not None:
                # route lazy embedding through the session cache so
                # overlapping/updated tables embed only genuinely new rows
                emb_fn = CachingEmbedder(self.embedding_cache, emb_fn)
            table = SemanticTable(texts=texts, embeddings=embeddings,
                                  embedder=emb_fn)
        if name is None:
            name = f"t{self._anon_tables}"
            self._anon_tables += 1
        if name in self._tables:
            raise ValueError(f"table name {name!r} already registered")
        handle = TableHandle(self, table, name)
        self._tables[name] = handle
        self._by_table_id[id(table)] = handle
        return handle

    def __getitem__(self, name: str) -> TableHandle:
        return self._tables[name]

    # ------------------------------------------------------------- oracles
    def register_oracle(self, name: str, oracle, proxy=None) -> None:
        """Bind a predicate name to an oracle (and optional baseline proxy)
        so queries can say ``handle.filter("name")``."""
        if name in self._oracles:
            raise ValueError(f"oracle {name!r} already registered")
        self._oracles[name] = (oracle, proxy)
        if self._session_log is not None:
            self._session_log.bind_oracle(name, oracle)

    def oracle(self, name: str):
        return self._lookup_oracle(name)[0]

    def _lookup_oracle(self, name: str) -> Tuple[Any, Any]:
        try:
            return self._oracles[name]
        except KeyError:
            raise KeyError(f"no oracle registered under {name!r}; call "
                           "session.register_oracle(name, oracle) or pass "
                           "the oracle to .filter() directly") from None

    def _anon_pred_name(self, handle: TableHandle) -> str:
        name = f"{handle.name}.p{self._anon_preds}"
        self._anon_preds += 1
        return name

    # ---------------------------------------------------------- clustering
    def _precluster(self, handle: TableHandle, n_clusters: int,
                    seed: int) -> np.ndarray:
        """Cross-table-safe precluster cache.

        Keyed by (table name, k, seed) — table names are unique per session
        (the session-visible table id), so two tables can never share an
        assignment entry.  Computation delegates to the wrapped table's own
        per-instance memoized ``precluster``: that second layer is what
        keeps a SemanticTable shared with legacy call sites (deprecation
        shims, direct ``sem_filter``) on one consistent assignment.
        """
        key = (handle.name, int(n_clusters), int(seed))
        if key not in self._assign_cache:
            # serialized: concurrent service queries on one table must not
            # race the (deterministic but expensive) k-means fit
            with self._lock:
                if key not in self._assign_cache:
                    assign, _ = handle._table.precluster_full(n_clusters,
                                                              seed)
                    self._assign_cache[key] = assign
                    # per-cluster dirty versions start at the clustering's
                    # birth version: decisions memoized from here on see
                    # clean clusters until append()/update() touches them
                    handle._dirty.setdefault(
                        (int(n_clusters), int(seed)),
                        np.full(int(n_clusters), handle.version,
                                dtype=np.int64))
                    if self._session_log is not None:
                        self._session_log.record_precluster(
                            handle, int(n_clusters), int(seed))
        return self._assign_cache[key]

    def _invalidate_oracles(self, table_name: str, ids: np.ndarray) -> None:
        """Update-path invalidation: drop stale per-id oracle memo entries
        for every oracle the session has seen touch ``table_name``.

        Sightings only, NOT the whole registry: tuple ids are plain ints,
        so invalidating a registered-but-unused oracle would drop its
        already-paid decisions for the *other* table it actually ran on.
        ``collect()`` registers every leaf oracle as a sighting even under
        reuse-disabled policies, so the sweep covers all relevant memos."""
        for oracle in self.memo.oracles_for(table_name):
            if hasattr(oracle, "memo_invalidate"):
                oracle.memo_invalidate(ids)
        self._clear_pair_oracles(table_name)

    def _clear_pair_oracles(self, table_name: str) -> None:
        """Pair (join) oracles memoize by pair id ``i * len(right) + j``:
        growing the right table reindexes every pair and updating either
        side changes pair payloads, so ANY mutation clears the whole memo
        of every join oracle sighted on the table — and the session-level
        join decision memo entries touching the table on either side."""
        for oracle in self.memo.pair_oracles_for(table_name):
            if hasattr(oracle, "memo_clear"):
                oracle.memo_clear()
        self.memo.drop_joins(table_name)

    # ------------------------------------------------------- durability log
    def _log_mutation(self, kind: str, handle: TableHandle, **fields) -> None:
        """Forward a table mutation to the attached session log (no-op for
        plain sessions)."""
        if self._session_log is not None:
            self._session_log.record_mutation(kind, handle, **fields)

    # ---------------------------------------------------------- accounting
    def _absorb(self, delta: OracleStats) -> None:
        with self._lock:
            self.stats.merge(delta)

    def _absorb_proxy(self, delta: OracleStats) -> None:
        with self._lock:
            self.proxy_stats.merge(delta)

    # ------------------------------------------------- concurrent service
    @property
    def scheduler(self):
        """The session's concurrent query scheduler (repro.service),
        created on first use.  ``submit``/``gather`` are the front door;
        reach for the scheduler itself for ``holding()`` (batch several
        submissions into one admission wave) or ``stats``."""
        if self._scheduler is None:
            from repro.service.scheduler import QueryScheduler
            self._scheduler = QueryScheduler(
                self, coordinator=self.coordinator)
        return self._scheduler

    def submit(self, query, policy: Optional[ExecutionPolicy] = None):
        """Schedule a query for concurrent execution; returns a
        ``QueryTicket`` (docs/service.md).  Oracle batches of all in-flight
        queries are merged into cross-query dispatches; per-query masks and
        call counts stay bit-identical to serial ``collect()``."""
        return self.scheduler.submit(query, policy=policy)

    def gather(self, *tickets):
        """Wait for submitted queries; returns their ``QueryResult``s (all
        outstanding tickets when called without arguments)."""
        return self.scheduler.gather(*tickets)

    def close(self) -> None:
        """Shut down the scheduler's worker threads (no-op when the
        concurrent service was never used)."""
        if self._scheduler is not None:
            self._scheduler.close()
            self._scheduler = None
