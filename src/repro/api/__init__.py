"""repro.api — the canonical declarative entry point (lazy Session/Query).

    from repro.api import ExecutionPolicy, Session

    sess = Session(policy=ExecutionPolicy(n_clusters=4, xi=0.005))
    reviews = sess.table(texts=..., embeddings=..., name="reviews")

    q = reviews.filter("is positive", oracle) & ~reviews.filter("spam", o2)
    print(q.explain())          # optimizer order + est_oracle_calls per node
    r = q.collect()             # the ONLY step that spends oracle calls
    r.mask, r.n_llm_calls, sess.stats

Filters, expression cascades, joins, and the linear baselines
(reference/lotus/bargain) all route through the same two calls —
``.explain()`` / ``.collect()`` — under one ``ExecutionPolicy``.  The legacy
``SemanticTable.sem_filter*``/``sem_join`` methods are deprecated shims over
this layer.  See docs/api.md.
"""
from repro.api.memo import ReplayHit, ReuseView, SessionMemo
from repro.api.policy import (BASELINE_METHODS, EXECUTORS, METHODS,
                              ExecutionPolicy, OracleBudgetError)
from repro.api.query import Explain, FilterQuery, JoinQuery, Query, QueryResult
from repro.api.session import Session, TableHandle
from repro.embeddings.cache import CachingEmbedder, EmbeddingCache

__all__ = [
    "BASELINE_METHODS", "EXECUTORS", "METHODS",
    "ExecutionPolicy", "OracleBudgetError",
    "Explain", "FilterQuery", "JoinQuery", "Query", "QueryResult",
    "Session", "TableHandle",
    "ReplayHit", "ReuseView", "SessionMemo",
    "CachingEmbedder", "EmbeddingCache",
]
