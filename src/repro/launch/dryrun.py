import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks device count at first init.
# This flag is set ONLY here — tests and benches see the single real device.

import argparse  # noqa: E402
import ast  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (get_config, input_specs, list_archs,  # noqa: E402
                           long_context_skip_reason)
from repro.distributed.api import sharding_context  # noqa: E402
from repro.distributed.rules import MeshRules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402
from repro.train.optimizer import OptConfig, adamw_init, opt_logical_axes  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402
from repro.utils.timing import monotonic  # noqa: E402

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_COLLECTIVES = ["all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"]


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-chip collective traffic from post-SPMD HLO.

    CPU HLO dumps put shapes only on results, so operand bytes are derived
    from the result shape + replica group size N:
      all-gather: operand = result / N; all-reduce / all-to-all /
      collective-permute: operand = result; reduce-scatter: operand = result*N.
    ``wire_bytes`` additionally estimates ring-algorithm bytes on the ICI
    links (all-reduce 2x(N-1)/N, gather/scatter (N-1)/N of the full tensor).
    """
    out = {c: 0 for c in _COLLECTIVES}
    wire = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for coll in _COLLECTIVES:
            if f" {coll}(" not in line and f" {coll}-start(" not in line:
                continue
            eq = line.find("=")
            op = line.find(f" {coll}")
            if eq < 0 or op < eq:
                continue
            result = line[eq + 1:op]
            rbytes = sum(_shape_bytes(d, dims)
                         for d, dims in _SHAPE_RE.findall(result))
            n = _group_size(line)
            if coll == "all-gather":
                operand = rbytes // max(1, n)
                w = rbytes * (n - 1) // max(1, n)
            elif coll == "reduce-scatter":
                operand = rbytes * n
                w = rbytes * (n - 1)
            elif coll == "all-reduce":
                operand = rbytes
                w = 2 * rbytes * (n - 1) // max(1, n)
            else:  # all-to-all, collective-permute
                operand = rbytes
                w = rbytes * (n - 1) // max(1, n) if coll == "all-to-all" else rbytes
            out[coll] += operand
            wire[coll] += w
            counts[coll] += 1
            break
    return {"bytes": out, "wire_bytes": wire, "counts": counts,
            "total_bytes": sum(out.values()),
            "total_wire_bytes": sum(wire.values())}


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "host_temp_size_in_bytes",
            "serialized_size_in_bytes"]
    d = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            d[k] = int(v)
    return d


def _cost_dict(compiled):
    try:
        c = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(c, (list, tuple)):
        c = c[0]
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float)) and "{" not in k}


def _batch_shardings(mesh, rules: MeshRules, spec_tree):
    def one(name, leaf):
        if name in ("tokens", "targets"):
            axes = ("batch",) + (None,) * (leaf.ndim - 1)
        elif name in ("prefix_embeds", "enc_frames"):
            axes = ("batch", None, None)
        elif name == "pos":
            axes = ("kv_batch",)
        else:
            axes = (None,) * leaf.ndim
        return NamedSharding(mesh, rules.spec(axes, leaf.shape))

    return {k: one(k, v) for k, v in spec_tree.items()}


def _tree_shardings(mesh, rules, axes_tree, abs_tree):
    return jax.tree_util.tree_map(
        lambda ax, leaf: NamedSharding(mesh, rules.spec(ax, leaf.shape)),
        axes_tree, abs_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _replicated_tree(mesh, abs_tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), abs_tree)


def build_cell(arch: str, shape_name: str, mesh_kind: str, overrides=None,
               oc: OptConfig = None):
    """Lower + compile one (arch x shape x mesh) cell; return artifact dict.

    Override keys starting with "_" are launcher levers, not config fields:
      _donate:           donate params/opt (train) or cache (decode)
      _last_only:        prefill emits last-position logits only
      _microbatches=N:   gradient accumulation
      _serve_replicated: drop FSDP ("embed"->data) for inference when the
                         bf16 model-sharded weights fit comfortably in HBM
    """
    overrides = dict(overrides or {})
    donate = overrides.pop("_donate", False)
    last_only = overrides.pop("_last_only", False)
    microbatches = overrides.pop("_microbatches", 1)
    serve_repl = overrides.pop("_serve_replicated", False)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    art = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "overrides": dict(overrides, _donate=donate, _last_only=last_only,
                             _microbatches=microbatches,
                             _serve_replicated=serve_repl),
           "ok": False}

    if shape_name == "long_500k":
        reason = long_context_skip_reason(arch)
        if reason:
            art.update(skipped_by_design=True, reason=reason, ok=True)
            return art

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rules = MeshRules(mesh)
    if serve_repl and shape.kind != "train":
        model_ways = mesh.shape["model"]
        shard_gb = cfg.param_count() * 2 / model_ways / 1e9
        if shard_gb < 8.0:
            rules.rules["embed"] = []  # replicate weights across data axis
            art["serve_replicated_applied"] = True
    chips = mesh.devices.size

    p_axes = lm.param_logical_axes(cfg)
    params_abs = lm.abstract_params(cfg)
    p_shard = _tree_shardings(mesh, rules, p_axes, params_abs)
    specs = input_specs(cfg, shape)

    t0 = monotonic()
    with sharding_context(rules), mesh:
        if shape.kind == "train":
            oc = oc or OptConfig()
            train_step = make_train_step(cfg, oc, microbatches=microbatches)
            opt_abs = jax.eval_shape(lambda p: adamw_init(p, oc), params_abs)
            o_axes = opt_logical_axes(p_axes, oc)
            o_shard = _tree_shardings(mesh, rules, o_axes, opt_abs)
            o_shard["step"] = NamedSharding(mesh, P())
            b_shard = _batch_shardings(mesh, rules, specs)
            out_abs = jax.eval_shape(train_step, params_abs, opt_abs, specs)
            out_shard = (p_shard, o_shard, _replicated_tree(mesh, out_abs[2]))
            lowered = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=out_shard,
                donate_argnums=(0, 1) if donate else (),
            ).lower(params_abs, opt_abs, specs)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                logits, cache, pos = lm.prefill(
                    cfg, params, batch["tokens"],
                    prefix_embeds=batch.get("prefix_embeds"),
                    enc_frames=batch.get("enc_frames"),
                    max_len=shape.seq_len, last_only=last_only)
                return logits, cache, pos

            b_shard = _batch_shardings(mesh, rules, specs)
            c_axes = lm.cache_logical_axes(cfg)
            out_abs = jax.eval_shape(prefill_step, params_abs, specs)
            logit_axes = (("batch", "vocab") if last_only
                          else ("batch", None, "vocab"))
            logits_sh = NamedSharding(
                mesh, rules.spec(logit_axes, out_abs[0].shape))
            cache_sh = _tree_shardings(mesh, rules, c_axes, out_abs[1])
            pos_sh = NamedSharding(mesh, rules.spec(("kv_batch",), out_abs[2].shape))
            lowered = jax.jit(
                prefill_step,
                in_shardings=(p_shard, b_shard),
                out_shardings=(logits_sh, cache_sh, pos_sh),
            ).lower(params_abs, specs)
        else:  # decode
            long_ctx = shape_name == "long_500k"

            def serve_step(params, cache, tokens, pos):
                return lm.decode_step(cfg, params, cache, tokens, pos)

            c_axes = lm.cache_logical_axes(cfg, long_context=long_ctx)
            cache_abs = specs["cache"]
            cache_sh = _tree_shardings(mesh, rules, c_axes, cache_abs)
            tok_sh = NamedSharding(mesh, rules.spec(("kv_batch",),
                                                    specs["tokens"].shape))
            out_abs = jax.eval_shape(serve_step, params_abs, cache_abs,
                                     specs["tokens"], specs["pos"])
            logits_sh = NamedSharding(
                mesh, rules.spec(("kv_batch", "vocab"), out_abs[0].shape))
            lowered = jax.jit(
                serve_step,
                in_shardings=(p_shard, cache_sh, tok_sh, tok_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(1,) if donate else (),
            ).lower(params_abs, cache_abs, specs["tokens"], specs["pos"])

        t_lower = monotonic() - t0
        compiled = lowered.compile()
        t_compile = monotonic() - t0 - t_lower

    mem = _mem_dict(compiled)
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)  # legacy: loop bodies counted once

    # trip-count-expanded static cost (see launch/hlo_cost.py): XLA's
    # cost_analysis counts while bodies once, undercounting scanned programs
    from repro.launch import hlo_cost
    try:
        xc = hlo_cost.analyze(hlo)
        expanded = {
            "flops": xc.flops, "bytes": xc.bytes,
            "transcendentals": xc.transcendentals,
            "coll_bytes": dict(xc.coll_bytes),
            "coll_wire": dict(xc.coll_wire),
            "total_coll_bytes": xc.total_coll_bytes,
            "total_coll_wire": xc.total_coll_wire,
        }
    except Exception as e:  # pragma: no cover
        expanded = {"error": f"{type(e).__name__}: {e}"}

    flops = expanded.get("flops") or cost.get("flops", 0.0)
    bytes_acc = expanded.get("bytes") or cost.get("bytes accessed", 0.0)
    coll_total = expanded.get("total_coll_bytes", coll["total_bytes"])
    coll_wire = expanded.get("total_coll_wire", coll["total_wire_bytes"])
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_total / ICI_BW,
        "collective_wire_s": coll_wire / ICI_BW,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    art.update(
        ok=True, chips=int(chips), lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2), memory=mem, cost=cost,
        cost_expanded=expanded,
        collectives=coll, roofline_terms=terms, dominant=dominant,
        params=cfg.param_count(), active_params=cfg.active_param_count(),
        sharding_warnings=sorted(set(rules.warnings)),
        hlo_bytes=len(hlo),
    )
    return art


def cell_path(arch, shape_name, mesh_kind, tag="baseline") -> pathlib.Path:
    safe = arch.replace("/", "_").replace(".", "_")
    return ART_DIR / f"{safe}__{shape_name}__{mesh_kind}__{tag}.json"


ASSIGNED = ["falcon-mamba-7b", "mixtral-8x22b", "dbrx-132b", "internvl2-26b",
            "gemma3-12b", "stablelm-12b", "codeqwen1.5-7b", "qwen1.5-0.5b",
            "jamba-v0.1-52b", "whisper-base"]


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true", help="all 40 assigned cells")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb lever)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    ART_DIR.mkdir(parents=True, exist_ok=True)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s, m) for a in ASSIGNED for s in SHAPES for m in meshes]
    else:
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s, m) for a in archs for s in shapes for m in meshes]

    n_ok = n_fail = 0
    for arch, shape_name, mesh_kind in cells:
        path = cell_path(arch, shape_name, mesh_kind, args.tag)
        if path.exists() and not args.force:
            print(f"skip (exists): {path.name}")
            continue
        print(f"=== {arch} x {shape_name} x {mesh_kind} [{args.tag}] ===",
              flush=True)
        try:
            art = build_cell(arch, shape_name, mesh_kind, overrides or None)
        except Exception as e:
            art = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        art["tag"] = args.tag
        path.write_text(json.dumps(art, indent=1))
        if art.get("ok"):
            n_ok += 1
            if art.get("skipped_by_design"):
                print(f"  SKIP-BY-DESIGN: {art['reason']}")
            else:
                t = art["roofline_terms"]
                print(f"  ok lower={art['lower_s']}s compile={art['compile_s']}s "
                      f"flops/dev={art['cost'].get('flops', 0):.3e} "
                      f"compute={t['compute_s']*1e3:.2f}ms "
                      f"memory={t['memory_s']*1e3:.2f}ms "
                      f"collective={t['collective_s']*1e3:.2f}ms "
                      f"dominant={art['dominant']}", flush=True)
                print("  memory_analysis:", art["memory"], flush=True)
        else:
            n_fail += 1
            print(f"  FAIL: {art['error']}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
