"""Production training driver: mesh + sharded train loop + fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 50 --ckpt-dir /tmp/repro_train

On real hardware this runs under the production mesh (launch/mesh.py); on
the CPU container use --smoke (reduced config, local 1x1 mesh).  Restart
the same command after a crash: it resumes from the newest committed
checkpoint (elastic: a different mesh shape re-shards on restore).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data import make_dataset, HashTokenizer
from repro.data.loader import PackedLoader
from repro.distributed.api import sharding_context
from repro.distributed.rules import MeshRules
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.utils.timing import monotonic
from repro.models import lm
from repro.train import OptConfig, adamw_init, make_train_step
from repro.train.optimizer import opt_logical_axes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default=None, choices=[None, "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_local_mesh(1, 1) if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    rules = MeshRules(mesh)
    oc = OptConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps)
    step_fn = make_train_step(cfg, oc, microbatches=args.microbatches,
                              compression=args.compression)

    p_axes = lm.param_logical_axes(cfg)
    p_shard = jax.tree_util.tree_map(
        lambda ax, s: rules.named_sharding(ax, s.shape),
        p_axes, lm.abstract_params(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    tok = HashTokenizer(cfg.vocab_size)
    ds = make_dataset("imdb_review", n=2000, seed=0)
    loader = PackedLoader([tok.encode(t) for t in ds.texts],
                          batch=args.batch, seq=args.seq, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    with sharding_context(rules), mesh:
        params = lm.init_params(cfg, jax.random.key(0))
        opt = adamw_init(params, oc)
        start = 0
        restored = mgr.restore({"params": params, "opt": opt})
        if restored[0] is not None:
            start, tree, _ = restored
            params, opt = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start} "
                  f"(re-sharded onto {dict(mesh.shape)})")
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        t0 = monotonic()
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in loader.batch_at(step).items()}
            params, opt, m = jit_step(params, opt, batch)
            if step % 10 == 0 or step == args.steps - 1:
                tput = args.batch * args.seq * max(1, step - start + 1) / (
                    monotonic() - t0)
                print(f"[train] step {step:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.2f} tok/s={tput:,.0f}",
                      flush=True)
            if step and step % args.ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt}, async_=True)
        mgr.wait()
        mgr.save(args.steps, {"params": params, "opt": opt})
    print(f"[train] done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
