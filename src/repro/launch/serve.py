"""Production serving driver: the CSV data plane + oracle model plane.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.1-8b --smoke

Boots the backbone on the mesh, the embedding encoder, and answers
semantic-filter requests through the CSV driver with the batched engine.
On restart, the oracle call-cache checkpoint avoids re-invoking the LLM.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import CSVConfig, SemanticTable
from repro.core.oracle import ModelOracle
from repro.core.operators import accuracy_f1
from repro.data import make_dataset, HashTokenizer
from repro.embeddings import EmbeddingModel
from repro.models import lm
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--predicate", default="the review is positive")
    ap.add_argument("--vote", default="csv", choices=["csv", "csv-sim"])
    ap.add_argument("--cache", default="/tmp/repro_serve_cache.json")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=8)
    tok = HashTokenizer(cfg.vocab_size)

    ds = make_dataset("imdb_review", n=args.n, seed=0)
    oracle = ModelOracle(engine, tok, args.predicate, ds.texts)
    cache_path = pathlib.Path(args.cache)
    if cache_path.exists():
        oracle.memo_restore(json.loads(cache_path.read_text()))
        print(f"[serve] restored {len(oracle.memo_snapshot())} cached calls")

    encoder = EmbeddingModel(smoke_config("e5-large"), max_len=32)
    table = SemanticTable(texts=ds.texts, embeddings=encoder.encode(ds.texts))
    r = table.sem_filter(oracle, method=args.vote,
                         cfg=CSVConfig(n_clusters=4, min_sample=25))
    cache_path.write_text(json.dumps(
        {str(k): v for k, v in oracle.memo_snapshot().items()}))
    print(f"[serve] predicate={args.predicate!r}: {int(r.mask.sum())}/{args.n} "
          f"pass; {r.n_llm_calls} LLM calls "
          f"({args.n/max(1, r.n_llm_calls):.1f}x reduction); "
          f"engine={engine.stats}")


if __name__ == "__main__":
    main()
