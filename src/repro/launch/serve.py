"""Production serving driver: the CSV data plane + oracle model plane.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.1-8b --smoke

Boots the backbone on the mesh, the embedding encoder, and answers
semantic-filter requests through the CSV driver with the batched engine.
On restart, the oracle call-cache checkpoint avoids re-invoking the LLM.

``--service K`` switches to the concurrent front end (repro.service): K
predicates become K ModelOracles over one shared engine, submitted
together so their per-round oracle batches merge into cross-query
dispatches, and the whole session (memo + caches + oracle call-caches) is
checkpointed through a SessionStore instead of the ad-hoc JSON cache —
restart the same command and every predicate replays at zero LLM calls.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import CSVConfig, SemanticTable
from repro.core.oracle import ModelOracle
from repro.core.operators import accuracy_f1
from repro.data import make_dataset, HashTokenizer
from repro.embeddings import EmbeddingModel
from repro.models import lm
from repro.obs import (FlightRecorder, HealthMonitor, LogAlertSink,
                       MetricsRegistry, StatusHub, Tracer, default_rules,
                       set_flight_recorder, set_monitor, set_tracer,
                       start_status_server, write_run_profile)
from repro.serving import ServingEngine

SERVICE_PREDICATES = [
    "the review is positive",
    "the review praises the acting",
    "the review discusses the plot",
    "the review would recommend the movie",
]


def start_metrics_server(registry: MetricsRegistry, port: int,
                         host: str = "127.0.0.1", hub: StatusHub = None,
                         label: str = "serve"):
    """Live observability endpoints on a daemon thread (stdlib only).

    /metrics serves the Prometheus dump (the historical scrape target);
    /healthz, /statusz, /varz come from ``repro.obs.status``.  Binds
    loopback by default — pass ``host="0.0.0.0"`` explicitly to expose the
    listener.  Returns the server so callers/tests can ``shutdown()`` it.
    """
    return start_status_server(registry, port, host=host, hub=hub,
                               label=label)


def export_trace(trace_dir: str, tracer: Tracer, registry: MetricsRegistry,
                 *stats_objects):
    """Sync legacy stat objects into the registry and write all sinks."""
    registry.sync_from(*[s for s in stats_objects if s is not None])
    files = write_run_profile(pathlib.Path(trace_dir), tracer, registry)
    n_spans = len(tracer.spans())
    print(f"[serve] trace: {n_spans} spans -> {trace_dir} "
          f"(spans.jsonl, trace.json, ticks.jsonl, metrics.prom, "
          f"metrics.json)")
    return files


def serve_concurrent(engine, tok, ds, embeddings, k: int, state_dir: str,
                     pipeline_depth: int = 1, shards: int = 1,
                     log_dir: str = None, hub: StatusHub = None,
                     flight: FlightRecorder = None):
    """K predicates through the concurrent service over one engine."""
    from repro.api import ExecutionPolicy, Session
    from repro.service import FilterService
    from repro.service.lifecycle import GracefulShutdown

    preds = (SERVICE_PREDICATES * ((k - 1) // len(SERVICE_PREDICATES) + 1))[:k]
    sess = Session(policy=ExecutionPolicy(n_clusters=4, min_sample=25,
                                          pipeline_depth=pipeline_depth,
                                          shards=shards))
    table = sess.table(embeddings=embeddings, name="reviews")
    for i, text in enumerate(preds):
        sess.register_oracle(f"p{i}", ModelOracle(engine, tok, text,
                                                  ds.texts))
    if log_dir is not None:
        # append-only log (docs/distributed.md): continuous durability,
        # restart = snapshot + log-tail replay
        service = FilterService(sess, log_dir=log_dir)
        rep = service.restore()
        if rep is not None:
            print(f"[serve] restore: {rep}")
            if rep.n_dropped:
                print(f"[serve] WARNING: {rep.n_dropped} entry(ies) did "
                      "not survive the restart (see report above)")
    else:
        service = FilterService(sess, store_dir=state_dir)
        if service.store.exists():
            rep = service.restore()
            print(f"[serve] restore: {rep}")
            n_dropped = len(rep.dropped) + len(rep.skipped)
            if n_dropped:
                # previously discarded silently: a warm start that lost
                # state looked identical to one that kept it all
                print(f"[serve] WARNING: {n_dropped} entry(ies) did not "
                      "survive the restart (see report above)")
    service.register_tenant("default", sess.policy)
    if hub is not None:
        # statusz sections come live as soon as the service exists
        hub.add_provider("tenants", service.status_view)
        hub.add_provider("scheduler", sess.scheduler.status_view)
        if service.log is not None:
            hub.add_provider("log", service.log.tail_summary)
    # exit-mode shutdown: SIGINT/SIGTERM writes a final session checkpoint
    # (best-effort mid-run — whatever rounds completed are memoized and
    # replay on restart) before exiting 128+signum; the normal path fires
    # the same once-only checkpoint via shutdown.close() below
    shutdown = GracefulShutdown(exit_on_signal=True).install()
    shutdown.register("service-checkpoint", service.checkpoint)
    if flight is not None:
        flight.attach_policy(sess.policy)
        if service.log is not None:
            flight.attach_log(service.log)
        flight.install(shutdown=shutdown)  # signal-only dump + excepthook
    with sess.scheduler.holding():
        tickets = [service.submit("default", table.filter(f"p{i}"),
                                  label=f"p{i}") for i in range(k)]
    results = service.gather(*tickets)
    for i, (text, r) in enumerate(zip(preds, results)):
        print(f"[serve] p{i} {text!r}: {int(r.mask.sum())}/{len(table)} "
              f"pass; {r.n_llm_calls} LLM calls, {r.n_replayed} replayed")
    merge = sess.scheduler.stats.merge
    print(f"[serve] merged dispatches: {merge.n_invocations}, mean "
          f"{merge.mean_batch_size:.0f} ids/invocation "
          f"(merge factor {merge.merge_factor:.1f}); engine={engine.stats}")
    print(f"[serve] per-tick: {merge.mean_wall_s * 1e3:.1f} ms mean "
          f"({merge.last_wall_s * 1e3:.1f} ms last), "
          f"{merge.tokens_per_s:.0f} oracle tokens/s; "
          f"engine mean batch {engine.mean_batch_size:.1f}, "
          f"bucket fill {engine.batcher.fill_ratio:.2f}, "
          f"truncated prompts {merge.n_truncated}")
    shutdown.close()   # final checkpoint (once) + restore signal handlers
    print(f"[serve] session checkpointed to {log_dir or state_dir} — rerun "
          "to replay at 0 LLM calls")
    service.close()
    return sess, results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--predicate", default="the review is positive")
    ap.add_argument("--vote", default="csv", choices=["csv", "csv-sim"])
    ap.add_argument("--cache", default="/tmp/repro_serve_cache.json")
    ap.add_argument("--service", type=int, default=0, metavar="K",
                    help="serve K concurrent predicates through "
                         "repro.service (cross-query batching + "
                         "restartable session store)")
    ap.add_argument("--state-dir", default="/tmp/repro_serve_state",
                    help="SessionStore directory for --service mode")
    ap.add_argument("--log-dir", default=None, metavar="DIR",
                    help="append-only session log directory (--service "
                         "mode); replaces --state-dir snapshots with "
                         "continuous checkpointing + log-tail restarts")
    ap.add_argument("--shards", type=int, default=1,
                    help="split each CSV round's sample/oracle/vote wave "
                         "across N shards (bit-identical to 1)")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "plain", "chunked", "tri", "flash",
                             "flash-ref"],
                    help="override the model's attention path; 'flash' "
                         "runs the Pallas kernels (interpret mode off-TPU)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="service tick waves: prefill of wave k+1 "
                         "overlaps voting on wave k (--service mode)")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="engine device batch cap per bucket")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="enable tracing; write spans.jsonl, Perfetto "
                         "trace.json, ticks.jsonl, metrics.prom and "
                         "metrics.json under DIR on exit")
    ap.add_argument("--metrics-port", type=int, default=0, metavar="PORT",
                    help="serve live /metrics, /healthz, /statusz and "
                         "/varz on PORT (0 = off)")
    ap.add_argument("--metrics-host", default="127.0.0.1", metavar="HOST",
                    help="bind address for --metrics-port (default "
                         "loopback; pass 0.0.0.0 to expose)")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="arm the flight recorder: dump a debug bundle "
                         "under DIR on unhandled exception, fatal signal, "
                         "or critical health alert")
    ap.add_argument("--linger", type=float, default=0.0, metavar="SECONDS",
                    help="keep the process (and status endpoints) alive "
                         "SECONDS after the run completes")
    ap.add_argument("--inject-failure", action="store_true",
                    help="raise after the run completes (CI: exercises "
                         "the flight recorder's crash path)")
    args = ap.parse_args()

    registry = MetricsRegistry()
    tracer = None
    monitor = None
    flight = None
    hub = None
    if args.trace_dir or args.metrics_port or args.flight_dir:
        # live metrics need the tracer installed even when only --metrics-port
        # is given: instrumented code publishes through get_tracer().metrics
        tracer = Tracer(metrics=registry)
        set_tracer(tracer)
        monitor = HealthMonitor(registry, rules=default_rules(),
                                sinks=[LogAlertSink("[serve][health]")])
        set_monitor(monitor)
    if args.flight_dir:
        flight = FlightRecorder(args.flight_dir, tracer=tracer,
                                registry=registry)
        flight.install()           # excepthook now; signal hook in-service
        set_flight_recorder(flight)
        monitor.add_sink(flight.note_alert)  # critical alerts dump too
    if args.metrics_port:
        hub = StatusHub(monitor=monitor, flight=flight)
        start_metrics_server(registry, args.metrics_port,
                             host=args.metrics_host, hub=hub)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn_impl:
        cfg = cfg.replace(attn_impl=args.attn_impl)
    params = lm.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch)
    tok = HashTokenizer(cfg.vocab_size)

    ds = make_dataset("imdb_review", n=args.n, seed=0)
    encoder = EmbeddingModel(smoke_config("e5-large"), max_len=32)
    embeddings = encoder.encode(ds.texts)

    if args.service > 0:
        sess, results = serve_concurrent(
            engine, tok, ds, embeddings, args.service,
            args.state_dir, pipeline_depth=args.pipeline_depth,
            shards=args.shards, log_dir=args.log_dir, hub=hub,
            flight=flight)
        if tracer is not None and args.trace_dir:
            print(results[0].profile())
            export_trace(args.trace_dir, tracer, registry,
                         sess.scheduler.stats, engine.batcher)
        _epilogue(args, flight)
        return

    oracle = ModelOracle(engine, tok, args.predicate, ds.texts)
    cache_path = pathlib.Path(args.cache)
    if cache_path.exists():
        oracle.memo_restore(json.loads(cache_path.read_text()))
        print(f"[serve] restored {len(oracle.memo_snapshot())} cached calls")

    table = SemanticTable(texts=ds.texts, embeddings=embeddings)
    r = table.sem_filter(oracle, method=args.vote,
                         cfg=CSVConfig(n_clusters=4, min_sample=25))
    cache_path.write_text(json.dumps(
        {str(k): v for k, v in oracle.memo_snapshot().items()}))
    print(f"[serve] predicate={args.predicate!r}: {int(r.mask.sum())}/{args.n} "
          f"pass; {r.n_llm_calls} LLM calls "
          f"({args.n/max(1, r.n_llm_calls):.1f}x reduction); "
          f"engine={engine.stats}")
    if tracer is not None and args.trace_dir:
        export_trace(args.trace_dir, tracer, registry,
                     getattr(oracle, "stats", None), engine.batcher)
    _epilogue(args, flight)


def _epilogue(args, flight):
    """Post-run hold/failure hooks shared by both serve modes."""
    if args.linger > 0:
        import time
        from repro.obs import get_monitor
        from repro.utils.timing import monotonic
        print(f"[serve] lingering {args.linger:g}s for live scrapes")
        end = monotonic() + args.linger
        try:
            while monotonic() < end:
                time.sleep(0.5)
                get_monitor().maybe_evaluate()
                if flight is not None:
                    flight.record_delta()
        except KeyboardInterrupt:
            pass
    if args.inject_failure:
        # deliberately crash AFTER the workload so the flight recorder's
        # excepthook path is exercised with a real span/metric history
        raise RuntimeError("injected failure (--inject-failure)")


if __name__ == "__main__":
    main()
