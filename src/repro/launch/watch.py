"""Stream watcher driver: standing semantic queries over a replayed feed.

    PYTHONPATH=src python -m repro.launch.watch --n 400 --queries 3

Replays a deterministic stream against K standing queries over one
session (docs/streaming.md): rows arrive per tick under a per-source
rate budget, each tick coalesced-appends them and re-votes only the
touched clusters, and every newly-matching row is pushed exactly once to
a JSONL sink.  The watcher checkpoints through a ``SessionStore`` —
rerun the same command after a kill (``--kill-after`` simulates one) and
it restores mid-stream: no already-notified row re-notifies, and the
rebuild itself costs ~0 oracle calls.

Default oracles are synthetic (seeded labels — fast, deterministic; the
CI stream-smoke leg).  ``--engine`` boots the tiny backbone instead and
answers every standing predicate with ``ModelOracle`` prompts batched
across queries through the scheduler, exactly like ``serve --service``.
"""
from __future__ import annotations

import argparse
import pathlib

import numpy as np

from repro.api import ExecutionPolicy, Session
from repro.core import SyntheticOracle
from repro.data import make_dataset
from repro.obs import (FlightRecorder, HealthMonitor, LogAlertSink,
                       MetricsRegistry, StatusHub, Tracer, default_rules,
                       set_flight_recorder, set_monitor, set_tracer)
from repro.service.lifecycle import GracefulShutdown
from repro.service.store import SessionStore
from repro.stream import (JsonlSink, RateBudget, StreamWatcher,
                          SyntheticSource)

WATCH_PREDICATES = [
    "the review is positive",
    "the review praises the acting",
    "the review discusses the plot",
    "the review would recommend the movie",
]
# synthetic label keys backing the K standing queries (cycled)
LABEL_KEYS = ["RV-Q1", "RV-Q3", "RV-Q2"]


def build_watcher(args):
    """Session + oracles + watcher over one deterministic stream."""
    ds = make_dataset("imdb_review", n=args.n, seed=0)
    pol = ExecutionPolicy(n_clusters=4, min_sample=25)
    sess = Session(policy=pol)
    store = SessionStore(args.state_dir)

    if args.engine:
        import jax

        from repro.configs import smoke_config
        from repro.core.oracle import ModelOracle
        from repro.data import HashTokenizer
        from repro.models import lm
        from repro.serving import ServingEngine
        cfg = smoke_config(args.arch)
        if args.attn_impl:
            cfg = cfg.replace(attn_impl=args.attn_impl)
        params = lm.init_params(cfg, jax.random.key(0))
        engine = ServingEngine(cfg, params)
        tok = HashTokenizer(cfg.vocab_size)
        # the stream table starts EMPTY; ModelOracle indexes the table's
        # live texts list, which append() extends in place, so prompts
        # always see the rows the ids name
        handle = sess.table(
            texts=[], embeddings=np.zeros((0, ds.embeddings.shape[1]),
                                          np.float32), name="feed")
        preds = (WATCH_PREDICATES
                 * ((args.queries - 1) // len(WATCH_PREDICATES) + 1))
        for i in range(args.queries):
            sess.register_oracle(f"p{i}", ModelOracle(
                engine, tok, preds[i], handle._table.texts))
    else:
        for i in range(args.queries):
            key = LABEL_KEYS[i % len(LABEL_KEYS)]
            sess.register_oracle(f"p{i}", SyntheticOracle(
                ds.labels[key], flip_prob=0.0, seed=7 + i,
                token_lens=ds.token_lens))

    watcher = StreamWatcher(sess, table_name="feed", store=store,
                            tag="watch",
                            checkpoint_every=args.checkpoint_every)
    watcher.add_source(
        SyntheticSource("feed0", texts=list(ds.texts),
                        embeddings=ds.embeddings,
                        arrive_per_tick=args.arrive_per_tick, seed=11),
        RateBudget(rows_per_tick=args.rows_per_tick))
    sink_dir = pathlib.Path(args.state_dir)
    for i in range(args.queries):
        watcher.register(f"p{i}",
                         sink=JsonlSink(sink_dir / f"notify_p{i}.jsonl"))
    return sess, watcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400,
                    help="total rows in the replayed stream")
    ap.add_argument("--queries", type=int, default=3, metavar="K",
                    help="number of standing queries")
    ap.add_argument("--arrive-per-tick", type=int, default=40)
    ap.add_argument("--rows-per-tick", type=int, default=40,
                    help="per-source ingestion quota (arrivals beyond it "
                         "defer to later ticks, never drop)")
    ap.add_argument("--state-dir", default="/tmp/repro_watch_state",
                    help="SessionStore + sink + checkpoint directory")
    ap.add_argument("--checkpoint-every", type=int, default=2,
                    metavar="TICKS")
    ap.add_argument("--kill-after", type=int, default=0, metavar="K",
                    help="stop after tick K as if killed (checkpoint via "
                         "the shutdown path); rerun to restore mid-stream")
    ap.add_argument("--engine", action="store_true",
                    help="ModelOracle over the tiny backbone instead of "
                         "synthetic oracles")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "plain", "chunked", "tri", "flash",
                             "flash-ref"])
    ap.add_argument("--metrics-port", type=int, default=0, metavar="PORT",
                    help="serve live /metrics, /healthz, /statusz and "
                         "/varz on PORT (0 = off)")
    ap.add_argument("--metrics-host", default="127.0.0.1", metavar="HOST",
                    help="bind address for --metrics-port (default "
                         "loopback; pass 0.0.0.0 to expose)")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="arm the flight recorder: dump a debug bundle "
                         "under DIR on unhandled exception, fatal signal, "
                         "or critical health alert")
    ap.add_argument("--trace-dir", default=None, metavar="DIR")
    args = ap.parse_args()

    registry = MetricsRegistry()
    tracer = None
    monitor = None
    flight = None
    hub = None
    if args.trace_dir or args.metrics_port or args.flight_dir:
        tracer = Tracer(metrics=registry)
        set_tracer(tracer)
        monitor = HealthMonitor(registry, rules=default_rules(),
                                sinks=[LogAlertSink("[watch][health]")])
        set_monitor(monitor)
    if args.flight_dir:
        flight = FlightRecorder(args.flight_dir, tracer=tracer,
                                registry=registry)
        flight.install()
        set_flight_recorder(flight)
        monitor.add_sink(flight.note_alert)
    if args.metrics_port:
        from repro.launch.serve import start_metrics_server
        hub = StatusHub(monitor=monitor, flight=flight)
        start_metrics_server(registry, args.metrics_port,
                             host=args.metrics_host, hub=hub,
                             label="watch")

    sess, watcher = build_watcher(args)
    if hub is not None:
        hub.add_provider("stream", watcher.status_view)

    resumed = False
    if watcher.has_checkpoint():
        report = watcher.restore()
        resumed = True
        print(f"[watch] restored at tick {watcher.stats.n_ticks} "
              f"({watcher.stats.n_notifications} rows already notified, "
              f"0 oracle calls to rebuild): {report}")

    # flag-mode shutdown: the tick loop stops at a tick boundary, then the
    # watcher writes its final checkpoint and flushes every sink
    shutdown = GracefulShutdown(exit_on_signal=False).install()
    shutdown.register("watch-shutdown", watcher.shutdown)
    if flight is not None:
        flight.install(shutdown=shutdown)  # signal-triggered dumps only
    try:
        while not watcher.drained and not shutdown.requested:
            summary = watcher.tick()
            print(f"[watch] tick {summary['tick']}: +{summary['rows']} rows "
                  f"({summary['backlog']} deferred), "
                  f"{summary['oracle_calls']} oracle calls, "
                  f"{summary['notified']} notified")
            if args.kill_after and summary["tick"] >= args.kill_after:
                print(f"[watch] --kill-after {args.kill_after}: stopping "
                      "mid-stream (rerun to restore)")
                break
    finally:
        shutdown.close()   # runs watcher.shutdown() once
        sess.close()

    st = watcher.stats
    print(f"[watch] {'resumed ' if resumed else ''}done: {st.n_ticks} ticks, "
          f"{st.n_rows_ingested} rows ingested, "
          f"{st.n_oracle_calls} oracle calls, "
          f"{st.n_notifications} notifications "
          f"({sum(sq.runner.stats.n_deduped for sq in watcher.queries.values())}"
          f" deduped, "
          f"{sum(sq.runner.stats.n_dead_lettered for sq in watcher.queries.values())}"
          f" dead-lettered)")
    if tracer is not None and args.trace_dir:
        from repro.launch.serve import export_trace
        export_trace(args.trace_dir, tracer, registry, watcher,
                     sess.scheduler.stats if sess._scheduler else None)


if __name__ == "__main__":
    main()
