"""Trip-count-aware static cost model over post-SPMD HLO text.

``compiled.cost_analysis()`` counts every while-loop *body once* — for
scan-over-layers programs that undercounts FLOPs/bytes/collectives by the
trip count (measured: a 10-step scanned matmul reports 1 matmul of cost).
This module re-derives totals by:

1. splitting the HLO dump into computations,
2. building a per-computation symbol table (instruction -> shape),
3. costing instructions (dot FLOPs = 2 * prod(result) * contracted size,
   derived from operand shapes + contracting dims; bytes = operands +
   results at instruction granularity; collectives by kind with replica
   group size),
4. recursively expanding `while` ops by their trip counts (parsed from the
   loop-condition computation's iteration-bound constant), `conditional`
   by max branch, fusions/calls by inlining flops (not bytes — fusion
   internals never touch HBM).

The expansion is exact for scan-generated loops (constant trip counts) and
conservative (trip=1) when no bound constant is found.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"\)?\s*([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                           r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_wire: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendentals += other.transcendentals
        for k in COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k]
            self.coll_wire[k] += other.coll_wire[k]
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t, self.transcendentals * t,
                    {k: v * t for k, v in self.coll_bytes.items()},
                    {k: v * t for k, v in self.coll_wire.items()})

    @property
    def total_coll_bytes(self):
        return sum(self.coll_bytes.values())

    @property
    def total_coll_wire(self):
        return sum(self.coll_wire.values())


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        self._tables: Dict[str, Dict[str, list]] = {}
        self._memo: Dict[str, Cost] = {}
        self._trip_memo: Dict[str, int] = {}

    # ------------------------------------------------------------- parsing
    def _split(self, text: str):
        cur = None
        for line in text.splitlines():
            m = _COMP_HDR_RE.match(line.strip()) if "{" in line else None
            if m and ("->" in line):
                cur = m.group(1)
                self.comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.comps[cur].append(line)

    @staticmethod
    def _split_type_op(rhs: str):
        """rhs = '<type> <op>(<args>), attrs' -> (type_str, op, args_attrs).

        Handles tuple types: '(f32[..], s32[..]) while(%t), ...'.
        """
        rhs = rhs.strip()
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        type_str = rhs[: i + 1]
                        rest = rhs[i + 1:].strip()
                        break
            else:
                return rhs, "", ""
        else:
            m = re.match(r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*(.*)$", rhs)
            if not m:
                return rhs, "", ""
            type_str, rest = m.group(1), m.group(2)
        om = re.match(r"^([a-z][a-z0-9\-]*)\(", rest)
        if not om:
            return type_str, "", rest
        return type_str, om.group(1), rest[om.end() - 1:]

    def _table(self, comp: str) -> Dict[str, list]:
        if comp not in self._tables:
            tab = {}
            for line in self.comps.get(comp, []):
                m = _DEF_RE.match(line)
                if not m:
                    continue
                name, rhs = m.group(1), m.group(2)
                type_part, _, _ = self._split_type_op(rhs)
                tab[name] = _shape_list(type_part)
            self._tables[comp] = tab
        return self._tables[comp]

    def _trip_count(self, cond_comp: str) -> int:
        if cond_comp in self._trip_memo:
            return self._trip_memo[cond_comp]
        best = 1
        for line in self.comps.get(cond_comp, []):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        self._trip_memo[cond_comp] = best
        return best

    # ------------------------------------------------------------- costing
    def _dot_flops(self, line: str, comp: str, result_shapes) -> float:
        tab = self._table(comp)
        # operands = first two %refs inside the call parens
        paren = line[line.index("("):]
        ops = _OPERAND_RE.findall(paren)
        shapes = [tab.get(o) for o in ops]
        shapes = [s for s in shapes if s]
        if len(shapes) < 2 or not result_shapes:
            return 0.0
        lhs, rhs = shapes[0][0], shapes[1][0]
        res = result_shapes[0]
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        lc = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
        contracted = 1
        for d in lc:
            if d < len(lhs[1]):
                contracted *= lhs[1][d]
        out_elems = 1
        for d in res[1]:
            out_elems *= d
        return 2.0 * out_elems * max(contracted, 1)

    def _line_cost(self, line: str, comp: str) -> Cost:
        c = Cost()
        m = _DEF_RE.match(line)
        if not m:
            return c
        rhs = m.group(2)
        type_str, op, rest = self._split_type_op(rhs)
        if not op:
            return c
        result_shapes = _shape_list(type_str)
        rbytes = _nbytes(result_shapes)
        first_paren = len(rhs) - len(rest)  # args start here

        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            return c

        # bytes: result + operand reads, with slice-access corrections —
        # a dynamic-slice (or a fusion wrapping one) reads only the slice,
        # not its full operand; dynamic-update-slice writes only the update.
        tab = self._table(comp)
        if op == "dynamic-slice":
            c.bytes = 2.0 * rbytes
            return c
        if op == "dynamic-update-slice":
            ops_ = _OPERAND_RE.findall(rhs[first_paren:])
            upd = _nbytes(tab.get(ops_[1], [])) if len(ops_) > 1 else rbytes
            c.bytes = 2.0 * upd
            return c
        slicing_fusion = False
        if op == "fusion":
            cm0 = re.search(r"calls=%?([\w.\-]+)", line)
            if cm0 and cm0.group(1) in self.comps:
                body_text = "\n".join(self.comps[cm0.group(1)])
                slicing_fusion = ("dynamic-slice(" in body_text
                                  or "dynamic-update-slice(" in body_text
                                  or " gather(" in body_text)
        operand_bytes = 0
        for o in _OPERAND_RE.findall(rhs[first_paren:]):
            s = tab.get(o)
            if s:
                b = _nbytes(s)
                if slicing_fusion and b > 4 * max(rbytes, 1):
                    b = rbytes  # slice-read of a large buffer
                operand_bytes += b
        c.bytes = rbytes + operand_bytes

        base = op.replace("-start", "")
        if base in COLLECTIVES:
            n = 1
            g = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if g:
                n = int(g.group(2))
            else:
                g2 = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
                if g2:
                    n = len(g2.group(1).split(","))
            if base == "all-gather":
                c.coll_bytes[base] += rbytes / max(1, n)
                c.coll_wire[base] += rbytes * (n - 1) / max(1, n)
            elif base == "reduce-scatter":
                c.coll_bytes[base] += rbytes * n
                c.coll_wire[base] += rbytes * (n - 1)
            elif base == "all-reduce":
                c.coll_bytes[base] += rbytes
                c.coll_wire[base] += 2 * rbytes * (n - 1) / max(1, n)
            else:
                c.coll_bytes[base] += rbytes
                c.coll_wire[base] += rbytes
            return c

        if op == "dot":
            c.flops = self._dot_flops(line, comp, result_shapes)
            return c
        if op in ("exponential", "tanh", "logistic", "log", "rsqrt", "sqrt",
                  "power", "sine", "cosine"):
            n = rbytes / max(1, _DTYPE_BYTES.get(result_shapes[0][0], 4)) \
                if result_shapes else 0
            c.transcendentals = n
            return c

        if op == "while":
            body = cond = None
            bm = re.search(r"body=%?([\w.\-]+)", line)
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            if bm:
                body = bm.group(1)
            if cm:
                cond = cm.group(1)
            tm = re.search(r'known_trip_count[^0-9]*(\d+)', line)
            if tm:
                trips = int(tm.group(1))
            else:
                trips = self._trip_count(cond) if cond else 1
            if body:
                c += self.comp_cost(body).scaled(trips)
            return c

        if op in ("fusion", "call", "custom-call", "reduce", "map", "sort",
                  "scatter", "select-and-scatter", "reduce-window"):
            cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
            if cm and cm.group(1) in self.comps:
                inner = self.comp_cost(cm.group(1))
                # fusion internals don't touch HBM; inherit flops/colls only
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                for k in COLLECTIVES:
                    c.coll_bytes[k] += inner.coll_bytes[k]
                    c.coll_wire[k] += inner.coll_wire[k]
            return c

        if op == "conditional":
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                branches = [b.strip().lstrip("%")
                            for b in bm.group(1).split(",")]
                costs = [self.comp_cost(b) for b in branches
                         if b in self.comps]
                if costs:
                    c += max(costs, key=lambda x: x.flops + x.bytes)
            return c

        return c

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # cycle guard
        total = Cost()
        for line in self.comps.get(comp, []):
            total += self._line_cost(line, comp)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
