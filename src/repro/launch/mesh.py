"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (single) device.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)}; "
            "run under launch/dryrun.py (which forces 512 host devices) or "
            "on real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    n = n_data * n_model
    devices = jax.devices()[:n]
    assert len(devices) == n, (len(jax.devices()), n)
    return jax.make_mesh((n_data, n_model), ("data", "model"), devices=devices)
