"""Concurrent query scheduler: cross-query oracle batching with per-query
bit-identity.

``Session.submit()`` hands a lazy ``FilterQuery``/``JoinQuery`` to this
scheduler instead of collecting it inline.  Each submission becomes a
*task* whose ``collect()`` runs on its own worker thread, with every leaf
oracle rebound to a ``BatchingOracleProxy``: the proxy parks the calling
thread and enqueues the batch with the scheduler instead of evaluating it.
The scheduler loop is a barrier tick —

    when every in-flight task has a pending oracle batch, merge ALL
    pending batches (ordered by task submission, FIFO within a task) into
    one cross-query dispatch,

so the mean ids-per-invocation grows with concurrency (the serving layer
sees one large prompt wave instead of per-query trickles) while each
query's own oracle still evaluates exactly the batches, in exactly the
order, a serial ``collect()`` would produce.  Bit-identity argument:

- the CSV driver RNG, the pilot draw, and each oracle's flip stream are
  all per-query state — merging only *groups* evaluations, it never
  reorders them within a query (the merged dispatch drains through a
  single-lane ``AsyncOracleDispatcher``, strict FIFO);
- cross-query coupling exists ONLY through shared oracle objects (the
  session memo keys decisions/pilots/selectivities by oracle identity), so
  the scheduler defers any task whose leaf oracles intersect an in-flight
  task's — conflicting tasks run in submission order, exactly the serial
  interleaving, which is what lets a resubmitted predicate replay at zero
  calls under the scheduler too;
- shared session state written from task threads (precluster cache, run
  aggregates) is lock-guarded in ``Session``.

Mutating a table (``append``/``update``) while queries are in flight is
not supported — mutate between ``gather()`` and the next ``submit()``.

See docs/service.md for the full model.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional

import numpy as np

from repro.api.memo import oracle_identity
from repro.api.query import FilterQuery, JoinQuery
from repro.core.oracle import AsyncOracleDispatcher, evaluate_packed
from repro.obs.health import get_monitor
from repro.obs.trace import get_tracer
from repro.plan.expr import And, Expr, Not, Or, Pred
from repro.serving.batcher import DispatchMergeStats
from repro.utils.timing import monotonic


class BatchingOracleProxy:
    """Stand-in for one task's leaf oracle: routes every batch through the
    scheduler (park -> merge -> evaluate), delegates everything else —
    ``stats``, ``scope``, ``memo_*`` — to the wrapped oracle.

    ``memo_target`` is the wrapped oracle, so session-memo entries
    recorded through the proxy replay for serial collects of the same
    predicate and vice versa (see ``repro.api.memo.oracle_identity``).
    """

    def __init__(self, scheduler: "QueryScheduler", task: "_Task", inner):
        while isinstance(inner, BatchingOracleProxy):
            inner = inner.inner  # resubmitted query: never chain proxies
        self.inner = inner
        self.memo_target = oracle_identity(inner)
        self._scheduler = scheduler
        self._task = task

    def __call__(self, ids) -> np.ndarray:
        return self._scheduler._evaluate(self._task, self.inner, ids)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __repr__(self):
        return f"BatchingOracleProxy({self.inner!r})"


@dataclasses.dataclass
class _OracleRequest:
    task: "_Task"
    oracle: object            # the UNWRAPPED oracle to evaluate with
    ids: np.ndarray
    future: Future
    # the requester's innermost open span (its round-level oracle span),
    # captured on the task thread at park time: the explicit cross-thread
    # edge parenting the dispatch_wave span run on the FIFO lane thread
    span: object = None


class _Task:
    """One scheduled query: proxied clone, worker thread, pending queue."""

    def __init__(self, index: int, label: str, policy):
        self.index = index
        self.label = label
        self.policy = policy
        self.query = None                  # proxied clone, set at submit
        self.oracle_refs: List = []        # strong refs -> stable ids
        self.oracle_ids: frozenset = frozenset()
        self.pending: deque = deque()
        self.future: Future = Future()
        self.thread: Optional[threading.Thread] = None
        self.finished = False
        self.deferred = False


class QueryTicket:
    """Handle to one submitted query (returned by ``Session.submit``)."""

    def __init__(self, scheduler: "QueryScheduler", task: _Task):
        self._scheduler = scheduler
        self._task = task
        self._gathered = False

    @property
    def label(self) -> str:
        return self._task.label

    @property
    def index(self) -> int:
        return self._task.index

    def done(self) -> bool:
        return self._task.future.done()

    @property
    def future(self) -> Future:
        """The underlying completion future — for callbacks and
        exception inspection; consume results via ``result()``/
        ``gather()`` (they also prune scheduler bookkeeping)."""
        return self._task.future

    def add_done_callback(self, fn) -> None:
        """Run ``fn(future)`` when the query finishes (immediately if it
        already has).  The service front end settles tenant budgets here,
        so settlement cannot be skipped by consuming the ticket directly."""
        self._task.future.add_done_callback(fn)

    def result(self, timeout: Optional[float] = None):
        """Block until the query completes; returns its ``QueryResult`` or
        re-raises the error its collect() hit.  A consumed ticket is
        dropped from the scheduler's bookkeeping (later no-arg ``gather``
        calls won't re-deliver it)."""
        if not self.done() and self._scheduler._hold > 0:
            # dispatch is paused: waiting here would deadlock — the parked
            # oracle batches can never be served until the hold is released
            raise RuntimeError(
                "ticket.result() inside scheduler.holding() would wait "
                "forever (dispatch is paused); exit the holding() block "
                "first")
        try:
            return self._task.future.result(timeout=timeout)
        finally:
            if self._task.future.done():
                self._scheduler._discard(self)

    def __repr__(self):
        state = "done" if self.done() else "in-flight"
        return f"QueryTicket({self.label!r}, {state})"


@dataclasses.dataclass
class ServiceStats:
    """Scheduler-level accounting (per-query accounting stays on the
    oracles / QueryResults, untouched by merging)."""
    merge: DispatchMergeStats = dataclasses.field(
        default_factory=DispatchMergeStats)
    n_submitted: int = 0
    n_deferred: int = 0          # tasks held back by an oracle conflict
    n_completed: int = 0
    n_failed: int = 0
    n_dispatch_ticks: int = 0    # barrier ticks that drained a batch

    def metrics_view(self) -> dict:
        """Unified-name view for ``MetricsRegistry.sync_from`` (includes
        the nested merge stats)."""
        view = self.merge.metrics_view()
        view.update({
            "service.submitted": self.n_submitted,
            "service.deferred": self.n_deferred,
            "service.completed": self.n_completed,
            "service.failed": self.n_failed,
            "service.dispatch_ticks": self.n_dispatch_ticks,
        })
        return view


def _map_leaves(expr: Expr, fn) -> Expr:
    """Rebuild an expression with every Pred leaf passed through ``fn``."""
    if isinstance(expr, Pred):
        return fn(expr)
    if isinstance(expr, Not):
        return Not(_map_leaves(expr.child, fn))
    if isinstance(expr, And):
        return And(*[_map_leaves(c, fn) for c in expr.children])
    if isinstance(expr, Or):
        return Or(*[_map_leaves(c, fn) for c in expr.children])
    raise TypeError(f"unknown Expr node {type(expr).__name__}")


class QueryScheduler:
    """Barrier-tick scheduler over one Session (see module docstring).

    Use through ``Session.submit()``/``gather()``; ``holding()`` pauses
    dispatch so a burst of submissions merges from its very first round:

        with sess.scheduler.holding():
            tickets = [sess.submit(q) for q in queries]
        results = sess.gather(*tickets)
    """

    def __init__(self, session, pipeline_depth: Optional[int] = None,
                 pack: bool = True, coordinator=None):
        self.session = session
        self.stats = ServiceStats()
        # tick-level pipelining: CSVConfig.pipeline_depth generalized to
        # the service layer.  Each barrier tick splits into up to this many
        # task-ordered waves queued back-to-back on the FIFO lane, so the
        # engine prefill of wave k+1 overlaps host-side voting/partitioning
        # by the task threads wave k just unparked.  Depth 1 == one merged
        # dispatch per tick (the PR-5 behavior).
        if pipeline_depth is None:
            pipeline_depth = max(1, getattr(getattr(session, "policy", None),
                                            "pipeline_depth", 1))
        self.pipeline_depth = int(pipeline_depth)
        # pack=False keeps per-oracle engine dispatch (benchmark control)
        self.pack = pack
        self._cv = threading.Condition()
        # observable idle flag: set while the scheduler has NO queries in
        # flight or deferred.  The loop thread parks on the condition (via
        # ``wait_for``) the whole time this is set — an idle scheduler
        # performs zero dispatch work (asserted in tests/test_stream.py),
        # which matters for an always-on stream watcher between ticks.
        self.idle = threading.Event()
        self.idle.set()
        self._running: List[_Task] = []
        self._deferred: List[_Task] = []
        self._tickets: List[QueryTicket] = []
        self._hold = 0
        self._closed = False
        self._next_index = 0
        # one FIFO lane for ALL queries' oracles: the merged dispatch
        # drains through it in deterministic (task, submission) order.
        # With a DispatchCoordinator the lane is shared across schedulers
        # (repro.distributed.coordinator): waves still leave here in this
        # scheduler's submission order, so per-query bit-identity holds.
        if coordinator is not None:
            self._dispatcher = coordinator.attach()
        else:
            self._dispatcher = AsyncOracleDispatcher()
        self._loop_thread = threading.Thread(
            target=self._loop, daemon=True, name="csv-service-scheduler")
        self._loop_thread.start()

    # ------------------------------------------------------------- submit
    def submit(self, query, policy=None,
               label: Optional[str] = None) -> QueryTicket:
        """Schedule a query; returns immediately with a ticket.

        The query is cloned with every leaf oracle rebound to a batching
        proxy; the original query object stays collectable serially.
        Tasks whose oracles overlap an in-flight task are deferred until
        it finishes (submission order — serial semantics for the shared
        predicate, including memo replay)."""
        if getattr(query, "session", None) is not self.session:
            raise ValueError("query belongs to a different session")
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            task = _Task(self._next_index,
                         label or f"q{self._next_index}", policy)
            self._next_index += 1
        task.query = self._instrument(task, query)
        ticket = QueryTicket(self, task)
        with self._cv:
            self.stats.n_submitted += 1
            self.idle.clear()
            self._tickets.append(ticket)
            blockers = set()
            for t in self._running + self._deferred:
                blockers |= t.oracle_ids
            if task.oracle_ids & blockers:
                task.deferred = True
                self.stats.n_deferred += 1
                self._deferred.append(task)
            else:
                self._start_locked(task)
            self._cv.notify_all()
        return ticket

    def _instrument(self, task: _Task, query):
        """Clone with proxied oracles (one proxy per distinct oracle)."""
        proxies: Dict[int, BatchingOracleProxy] = {}

        def proxy_for(oracle) -> BatchingOracleProxy:
            ident = oracle_identity(oracle)
            key = id(ident)
            if key not in proxies:
                proxies[key] = BatchingOracleProxy(self, task, oracle)
                task.oracle_refs.append(ident)
            return proxies[key]

        if isinstance(query, FilterQuery):
            expr = _map_leaves(
                query.expr,
                lambda p: Pred(p.name, proxy_for(p.oracle), p.cfg))
            clone = FilterQuery(self.session, query.handle, expr,
                                policy=query.policy, proxy=query.proxy)
            # share the pilot caches: a re-plan of the clone must reuse
            # probes the original already paid for (and vice versa), not
            # re-probe a memo-warm oracle — see FilterQuery._prepare
            clone._pilot_cache = query._pilot_cache
            clone._fresh_pilots = query._fresh_pilots
        elif isinstance(query, JoinQuery):
            clone = JoinQuery(self.session, query.left, query.right,
                              proxy_for(query.oracle), policy=query.policy)
        else:
            raise TypeError(
                f"cannot schedule {type(query).__name__}; expected a "
                "FilterQuery or JoinQuery")
        task.oracle_ids = frozenset(id(o) for o in task.oracle_refs)
        return clone

    def _start_locked(self, task: _Task) -> None:
        self._running.append(task)
        task.thread = threading.Thread(
            target=self._run_task, args=(task,), daemon=True,
            name=f"csv-service-{task.label}")
        task.thread.start()

    def _run_task(self, task: _Task) -> None:
        try:
            result = task.query.collect(task.policy)
        except BaseException as e:
            failed = True
            task.future.set_exception(e)
        else:
            failed = False
            task.future.set_result(result)
        finally:
            with self._cv:
                task.finished = True
                self._running.remove(task)
                while task.pending:  # defensive: never strand a waiter
                    task.pending.popleft().future.set_exception(
                        RuntimeError("task exited with unserved oracle "
                                     "requests"))
                if failed:
                    self.stats.n_failed += 1
                else:
                    self.stats.n_completed += 1
                self._release_deferred_locked()
                if not self._running and not self._deferred:
                    self.idle.set()
                self._cv.notify_all()

    def _release_deferred_locked(self) -> None:
        """Start every deferred task whose oracles no longer conflict.
        Order is preserved: a deferred task also blocks later tasks that
        overlap it, so conflicting tasks always run in submission order."""
        blockers = set()
        for t in self._running:
            blockers |= t.oracle_ids
        still: List[_Task] = []
        for t in self._deferred:
            if t.oracle_ids & blockers:
                still.append(t)
            else:
                self._start_locked(t)
            blockers |= t.oracle_ids
        self._deferred = still

    # ------------------------------------------------------------ requests
    def _evaluate(self, task: _Task, oracle, ids) -> np.ndarray:
        """Proxy entry point: park the calling thread until the merged
        dispatch containing this batch resolves."""
        req = _OracleRequest(task=task, oracle=oracle,
                             ids=np.asarray(ids), future=Future(),
                             span=get_tracer().current())
        with self._cv:
            task.pending.append(req)
            self._cv.notify_all()
        return req.future.result()

    def _barrier_ready_locked(self) -> bool:
        """``wait_for`` predicate for the loop thread (call under _cv).
        True when the loop has something to do: shut down, or dispatch a
        full barrier tick.  While idle the thread blocks in ``_cv.wait``
        inside ``wait_for`` — it burns no CPU and ticks no dispatch work
        until a submit/park/close notifies the condition."""
        if self._closed and not self._running and not self._deferred:
            return True
        if (self._hold == 0 and self._running
                and all(t.pending for t in self._running)):
            return True
        if not self._running and not self._deferred:
            self.idle.set()
        return False

    def _loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(self._barrier_ready_locked)
                if (self._closed and not self._running
                        and not self._deferred):
                    return
                self.stats.n_dispatch_ticks += 1
                batch: List[_OracleRequest] = []
                for t in sorted(self._running, key=lambda t: t.index):
                    while t.pending:
                        batch.append(t.pending.popleft())
            # evaluate OUTSIDE the lock: split the tick into up to
            # pipeline_depth task-ordered waves, each ONE packed dispatch
            # on the FIFO lane — oracles sharing an engine contribute all
            # their prompts to a single bucketed first_token_logits call
            # per wave, and wave k+1's prefill overlaps the voting wave k
            # unparked (see _run_wave)
            n_waves = max(1, min(self.pipeline_depth, len(batch)))
            bounds = np.linspace(0, len(batch), n_waves + 1).astype(int)
            for w in range(n_waves):
                wave = batch[bounds[w]:bounds[w + 1]]
                if wave:
                    self._dispatcher.submit_call(self._run_wave, wave)

    def _run_wave(self, wave: List[_OracleRequest]) -> None:
        """Evaluate one packed wave on the dispatcher lane and unpark its
        requesters.  Runs strictly FIFO relative to other waves, so
        per-oracle evaluation order stays exactly submission order."""
        tr = get_tracer()
        t0 = monotonic()
        # the wave runs on the lane thread; parent it to the first
        # requester's captured span (the cross-thread edge) and list every
        # member request's span id so all requesters stay correlated
        with tr.span("dispatch_wave", kind="dispatch_wave",
                     parent=wave[0].span,
                     n_requests=len(wave),
                     n_ids=int(sum(len(r.ids) for r in wave)),
                     tasks=[r.task.label for r in wave],
                     request_spans=[getattr(r.span, "span_id", None)
                                    for r in wave]) as sp:
            try:
                outcomes, info = evaluate_packed(
                    [(r.oracle, r.ids) for r in wave], pack=self.pack)
            except BaseException as e:  # defensive: never strand a waiter
                outcomes, info = [e] * len(wave), {"tokens": 0,
                                                   "truncated": 0}
            sp.set(tokens=info["tokens"], truncated=info["truncated"])
        wall = monotonic() - t0
        self.stats.merge.record([len(r.ids) for r in wave],
                                wall_s=wall,
                                tokens=info["tokens"],
                                truncated=info["truncated"])
        tr.metrics.inc("service.ticks")
        tr.metrics.observe("service.wave_wall_s", wall)
        tr.metrics.set("service.batch_fill", self.stats.merge.merge_factor)
        # the dispatch tick is the service's natural heartbeat: evaluate
        # health rules here (rate-limited inside; no-op null default)
        get_monitor().maybe_evaluate()
        for r, out in zip(wave, outcomes):
            if isinstance(out, BaseException):
                r.future.set_exception(out)
            else:
                r.future.set_result(out)

    # ------------------------------------------------------------- status
    def status_view(self) -> dict:
        """statusz section: in-flight work and lifetime tick counters."""
        with self._cv:
            in_flight = len(self._running)
            deferred = len(self._deferred)
        return {
            "in_flight": in_flight,
            "deferred": deferred,
            "idle": self.idle.is_set(),
            "submitted": self.stats.n_submitted,
            "completed": self.stats.n_completed,
            "failed": self.stats.n_failed,
            "dispatch_ticks": self.stats.n_dispatch_ticks,
            "mean_batch_size": self.stats.merge.mean_batch_size,
            "merge_factor": self.stats.merge.merge_factor,
        }

    # ------------------------------------------------------------ control
    @contextlib.contextmanager
    def holding(self):
        """Pause dispatch while submitting a burst, so even first-round
        batches merge across the whole burst (deterministic merge sizes)."""
        with self._cv:
            self._hold += 1
        try:
            yield self
        finally:
            with self._cv:
                self._hold = max(0, self._hold - 1)
                self._cv.notify_all()

    def _discard(self, ticket: QueryTicket) -> None:
        """Drop a consumed ticket from the bookkeeping — a long-lived
        service must not retain every ticket (and its result mask) ever
        served."""
        with self._cv:
            ticket._gathered = True
            self._tickets = [t for t in self._tickets if t is not ticket]

    def take_outstanding(self, *tickets) -> List[QueryTicket]:
        """Claim tickets for gathering: select the given tickets (or every
        not-yet-gathered one), mark them gathered, and drop them from the
        scheduler's bookkeeping.  Raises — instead of claiming and then
        deadlocking — when dispatch is held and a selected ticket is still
        in flight; NOT releasing the hold here is deliberate: another
        thread may be mid-``holding()`` building its own burst, and its
        merge guarantee must survive a concurrent gather."""
        with self._cv:
            targets = list(tickets) if tickets else [
                t for t in self._tickets if not t._gathered]
            if self._hold > 0 and any(not t.done() for t in targets):
                raise RuntimeError(
                    "gather() inside scheduler.holding() would wait "
                    "forever (dispatch is paused); exit the holding() "
                    "block first")
            for tk in targets:
                tk._gathered = True
            self._tickets = [t for t in self._tickets if not t._gathered]
        return targets

    def gather(self, *tickets):
        """Wait for the given tickets (all outstanding ones when called
        with no arguments) and return their results in order."""
        return [tk.result() for tk in self.take_outstanding(*tickets)]

    def close(self) -> None:
        """Drain in-flight tasks and stop the scheduler threads."""
        with self._cv:
            self._closed = True
            self._hold = 0
            self._cv.notify_all()
        self._loop_thread.join()
        self._dispatcher.close()
