"""repro.service — concurrent, restartable semantic-filter serving.

Three layers over the lazy ``repro.api`` surface (docs/service.md):

- ``QueryScheduler`` (scheduler.py): drives many submitted queries
  concurrently and merges their per-round oracle batches into cross-query
  dispatches — mean batch size grows with concurrency, per-query masks and
  call counts stay bit-identical to serial ``collect()``.
- ``SessionStore`` (store.py): session memo + caches to disk; a reloaded
  session replays previously-collected queries at zero oracle calls.
- ``SessionLogStore`` (log.py): the incremental alternative — every memo
  decision / cache insert / table mutation appends to a write-ahead log
  the moment it happens; restart = snapshot + log-tail replay
  (docs/distributed.md).
- ``FilterService`` (server.py): multi-tenant front end with aggregate
  ``max_oracle_calls`` admission control.

    from repro.service import FilterService
    svc = FilterService(session, store_dir=".../state")
    svc.register_tenant("t0", ExecutionPolicy(max_oracle_calls=10_000))
    with session.scheduler.holding():
        tickets = [svc.submit("t0", q) for q in queries]
    results = svc.gather(*tickets)
"""
from repro.service.log import (ConcurrentWriterError, LogRestoreReport,
                               SessionLogStore)
from repro.service.scheduler import (BatchingOracleProxy, QueryScheduler,
                                     QueryTicket, ServiceStats)
from repro.service.server import (FilterService, TenantAccount,
                                  TenantBudgetError)
from repro.service.store import RestoreReport, SessionStore, STORE_SCHEMA

__all__ = [
    "BatchingOracleProxy", "QueryScheduler", "QueryTicket", "ServiceStats",
    "FilterService", "TenantAccount", "TenantBudgetError",
    "RestoreReport", "SessionStore", "STORE_SCHEMA",
    "ConcurrentWriterError", "LogRestoreReport", "SessionLogStore",
]
