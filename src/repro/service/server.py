"""Service front end: tenant admission control over the query scheduler.

``FilterService`` is the deployable face of one session: it owns the
scheduler, a ``SessionStore`` for checkpoint/restore, and per-tenant
oracle budgets.  A tenant registers with an ``ExecutionPolicy`` whose
``max_oracle_calls`` is read as the tenant's AGGREGATE budget: every
submission's closed-form worst-case estimate (``Query.worst_case_calls``,
zero oracle calls to compute, memo-aware — replayable queries reserve ~0)
is reserved against it, and ``gather`` settles reservations to actual
spend.  A submission whose reservation would overflow the remaining
budget is rejected up front with ``TenantBudgetError`` — no partial
execution, no oracle calls.  The per-query ``max_oracle_calls`` pre-flight
inside ``collect()`` still applies on top (a single runaway query is
rejected even under an ample tenant budget).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from repro.api.policy import ExecutionPolicy, OracleBudgetError
from repro.obs.trace import get_tracer
from repro.service.scheduler import QueryTicket
from repro.service.store import RestoreReport, SessionStore


class TenantBudgetError(OracleBudgetError):
    """A submission's worst-case estimate overflows the tenant's
    aggregate ``max_oracle_calls`` budget."""


@dataclasses.dataclass
class TenantAccount:
    """Aggregate oracle accounting for one tenant."""
    name: str
    policy: ExecutionPolicy
    reserved: float = 0.0      # worst-case estimates of in-flight queries
    spent: int = 0             # actual calls of settled queries
    n_admitted: int = 0
    n_rejected: int = 0

    @property
    def budget(self) -> Optional[int]:
        return self.policy.max_oracle_calls

    @property
    def remaining(self) -> Optional[float]:
        if self.budget is None:
            return None
        return self.budget - self.spent - self.reserved


class FilterService:
    """Concurrent multi-tenant semantic-filter service over one Session.

        service = FilterService(session, store_dir="/var/lib/csv")
        service.register_tenant("alice", ExecutionPolicy(
            n_clusters=4, max_oracle_calls=10_000))
        t1 = service.submit("alice", table.filter("positive"))
        t2 = service.submit("alice", table.filter("spam") & ...)
        r1, r2 = service.gather(t1, t2)   # settles alice's budget
        service.checkpoint()              # restartable: see store.py
    """

    def __init__(self, session, store_dir=None, log_dir=None):
        if store_dir is not None and log_dir is not None:
            raise ValueError("pass store_dir (whole-session snapshots) OR "
                             "log_dir (append-only log), not both")
        if log_dir is None:
            log_dir = session.policy.log_dir
        self.session = session
        self.store = SessionStore(store_dir) if store_dir is not None \
            else None
        self.log = None
        if log_dir is not None:
            from repro.service.log import SessionLogStore
            self.log = SessionLogStore(
                log_dir,
                compact_bytes=session.policy.log_compact_bytes,
                compact_records=session.policy.log_compact_records)
            if not self.log.exists():
                # fresh directory: start recording now; with prior state
                # the caller decides when to restore() (it must register
                # tables/oracles first), and restore() attaches after
                self.log.attach(session)
        self._tenants: Dict[str, TenantAccount] = {}
        # idempotent settlement closures of in-flight tickets, by index;
        # each removes itself once run (done-callback or gather)
        self._settlers: Dict[int, object] = {}
        # admission is check-then-reserve: concurrent submits/settlements
        # for one tenant must serialize or both could fit a budget that
        # only holds one of them
        self._lock = threading.Lock()

    @property
    def scheduler(self):
        # read through the session every time: Session.close() retires its
        # scheduler and a later submit builds a fresh one — a cached
        # reference would keep pointing at the closed instance
        return self.session.scheduler

    # ------------------------------------------------------------- tenants
    def register_tenant(self, name: str,
                        policy: Optional[ExecutionPolicy] = None
                        ) -> TenantAccount:
        """Admit a tenant.  ``policy`` is its default execution policy AND
        its budget: ``policy.max_oracle_calls`` caps the tenant's aggregate
        reserved+spent oracle calls (None = unmetered)."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        acct = TenantAccount(name=name,
                             policy=policy or self.session.policy)
        self._tenants[name] = acct
        return acct

    def tenant(self, name: str) -> TenantAccount:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r}; register_tenant() "
                           "first") from None

    # ------------------------------------------------------------- queries
    def submit(self, tenant: str, query,
               policy: Optional[ExecutionPolicy] = None,
               label: Optional[str] = None) -> QueryTicket:
        """Admission-checked submit.  Resolution order for the effective
        policy: explicit ``policy`` > the query's own > the tenant's."""
        acct = self.tenant(tenant)
        pol = policy or getattr(query, "policy", None) or acct.policy
        est = query.worst_case_calls(pol)
        with self._lock:
            if acct.budget is not None and \
                    acct.spent + acct.reserved + est > acct.budget:
                acct.n_rejected += 1
                raise TenantBudgetError(
                    f"tenant {tenant!r}: worst-case {est:.0f} calls do not "
                    f"fit the remaining budget ({acct.remaining:.0f} of "
                    f"{acct.budget}; {acct.spent} spent, "
                    f"{acct.reserved:.0f} reserved)")
            acct.reserved += est
            acct.n_admitted += 1
            self._export_budget_gauge_locked()
        try:
            ticket = self.scheduler.submit(query, policy=pol,
                                           label=label or f"{tenant}/q")
        except BaseException:
            with self._lock:   # submission failed: hand the budget back
                acct.reserved = max(0.0, acct.reserved - est)
                acct.n_admitted -= 1
            raise

        settled = [False]

        def _settle(future):
            # settlement rides on query COMPLETION, not on gather(): a
            # client consuming the ticket via result() must still free the
            # reservation, or the tenant's budget leaks.  Idempotent —
            # gather() also invokes it synchronously so budgets are
            # settled the moment gather returns (done-callbacks race the
            # woken waiter).  Failed queries settle at zero spend.
            with self._lock:
                self._settlers.pop(ticket.index, None)
                if settled[0]:
                    return
                settled[0] = True
                acct.reserved = max(0.0, acct.reserved - est)
                if future.exception() is None:
                    acct.spent += int(future.result().n_llm_calls)
                self._export_budget_gauge_locked()
        with self._lock:
            self._settlers[ticket.index] = _settle
        ticket.add_done_callback(_settle)
        return ticket

    def _export_budget_gauge_locked(self) -> None:
        """Export the worst (max) tenant budget-burn ratio as a gauge so
        the health monitor's ``tenant-budget-burn`` rule can alert before
        admissions start bouncing.  No-op under the null registry."""
        used = [
            (acct.spent + acct.reserved) / acct.budget
            for acct in self._tenants.values()
            if acct.budget is not None and acct.budget > 0
        ]
        if used:
            get_tracer().metrics.set("service.tenant_budget_used_ratio",
                                     max(used))

    def status_view(self) -> Dict[str, dict]:
        """statusz section: per-tenant budgets and admission counters."""
        with self._lock:
            tenants = {
                name: {
                    "budget": acct.budget,
                    "spent": acct.spent,
                    "reserved": acct.reserved,
                    "remaining": acct.remaining,
                    "admitted": acct.n_admitted,
                    "rejected": acct.n_rejected,
                }
                for name, acct in self._tenants.items()
            }
        return tenants

    def gather(self, *tickets) -> List:
        """Wait for tickets (all outstanding when none given).  Budget
        settlement happens when each query finishes (also when a client
        consumes a ticket via ``result()`` directly); the first failure
        re-raises after every ticket is collected."""
        results, first_error = [], None
        for tk in self.scheduler.take_outstanding(*tickets):
            try:
                res = tk.result()
            except BaseException as e:
                res = None
                if first_error is None:
                    first_error = e
            with self._lock:
                settle = self._settlers.get(tk.index)
            if settle is not None:
                settle(tk.future)
            results.append(res)
        if first_error is not None:
            raise first_error
        if self.log is not None and self.log.attached:
            # gather's return is a quiescent point for the gathered work:
            # fold the log tail into a snapshot when thresholds say so
            self.log.compact_if_due(self.session)
        return results

    # --------------------------------------------------------- persistence
    def checkpoint(self, tag: str = "session"):
        """Snapshot mode: write a whole-session snapshot.  Log mode: fold
        the log tail into a fresh snapshot (compaction) — continuous
        durability means there is nothing else to flush."""
        if self.log is not None:
            self.log.compact(self.session)
            return self.log.dir
        if self.store is None:
            raise ValueError("FilterService built without store_dir or "
                             "log_dir")
        return self.store.save(self.session, tag)

    def restore(self, tag: str = "session", strict: bool = False):
        """Rebuild session state.  Snapshot mode returns a
        ``RestoreReport``; log mode replays snapshot + log tail, starts
        recording, and returns a ``LogRestoreReport``.  Either way the
        session's tables and oracles must be registered first."""
        if self.log is not None:
            rep = None
            if not self.log.attached:
                if self.log.exists():
                    rep = self.log.restore(self.session, strict=strict)
                self.log.attach(self.session)
            return rep
        if self.store is None:
            raise ValueError("FilterService built without store_dir or "
                             "log_dir")
        return self.store.load(self.session, tag, strict=strict)

    def close(self) -> None:
        self.session.close()
        if self.log is not None:
            self.log.close()
