"""Graceful shutdown for long-running entry points (serve.py, watch.py).

A ``GracefulShutdown`` installs SIGINT/SIGTERM handlers that run a set of
registered cleanup callbacks exactly once — a final ``SessionStore``
checkpoint, a sink flush — before the process exits, so killing a service
or a stream watcher never loses acknowledged state.  Two consumption
modes:

- **exit mode** (``exit_on_signal=True``, the serve.py default): the
  handler runs the callbacks and raises ``SystemExit(128 + signum)`` —
  the conventional fatal-signal exit code — from wherever the main thread
  happened to be.
- **flag mode** (``exit_on_signal=False``, the watch.py default): the
  handler runs the callbacks and sets ``requested``; a tick loop checks
  ``requested`` between ticks and winds down at a tick boundary, so the
  checkpoint it wrote is never followed by a half-applied tick.

Cleanup callbacks run in registration order and are idempotent at the
manager level: however many signals arrive (or whether ``close()`` also
runs at normal exit), each callback fires once.  A failing callback is
logged to stderr and does not block the remaining ones — shutdown must
make progress even when a sink is wedged.

Tests drive the handler in-process (``trigger()``) instead of delivering
real signals; see tests/test_stream.py.
"""
from __future__ import annotations

import signal
import sys
import threading
from typing import Callable, List, Optional


class GracefulShutdown:
    """Run registered cleanups once on SIGINT/SIGTERM (or ``close()``)."""

    SIGNALS = (signal.SIGINT, signal.SIGTERM)

    def __init__(self, exit_on_signal: bool = True):
        self.exit_on_signal = exit_on_signal
        self.requested = False          # flag-mode loops poll this
        self.signum: Optional[int] = None
        self._callbacks: List[tuple] = []   # (label, fn), fire-once order
        self._done = set()                  # labels already fired
        self._lock = threading.Lock()
        self._previous: dict = {}
        self._installed = False

    # ------------------------------------------------------------ wiring
    def register(self, label: str, fn: Callable[[], None]) -> None:
        """Add a cleanup; ``label`` names it in error output and keys the
        fire-once bookkeeping (re-registering a label replaces the fn)."""
        with self._lock:
            self._callbacks = [(lb, f) for lb, f in self._callbacks
                               if lb != label]
            self._callbacks.append((label, fn))
            self._done.discard(label)

    def install(self) -> "GracefulShutdown":
        """Install the signal handlers (main thread only — Python delivers
        signals there).  Previous handlers are saved and restored by
        ``close()``.  Off the main thread (a test driving the entry point
        in-process) installation is skipped: ``trigger()`` still works."""
        if threading.current_thread() is threading.main_thread():
            for sig in self.SIGNALS:
                self._previous[sig] = signal.signal(sig, self._handler)
            self._installed = True
        return self

    # ---------------------------------------------------------- shutdown
    def _handler(self, signum, frame) -> None:
        self.trigger(signum)
        if self.exit_on_signal:
            raise SystemExit(128 + signum)

    def trigger(self, signum: int = signal.SIGTERM) -> None:
        """The handler body, callable in-process (tests, supervisors):
        mark shutdown requested and run the cleanups once."""
        self.signum = signum
        self.requested = True
        self._run_callbacks()

    def _run_callbacks(self) -> None:
        with self._lock:
            todo = [(lb, f) for lb, f in self._callbacks
                    if lb not in self._done]
            self._done.update(lb for lb, _ in todo)
        for label, fn in todo:
            try:
                fn()
            except BaseException as e:   # keep shutting down regardless
                print(f"[shutdown] cleanup {label!r} failed: "
                      f"{type(e).__name__}: {e}", file=sys.stderr)

    def close(self) -> None:
        """Normal-exit path: run any cleanups that have not fired yet and
        restore the previous signal handlers."""
        self._run_callbacks()
        if self._installed:
            for sig, prev in self._previous.items():
                signal.signal(sig, prev)
            self._installed = False

    def __enter__(self) -> "GracefulShutdown":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.close()
