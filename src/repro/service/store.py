"""Disk-persistent restartable sessions: SessionMemo + caches to disk.

A ``Session`` is an optimization scope whose value is the observations it
has accumulated (docs/caching.md): full-table decision masks, pilot
probes, observed selectivities, join pair decisions, the content-hash
embedding cache, the precluster assignments (+ centroids, for post-reload
incremental mutations) and the per-cluster dirty versions.  ``SessionStore``
serializes exactly that state through ``repro.checkpoint.manager`` (msgpack
shards + manifest, atomic rename, zstd/zlib codec) so a new process can
rebuild the session and **replay every previously-collected query at zero
oracle calls, bit-identically** — and, after a post-reload ``append()``/
``update()``, re-vote only the dirty clusters, exactly as an unrestarted
session would.

Identity across processes: in-memory memo keys use ``id(oracle)``; on disk
they use the session's **registered oracle names** (``register_oracle``).
Entries whose oracle was never registered cannot be named durably and are
skipped (reported).  On load, names rebind to the current process's
registered oracle objects.

Versioned invalidation (mirrors the in-memory rules):
- a schema bump invalidates the whole store (clear error, no best-effort);
- each table carries a content fingerprint (texts if present, else
  embedding bytes); a mismatch — the caller rebuilt different data —
  drops every entry touching that table;
- decision/pilot/selectivity entries keep their recorded table versions,
  and handles are restored AT their saved version, so the normal
  dirty-cluster arithmetic applies unchanged after reload.

Per-id oracle memos of registered oracles ride along (the restartable-
driver cache of ``launch/serve.py``, now session-scoped).  Note the flip
RNG of a stochastic oracle is NOT state that can be restored — replays are
bit-identical regardless (no oracle involved), but post-reload *fresh*
evaluation of a ``flip_prob > 0`` oracle agrees with the unrestarted run
only in expectation (same caveat as docs/caching.md).
"""
from __future__ import annotations

import dataclasses
import hashlib
import pathlib
from typing import Dict, List

import numpy as np

from repro.api.memo import (DecisionMemo, JoinDecisionMemo, SelObservation,
                            oracle_identity)
from repro.checkpoint.manager import load_pytree, save_pytree
from repro.obs.trace import get_tracer
from repro.plan.cost import PredStats

STORE_SCHEMA = 1


def table_fingerprint(handle, require_embeddings: bool = False) -> dict:
    """Content hashes of a table's payload, per component:
    ``{"texts": hex | None, "emb": hex | None}``.

    BOTH components are hashed when available — same texts re-embedded by
    a different encoder are different data, and restoring precluster
    state computed in a foreign embedding space would silently corrupt
    dirty-cluster re-votes.  At save time a still-lazy embedding is
    simply absent from the fingerprint; at load time
    ``require_embeddings=True`` (the save hashed them) materializes the
    embeddings — cheap when the store's embedding-cache rows were
    restored first."""
    t = handle._table
    out = {"texts": None, "emb": None}
    if t.texts is not None:
        h = hashlib.blake2b(digest_size=16)
        h.update(f"texts:{len(t.texts)}".encode())
        for s in t.texts:
            h.update(s.encode("utf-8"))
            h.update(b"\x00")
        out["texts"] = h.hexdigest()
    emb = t.embeddings if require_embeddings else t._embeddings
    if emb is not None:
        emb = np.ascontiguousarray(emb, dtype=np.float32)
        h = hashlib.blake2b(digest_size=16)
        h.update(f"emb:{emb.shape}".encode())
        h.update(emb.tobytes())
        out["emb"] = h.hexdigest()
    return out


def _fingerprint_matches(saved: dict, handle) -> bool:
    """Every component the save hashed must match the rebuilt table."""
    cur = table_fingerprint(handle,
                            require_embeddings=saved.get("emb") is not None)
    return all(saved[part] == cur[part]
               for part in ("texts", "emb") if saved.get(part) is not None)


@dataclasses.dataclass
class RestoreReport:
    """What a ``SessionStore.load`` actually rebound.

    ``skipped`` lists entries present in the store that could not be
    rebound onto THIS session (unregistered table/oracle, changed
    content).  ``dropped`` lists entries the SAVE already left out
    (e.g. decisions of an oracle that was never registered under a
    durable name) — previously recorded in the manifest but silently
    discarded at load; warm-start paths surface them so a quiet
    "restored N masks" doesn't hide state that never made it to disk.
    """
    tables: List[str] = dataclasses.field(default_factory=list)
    n_decisions: int = 0
    n_selectivities: int = 0
    n_pilots: int = 0
    n_joins: int = 0
    n_embedding_rows: int = 0
    n_oracle_memo_entries: int = 0
    skipped: List[str] = dataclasses.field(default_factory=list)
    dropped: List[str] = dataclasses.field(default_factory=list)

    def __str__(self) -> str:
        s = (f"restored {len(self.tables)} table(s), "
             f"{self.n_decisions} decision mask(s), "
             f"{self.n_joins} join mask(s), {self.n_pilots} pilot(s), "
             f"{self.n_selectivities} selectivity(ies), "
             f"{self.n_embedding_rows} embedding row(s), "
             f"{self.n_oracle_memo_entries} oracle memo entry(ies)")
        if self.skipped:
            s += f"; skipped: {'; '.join(self.skipped)}"
        if self.dropped:
            s += (f"; {len(self.dropped)} entry(ies) dropped at save: "
                  f"{'; '.join(self.dropped)}")
        return s


class SessionStore:
    """Save/load one session's reusable state under a directory."""

    def __init__(self, directory):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    def path(self, tag: str = "session") -> pathlib.Path:
        return self.dir / tag

    def exists(self, tag: str = "session") -> bool:
        return (self.path(tag) / "MANIFEST.json").exists()

    # ----------------------------------------------------------------- save
    def save(self, session, tag: str = "session") -> pathlib.Path:
        memo = session.memo
        arrays: Dict[str, np.ndarray] = {}
        # reverse map: durable names for oracles with stored entries
        name_of = {id(oracle_identity(o)): name
                   for name, (o, _proxy) in session._oracles.items()}

        tables: Dict[str, dict] = {}
        for tname, handle in session._tables.items():
            cluster_keys = []
            for (name, k, seed), assign in session._assign_cache.items():
                if name != tname:
                    continue
                cached = handle._table._assign_cache.get((k, seed))
                cents = cached[1] if cached is not None else np.zeros(
                    (0, 0), np.float32)
                dirty = handle._dirty.get(
                    (k, seed), np.full(k, handle.version, dtype=np.int64))
                arrays[f"table/{tname}/assign/{k}_{seed}"] = assign
                arrays[f"table/{tname}/centroids/{k}_{seed}"] = cents
                arrays[f"table/{tname}/dirty/{k}_{seed}"] = dirty
                cluster_keys.append([int(k), int(seed)])
            tables[tname] = {"version": int(handle.version),
                             "n": int(len(handle)),
                             "fingerprint": table_fingerprint(handle),
                             "cluster_keys": cluster_keys}

        decisions, dropped = [], []
        for (tname, oid, fp), dm in memo._decisions.items():
            oname = name_of.get(oid)
            if oname is None or tname not in tables:
                dropped.append(f"decision on {tname!r} (unregistered oracle)")
                continue
            arrays[f"dec/{len(decisions)}/mask"] = dm.mask
            decisions.append({"table": tname, "oracle": oname,
                              "version": int(dm.version), "n": int(dm.n),
                              "cluster_key": list(dm.cluster_key),
                              "fingerprint": list(fp)})
        selectivities = []
        for (tname, oid), obs in memo._selectivity.items():
            oname = name_of.get(oid)
            if oname is None or tname not in tables:
                continue
            selectivities.append({
                "table": tname, "oracle": oname,
                "version": int(obs.version),
                "selectivity": float(obs.selectivity),
                "tokens_per_call": float(obs.tokens_per_call)})
        pilots = []
        for (tname, oid, version, seed, pilot_size), ps in \
                memo._pilots.items():
            oname = name_of.get(oid)
            if oname is None or tname not in tables:
                continue
            pilots.append({"table": tname, "oracle": oname,
                           "version": int(version), "seed": int(seed),
                           "pilot_size": int(pilot_size),
                           "stats": dataclasses.asdict(ps)})
        joins = []
        for (lname, rname, oid, fp), jm in memo._join_decisions.items():
            oname = name_of.get(oid)
            if oname is None or lname not in tables or rname not in tables:
                dropped.append(f"join {lname!r} x {rname!r} "
                               "(unregistered oracle)")
                continue
            arrays[f"join/{len(joins)}/mask"] = jm.pair_mask
            joins.append({"left": lname, "right": rname, "oracle": oname,
                          "left_version": int(jm.left_version),
                          "right_version": int(jm.right_version),
                          "fingerprint": list(fp)})

        emb_groups: Dict[str, List[str]] = {}
        by_dim: Dict[int, List[str]] = {}
        for key, row in session.embedding_cache._store.items():
            by_dim.setdefault(int(np.asarray(row).shape[0]), []).append(key)
        for g, (dim, keys) in enumerate(sorted(by_dim.items())):
            arrays[f"emb/{g}/rows"] = np.stack(
                [session.embedding_cache._store[k] for k in keys])
            emb_groups[str(g)] = keys

        oracle_memos = []
        for name, (oracle, _proxy) in session._oracles.items():
            target = oracle_identity(oracle)
            snap = (target.memo_snapshot()
                    if hasattr(target, "memo_snapshot") else {})
            if not snap:
                continue
            ids = np.fromiter(snap.keys(), dtype=np.int64, count=len(snap))
            vals = np.fromiter((snap[int(i)] for i in ids), dtype=bool,
                               count=len(snap))
            arrays[f"omemo/{name}/ids"] = ids
            arrays[f"omemo/{name}/vals"] = vals
            oracle_memos.append({"oracle": name, "n": int(len(ids))})

        meta = {"store_schema": STORE_SCHEMA, "tables": tables,
                "decisions": decisions, "selectivities": selectivities,
                "pilots": pilots, "joins": joins, "emb_groups": emb_groups,
                "oracle_memos": oracle_memos, "dropped": dropped}
        save_pytree(arrays, self.path(tag), extra_meta=meta)
        return self.path(tag)

    # ----------------------------------------------------------------- load
    def load(self, session, tag: str = "session",
             strict: bool = False) -> RestoreReport:
        """Rebind saved state onto ``session`` (tables and oracles already
        registered under their original names).  Entries whose table
        fingerprint or oracle name no longer resolves are skipped — or, in
        ``strict`` mode, raise."""
        by_key, meta = load_pytree(self.path(tag))
        if meta.get("store_schema") != STORE_SCHEMA:
            raise ValueError(
                f"session store schema {meta.get('store_schema')!r} does "
                f"not match this build ({STORE_SCHEMA}); re-save the "
                "session (stale stores are invalidated, not migrated)")
        rep = RestoreReport(dropped=list(meta.get("dropped", [])))
        memo = session.memo

        def _skip(msg: str):
            if strict:
                raise ValueError(f"session store mismatch: {msg}")
            rep.skipped.append(msg)

        # embedding cache FIRST: the fingerprint check below may have to
        # materialize a lazy table's embeddings, which should come from
        # the restored cache rows, not a fresh encoder pass
        for g, keys in meta["emb_groups"].items():
            rows = by_key[f"emb/{g}/rows"]
            for r, key in enumerate(keys):
                session.embedding_cache._store[key] = np.array(
                    rows[r], dtype=np.float32)
            rep.n_embedding_rows += len(keys)

        restored_tables = set()
        for tname, tinfo in meta["tables"].items():
            handle = session._tables.get(tname)
            if handle is None:
                _skip(f"table {tname!r} not registered")
                continue
            if len(handle) != tinfo["n"]:
                _skip(f"table {tname!r} has {len(handle)} rows, "
                      f"store expects {tinfo['n']}")
                continue
            if not _fingerprint_matches(tinfo["fingerprint"], handle):
                _skip(f"table {tname!r} content changed since the save")
                continue
            handle.version = int(tinfo["version"])
            for k, seed in tinfo["cluster_keys"]:
                assign = np.array(by_key[f"table/{tname}/assign/{k}_{seed}"])
                cents = np.array(
                    by_key[f"table/{tname}/centroids/{k}_{seed}"])
                dirty = np.array(by_key[f"table/{tname}/dirty/{k}_{seed}"],
                                 dtype=np.int64)
                session._assign_cache[(tname, int(k), int(seed))] = assign
                handle._dirty[(int(k), int(seed))] = dirty
                if cents.size:
                    handle._table._assign_cache[(int(k), int(seed))] = (
                        assign, cents)
            restored_tables.add(tname)
            rep.tables.append(tname)

        def _oracle(name: str):
            entry = session._oracles.get(name)
            if entry is None:
                _skip(f"oracle {name!r} not registered")
                return None
            ident = oracle_identity(entry[0])
            memo._oracles[id(ident)] = ident
            return ident

        for i, d in enumerate(meta["decisions"]):
            if d["table"] not in restored_tables:
                continue
            ident = _oracle(d["oracle"])
            if ident is None:
                continue
            fp = tuple(d["fingerprint"])
            memo._decisions[(d["table"], id(ident), fp)] = DecisionMemo(
                version=d["version"], n=d["n"],
                mask=np.array(by_key[f"dec/{i}/mask"], dtype=bool),
                cluster_key=tuple(d["cluster_key"]), fingerprint=fp)
            memo.note_sighting(d["table"], ident)
            rep.n_decisions += 1
        for s in meta["selectivities"]:
            if s["table"] not in restored_tables:
                continue
            ident = _oracle(s["oracle"])
            if ident is None:
                continue
            memo._selectivity[(s["table"], id(ident))] = SelObservation(
                version=s["version"], selectivity=s["selectivity"],
                tokens_per_call=s["tokens_per_call"])
            rep.n_selectivities += 1
        for p in meta["pilots"]:
            if p["table"] not in restored_tables:
                continue
            ident = _oracle(p["oracle"])
            if ident is None:
                continue
            memo._pilots[(p["table"], id(ident), p["version"], p["seed"],
                          p["pilot_size"])] = PredStats(**p["stats"])
            rep.n_pilots += 1
        for i, j in enumerate(meta["joins"]):
            if (j["left"] not in restored_tables
                    or j["right"] not in restored_tables):
                continue
            ident = _oracle(j["oracle"])
            if ident is None:
                continue
            fp = tuple(j["fingerprint"])
            memo._join_decisions[(j["left"], j["right"], id(ident), fp)] = \
                JoinDecisionMemo(
                    left_version=j["left_version"],
                    right_version=j["right_version"],
                    pair_mask=np.array(by_key[f"join/{i}/mask"], dtype=bool),
                    fingerprint=fp)
            memo.note_pair_oracle(j["left"], ident)
            memo.note_pair_oracle(j["right"], ident)
            rep.n_joins += 1

        for om in meta["oracle_memos"]:
            ident = _oracle(om["oracle"])
            if ident is None or not hasattr(ident, "memo_restore"):
                continue
            ids = by_key[f"omemo/{om['oracle']}/ids"]
            vals = by_key[f"omemo/{om['oracle']}/vals"]
            ident.memo_restore({int(i): bool(v)
                                for i, v in zip(ids, vals)})
            rep.n_oracle_memo_entries += len(ids)
        if rep.dropped or rep.skipped:
            get_tracer().metrics.inc("store.restore_dropped",
                                     len(rep.dropped) + len(rep.skipped))
        return rep
