"""Incremental append-only session log: continuous checkpointing.

``SessionStore`` (store.py) serializes a whole session at once — correct,
but stop-the-world: a busy multi-tenant service pays the full session
size at every checkpoint.  ``SessionLogStore`` replaces that with a
write-ahead log: **every memo decision, observed selectivity, pilot
probe, join mask, embedding-cache insert, oracle memo commit, precluster
fit, and table append/update becomes one framed record** appended (and
flushed) the moment it happens.  A checkpoint is just a log offset;
restart = snapshot-load + log-tail replay, so restart time is bounded by
the tail length, not the session size.

Frame format (little-endian), after an 8-byte file magic::

    <u32 payload length> <u32 crc32(payload)> <payload: msgpack map>

Numpy arrays travel as ``{"__nd__": dtype, shape, bytes}`` inside the
msgpack payload.  A torn final frame (crash mid-write) is detected by
length/crc and **truncated away on the next attach** — everything before
it replays normally.  A ``wal.lock`` file (O_CREAT|O_EXCL, pid inside)
rejects concurrent writers; a lock whose pid is dead is stolen.

Generations and compaction
--------------------------
Log files are ``wal_<gen>.log``.  ``compact()`` (a) opens generation
g+1 and re-writes the accumulated **table-mutation records** at its head
— the snapshot stores table *fingerprints*, not rows, so the mutations
that produced the fingerprinted content must stay replayable from the
base table the caller rebuilds — then (b) saves a standard
``SessionStore`` snapshot, (c) atomically commits ``CHECKPOINT.json``
pointing at ``(g+1, snapshot_offset)``, and (d) deletes older
generations.  A crash between any two steps leaves the previous
checkpoint fully usable.  ``restore()`` therefore replays:

    carried mutations (head of gen file) -> snapshot -> tail records

and the in-flight tail is exactly the work since the last compaction.

See docs/distributed.md; edge cases are covered in
tests/test_session_log.py.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import struct
import threading
import zlib
from typing import Dict, List, Optional

import msgpack
import numpy as np

from repro.api.memo import (DecisionMemo, JoinDecisionMemo, SelObservation,
                            oracle_identity)
from repro.obs.trace import get_tracer
from repro.plan.cost import PredStats
from repro.service.store import RestoreReport, SessionStore

LOG_MAGIC = b"CSVWAL1\n"
LOG_SCHEMA = 1
_FRAME = struct.Struct("<II")


class ConcurrentWriterError(RuntimeError):
    """A second live writer tried to attach to the same log directory."""


class LogCorruptionError(RuntimeError):
    """The log failed structural validation beyond a recoverable tail."""


# ------------------------------------------------------------ array codec
def _enc(obj):
    if isinstance(obj, np.ndarray):
        arr = np.ascontiguousarray(obj)
        return {"__nd__": arr.dtype.str, "s": list(arr.shape),
                "b": arr.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot log object of type {type(obj).__name__}")


def _dec(obj):
    if "__nd__" in obj:
        return np.frombuffer(obj["b"], dtype=np.dtype(obj["__nd__"])
                             ).reshape(obj["s"]).copy()
    return obj


def pack_record(payload: dict) -> bytes:
    """One framed record: length + crc32 header, msgpack body."""
    body = msgpack.packb(payload, use_bin_type=True, default=_enc)
    return _FRAME.pack(len(body), zlib.crc32(body)) + body


def read_records(path: pathlib.Path):
    """Scan one log file.  Returns ``(records, ends, valid_end, size)``
    where ``ends[i]`` is the file offset just past record ``i``.

    ``valid_end`` is the offset after the last intact frame; anything
    beyond it is a torn tail (crash mid-append) that ``LogWriter`` will
    truncate on the next attach.  A bad magic raises — that is not a torn
    tail but a file this code never wrote.
    """
    data = path.read_bytes()
    if len(data) < len(LOG_MAGIC) or data[:len(LOG_MAGIC)] != LOG_MAGIC:
        raise LogCorruptionError(f"{path} is not a session log "
                                 "(bad magic)")
    records: List[dict] = []
    ends: List[int] = []
    off = len(LOG_MAGIC)
    while off < len(data):
        if off + _FRAME.size > len(data):
            break  # torn header
        length, crc = _FRAME.unpack_from(data, off)
        body = data[off + _FRAME.size: off + _FRAME.size + length]
        if len(body) < length or zlib.crc32(body) != crc:
            break  # torn or corrupt frame: recovery truncates here
        records.append(msgpack.unpackb(body, raw=False, object_hook=_dec))
        off += _FRAME.size + length
        ends.append(off)
    return records, ends, off, len(data)


class LogWriter:
    """Append-only writer over one generation file (flush per record)."""

    def __init__(self, path: pathlib.Path, truncate_to: Optional[int] = None,
                 fresh: bool = False):
        self.path = path
        if fresh or not path.exists():
            path.write_bytes(LOG_MAGIC)
        elif truncate_to is not None and truncate_to < path.stat().st_size:
            with open(path, "r+b") as fh:
                fh.truncate(truncate_to)
        self._fh = open(path, "ab")

    @property
    def offset(self) -> int:
        return self._fh.tell()

    def append(self, payload: dict) -> int:
        """Write + flush one framed record; returns bytes written."""
        frame = pack_record(payload)
        self._fh.write(frame)
        self._fh.flush()
        return len(frame)

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()


@dataclasses.dataclass
class LogRestoreReport:
    """What a ``SessionLogStore.restore`` rebuilt, and from where."""
    snapshot: Optional[RestoreReport] = None  # compaction snapshot, if any
    n_carried_mutations: int = 0  # mutation records replayed pre-snapshot
    n_tail_records: int = 0       # records replayed after the snapshot
    torn_bytes: int = 0           # bytes dropped from a torn final frame
    skipped: List[str] = dataclasses.field(default_factory=list)

    @property
    def n_dropped(self) -> int:
        """Entries that could not be rebound (log skips + snapshot skips)."""
        snap = len(self.snapshot.skipped) if self.snapshot else 0
        return len(self.skipped) + snap

    def __str__(self) -> str:
        s = (f"log restore: {self.n_carried_mutations} carried mutation(s), "
             f"{'snapshot [' + str(self.snapshot) + '], ' if self.snapshot else 'no snapshot, '}"
             f"{self.n_tail_records} tail record(s)")
        if self.torn_bytes:
            s += f"; truncated {self.torn_bytes} torn byte(s)"
        if self.skipped:
            s += f"; skipped: {'; '.join(self.skipped)}"
        return s


_MUTATION_KINDS = ("append", "update")


class SessionLogStore:
    """Log-backed durability for one session (see module docstring).

    Lifecycle::

        store = SessionLogStore(log_dir)
        if store.exists():
            report = store.restore(session)   # snapshot + tail replay
        store.attach(session)                 # lock + start recording
        ...                                   # every event self-appends
        if store.compact_due:                 # thresholds crossed
            store.compact(session)            # at a quiescent point
        store.close()

    Recording hooks are installed on the session's memo, embedding cache,
    and registered oracles at ``attach`` and removed at ``close``; a
    session without an attached store pays a single ``is None`` check per
    event.  Appends are thread-safe (hooks fire from scheduler task
    threads and the dispatch lane).  ``compact()`` must run at a
    quiescent point — between ``gather()`` and the next ``submit()`` —
    because it snapshots live session state.
    """

    def __init__(self, directory, compact_bytes: int = 4 << 20,
                 compact_records: int = 10_000):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.compact_bytes = int(compact_bytes)
        self.compact_records = int(compact_records)
        self._snap = SessionStore(self.dir)
        self._lock = threading.RLock()
        self._writer: Optional[LogWriter] = None
        self._session = None
        self._recording = False
        self._gen = 0
        self._names: Dict[int, str] = {}   # id(oracle identity) -> name
        self._idents: Dict[int, object] = {}  # strong refs: ids stay stable
        self._carried: List[dict] = []     # mutation payloads to carry
        self._bytes_since = 0              # since last compaction
        self._records_since = 0
        self.n_unnamed_dropped = 0         # events of unregistered oracles

    # -------------------------------------------------------------- layout
    def _gen_path(self, gen: int) -> pathlib.Path:
        return self.dir / f"wal_{gen:06d}.log"

    @property
    def _checkpoint_path(self) -> pathlib.Path:
        return self.dir / "CHECKPOINT.json"

    @property
    def _lock_path(self) -> pathlib.Path:
        return self.dir / "wal.lock"

    def _read_checkpoint(self) -> dict:
        if self._checkpoint_path.exists():
            ck = json.loads(self._checkpoint_path.read_text())
            if ck.get("schema") != LOG_SCHEMA:
                raise LogCorruptionError(
                    f"session log schema {ck.get('schema')!r} does not "
                    f"match this build ({LOG_SCHEMA})")
            return ck
        return {"schema": LOG_SCHEMA, "gen": 0, "snapshot_offset": None}

    def _write_checkpoint(self, ck: dict) -> None:
        tmp = self._checkpoint_path.with_suffix(f".tmp-{os.getpid()}")
        tmp.write_text(json.dumps(ck))
        os.replace(tmp, self._checkpoint_path)

    def exists(self) -> bool:
        """Any restorable state under the directory?"""
        if self._checkpoint_path.exists():
            return True
        return any(self.dir.glob("wal_*.log"))

    @property
    def attached(self) -> bool:
        return self._writer is not None

    # ---------------------------------------------------------------- lock
    def _acquire_lock(self) -> None:
        for _ in range(2):
            try:
                fd = os.open(self._lock_path,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.write(fd, str(os.getpid()).encode())
                os.close(fd)
                return
            except FileExistsError:
                pid = self._lock_holder()
                if pid is not None and _pid_alive(pid):
                    raise ConcurrentWriterError(
                        f"session log {self.dir} is held by live writer "
                        f"pid {pid}; a log directory supports exactly one "
                        "writer") from None
                # dead holder (killed process): steal the lock and retry
                try:
                    os.unlink(self._lock_path)
                except FileNotFoundError:
                    pass
        raise ConcurrentWriterError(
            f"could not acquire {self._lock_path} (lock churn)")

    def _lock_holder(self) -> Optional[int]:
        try:
            return int(self._lock_path.read_text().strip() or 0)
        except (FileNotFoundError, ValueError):
            return None

    def _release_lock(self) -> None:
        if self._lock_holder() == os.getpid():
            try:
                os.unlink(self._lock_path)
            except FileNotFoundError:
                pass

    # -------------------------------------------------------------- attach
    def attach(self, session) -> None:
        """Acquire the writer lock and start recording ``session``.

        Call ``restore(session)`` first when ``exists()`` — attaching a
        fresh session over unreplayed state would interleave records of
        two unrelated lifetimes.
        """
        with self._lock:
            if self._writer is not None:
                raise RuntimeError("store is already attached")
            self._acquire_lock()
            if self.exists() and self._session is not session:
                self._release_lock()
                raise RuntimeError(
                    "log directory has existing state; call "
                    "restore(session) before attach(session) (or point "
                    "the store at an empty directory)")
            ck = self._read_checkpoint()
            self._gen = int(ck["gen"])
            path = self._gen_path(self._gen)
            valid_end = None
            if path.exists():
                _, _, valid_end, size = read_records(path)
                if valid_end < size:
                    get_tracer().metrics.inc("log.torn_bytes",
                                             size - valid_end)
            self._writer = LogWriter(path, truncate_to=valid_end)
            if not self._checkpoint_path.exists():
                self._write_checkpoint(ck)
            self._session = session
            self._install_hooks(session)
            self._recording = True

    def _install_hooks(self, session) -> None:
        session._session_log = self
        session.memo.hook = self._on_memo_event
        session.embedding_cache.hook = self._on_embedding_insert
        for name, (oracle, _proxy) in session._oracles.items():
            self.bind_oracle(name, oracle)

    def _remove_hooks(self) -> None:
        s = self._session
        if s is None:
            return
        s._session_log = None
        s.memo.hook = None
        s.embedding_cache.hook = None
        for ident in self._idents.values():
            if getattr(ident, "memo_hook", None) is not None:
                ident.memo_hook = None

    def bind_oracle(self, name: str, oracle) -> None:
        """Give ``oracle`` a durable name; hook its memo commits.  Called
        for already-registered oracles at attach and by
        ``Session.register_oracle`` afterwards."""
        ident = oracle_identity(oracle)
        with self._lock:
            self._names[id(ident)] = name
            self._idents[id(ident)] = ident
        try:
            ident.memo_hook = (
                lambda ids, labels, _n=name: self.record_oracle_memo(
                    _n, ids, labels))
        except AttributeError:
            pass  # oracle without a per-id memo (e.g. plain callable)

    def _name_of(self, ident) -> Optional[str]:
        name = self._names.get(id(ident))
        if name is None:
            # registered after the entry's oracle was first sighted —
            # refresh from the session registry before giving up
            if self._session is not None:
                for n, (o, _p) in self._session._oracles.items():
                    self._names.setdefault(id(oracle_identity(o)), n)
                    self._idents.setdefault(id(oracle_identity(o)),
                                            oracle_identity(o))
                name = self._names.get(id(ident))
            if name is None:
                self.n_unnamed_dropped += 1
                get_tracer().metrics.inc("log.unnamed_dropped")
        return name

    # ------------------------------------------------------------- append
    def _append(self, payload: dict) -> None:
        with self._lock:
            if not self._recording or self._writer is None:
                return
            n = self._writer.append(payload)
            self._bytes_since += n
            self._records_since += 1
            if payload["t"] in _MUTATION_KINDS:
                self._carried.append(payload)
        m = get_tracer().metrics
        m.inc("log.records")
        m.inc("log.bytes", n)

    # hook targets ----------------------------------------------------
    def _on_memo_event(self, kind: str, **f) -> None:
        if not self._recording:
            return
        if kind == "decision":
            name = self._name_of(f["ident"])
            if name is None:
                return
            dm: DecisionMemo = f["dm"]
            self._append({
                "t": "decision", "table": f["table"], "oracle": name,
                "version": int(dm.version), "n": int(dm.n),
                "cluster_key": list(dm.cluster_key),
                "fp": list(dm.fingerprint), "mask": dm.mask})
        elif kind == "selectivity":
            name = self._name_of(f["ident"])
            if name is None:
                return
            obs: SelObservation = f["obs"]
            self._append({
                "t": "selectivity", "table": f["table"], "oracle": name,
                "version": int(obs.version),
                "selectivity": float(obs.selectivity),
                "tokens_per_call": float(obs.tokens_per_call)})
        elif kind == "pilot":
            name = self._name_of(f["ident"])
            if name is None:
                return
            self._append({
                "t": "pilot", "table": f["table"], "oracle": name,
                "version": int(f["version"]), "seed": int(f["seed"]),
                "pilot_size": int(f["pilot_size"]),
                "stats": dataclasses.asdict(f["stats"])})
        elif kind == "join":
            name = self._name_of(f["ident"])
            if name is None:
                return
            jm: JoinDecisionMemo = f["jm"]
            self._append({
                "t": "join", "left": f["left"], "right": f["right"],
                "oracle": name, "left_version": int(jm.left_version),
                "right_version": int(jm.right_version),
                "fp": list(jm.fingerprint), "mask": jm.pair_mask})

    def _on_embedding_insert(self, keys: List[str], rows) -> None:
        if self._recording:
            self._append({"t": "emb", "keys": list(keys),
                          "rows": np.asarray(rows, np.float32)})

    def record_oracle_memo(self, name: str, ids, labels) -> None:
        if self._recording:
            self._append({"t": "omemo", "oracle": name,
                          "ids": np.asarray(ids, np.int64),
                          "vals": np.asarray(labels, bool)})

    def record_mutation(self, kind: str, handle, texts=None, embeddings=None,
                        ids=None) -> None:
        if not self._recording:
            return
        payload = {"t": kind, "table": handle.name,
                   "texts": list(texts) if texts is not None else None,
                   "emb": (np.asarray(embeddings, np.float32)
                           if embeddings is not None else None)}
        if ids is not None:
            payload["ids"] = np.asarray(ids, np.int64)
        self._append(payload)

    def record_precluster(self, handle, k: int, seed: int) -> None:
        """A cold k-means fit just happened: log (assign, centroids) so a
        restart replays the clustering instead of re-fitting it (restart
        time must be bounded by the tail, not the table)."""
        if not self._recording:
            return
        cached = handle._table._assign_cache.get((k, seed))
        if cached is None:
            return
        assign, cents = cached
        self._append({"t": "precluster", "table": handle.name,
                      "k": int(k), "seed": int(seed),
                      "version": int(handle.version),
                      "assign": np.asarray(assign),
                      "centroids": np.asarray(cents, np.float32)})

    # ------------------------------------------------------------ restore
    def restore(self, session, strict: bool = False) -> LogRestoreReport:
        """Rebuild ``session`` (tables/oracles registered, base data) from
        carried mutations + compaction snapshot + log tail.  Read-only:
        call ``attach`` afterwards to resume recording."""
        rep = LogRestoreReport()
        ck = self._read_checkpoint()
        self._gen = int(ck["gen"])
        snapshot_offset = ck.get("snapshot_offset")
        path = self._gen_path(self._gen)
        records: List[dict] = []
        ends: List[int] = []
        if path.exists():
            records, ends, valid_end, size = read_records(path)
            rep.torn_bytes = size - valid_end
        self._session = session
        was_recording, self._recording = self._recording, False
        try:
            # locate the snapshot point: records ending at or before it
            # are carried mutations that must replay BEFORE the snapshot
            # load (the snapshot fingerprints post-mutation table content)
            n_carried = 0
            if snapshot_offset is not None:
                while (n_carried < len(ends)
                       and ends[n_carried] <= snapshot_offset):
                    n_carried += 1
            carried, tail = records[:n_carried], records[n_carried:]
            for r in carried:
                self._apply(session, r, rep, strict)
                rep.n_carried_mutations += 1
            if snapshot_offset is not None and self._snap.exists("snapshot"):
                rep.snapshot = self._snap.load(session, tag="snapshot",
                                               strict=strict)
            for r in tail:
                self._apply(session, r, rep, strict)
                rep.n_tail_records += 1
            # mutations seen anywhere must carry forward at next compaction
            self._carried = [r for r in records
                             if r["t"] in _MUTATION_KINDS]
        finally:
            self._recording = was_recording
        m = get_tracer().metrics
        m.inc("log.replayed_records", rep.n_tail_records)
        m.inc("log.carried_mutations", rep.n_carried_mutations)
        m.inc("store.restore_dropped", rep.n_dropped)
        return rep

    def _resolve_oracle(self, session, name: str, rep: LogRestoreReport,
                        strict: bool):
        entry = session._oracles.get(name)
        if entry is None:
            msg = f"oracle {name!r} not registered"
            if strict:
                raise ValueError(f"session log mismatch: {msg}")
            if msg not in rep.skipped:
                rep.skipped.append(msg)
            return None
        ident = oracle_identity(entry[0])
        session.memo._oracles[id(ident)] = ident
        return ident

    def _apply(self, session, r: dict, rep: LogRestoreReport,
               strict: bool) -> None:
        kind = r["t"]
        memo = session.memo
        if kind in _MUTATION_KINDS:
            handle = session._tables.get(r["table"])
            if handle is None:
                msg = f"table {r['table']!r} not registered"
                if strict:
                    raise ValueError(f"session log mismatch: {msg}")
                rep.skipped.append(msg)
                return
            if kind == "append":
                handle.append(texts=r["texts"], embeddings=r["emb"])
            else:
                handle.update(r["ids"], texts=r["texts"],
                              embeddings=r["emb"])
        elif kind == "precluster":
            handle = session._tables.get(r["table"])
            if handle is None:
                rep.skipped.append(f"table {r['table']!r} not registered")
                return
            k, seed = int(r["k"]), int(r["seed"])
            assign = np.asarray(r["assign"])
            cents = np.asarray(r["centroids"], np.float32)
            session._assign_cache[(handle.name, k, seed)] = assign
            handle._table._assign_cache[(k, seed)] = (assign, cents)
            handle._dirty.setdefault(
                (k, seed), np.full(k, int(r["version"]), dtype=np.int64))
        elif kind == "decision":
            ident = self._resolve_oracle(session, r["oracle"], rep, strict)
            if ident is None:
                return
            fp = tuple(r["fp"])
            memo._decisions[(r["table"], id(ident), fp)] = DecisionMemo(
                version=int(r["version"]), n=int(r["n"]),
                mask=np.asarray(r["mask"], bool),
                cluster_key=tuple(r["cluster_key"]), fingerprint=fp)
            memo.note_sighting(r["table"], ident)
        elif kind == "selectivity":
            ident = self._resolve_oracle(session, r["oracle"], rep, strict)
            if ident is None:
                return
            memo._selectivity[(r["table"], id(ident))] = SelObservation(
                version=int(r["version"]),
                selectivity=float(r["selectivity"]),
                tokens_per_call=float(r["tokens_per_call"]))
        elif kind == "pilot":
            ident = self._resolve_oracle(session, r["oracle"], rep, strict)
            if ident is None:
                return
            memo._pilots[(r["table"], id(ident), int(r["version"]),
                          int(r["seed"]), int(r["pilot_size"]))] = \
                PredStats(**r["stats"])
        elif kind == "join":
            ident = self._resolve_oracle(session, r["oracle"], rep, strict)
            if ident is None:
                return
            fp = tuple(r["fp"])
            memo._join_decisions[(r["left"], r["right"], id(ident), fp)] = \
                JoinDecisionMemo(left_version=int(r["left_version"]),
                                 right_version=int(r["right_version"]),
                                 pair_mask=np.asarray(r["mask"], bool),
                                 fingerprint=fp)
            memo.note_pair_oracle(r["left"], ident)
            memo.note_pair_oracle(r["right"], ident)
        elif kind == "emb":
            rows = np.asarray(r["rows"], np.float32)
            for i, key in enumerate(r["keys"]):
                session.embedding_cache._store[key] = rows[i]
        elif kind == "omemo":
            ident = self._resolve_oracle(session, r["oracle"], rep, strict)
            if ident is None or not hasattr(ident, "memo_restore"):
                return
            ident.memo_restore({int(i): bool(v)
                                for i, v in zip(r["ids"], r["vals"])})
        else:
            msg = f"unknown record type {kind!r}"
            if strict:
                raise LogCorruptionError(msg)
            rep.skipped.append(msg)

    # ------------------------------------------------------------- status
    def tail_summary(self) -> dict:
        """WAL tail at a glance (statusz / flight-recorder bundle): which
        generation is live, how far the writer has advanced, and how much
        has accumulated since the last compaction."""
        with self._lock:
            return {
                "dir": str(self.dir),
                "generation": self._gen,
                "attached": self._writer is not None,
                "tail_offset": (self._writer.offset
                                if self._writer is not None else None),
                "bytes_since_compaction": self._bytes_since,
                "records_since_compaction": self._records_since,
                "compact_due": (self._bytes_since >= self.compact_bytes
                                or self._records_since
                                >= self.compact_records),
            }

    # --------------------------------------------------------- compaction
    @property
    def compact_due(self) -> bool:
        return (self._bytes_since >= self.compact_bytes
                or self._records_since >= self.compact_records)

    def compact(self, session=None) -> None:
        """Fold the log into a fresh snapshot + empty tail (see module
        docstring for the crash-safe commit order).  Run at a quiescent
        point — no queries in flight."""
        session = session if session is not None else self._session
        if session is None:
            raise RuntimeError("compact() needs a session (none attached)")
        with self._lock:
            if self._writer is None:
                raise RuntimeError("compact() before attach()")
            new_gen = self._gen + 1
            # (a) new generation, carried mutations at its head
            writer = LogWriter(self._gen_path(new_gen), fresh=True)
            for payload in self._carried:
                writer.append(payload)
            snapshot_offset = writer.offset
            # (b) whole-session snapshot (atomic tmp+rename inside)
            self._snap.save(session, tag="snapshot")
            # (c) commit point: the checkpoint flips restores to the new
            # generation; a crash before this line leaves the old
            # checkpoint + old generation fully usable
            self._write_checkpoint({"schema": LOG_SCHEMA, "gen": new_gen,
                                    "snapshot_offset": snapshot_offset})
            old_writer, self._writer = self._writer, writer
            old_writer.close()
            old_gen, self._gen = self._gen, new_gen
            # (d) best-effort cleanup of superseded generations
            for g in range(old_gen, -1, -1):
                p = self._gen_path(g)
                if not p.exists():
                    break
                try:
                    p.unlink()
                except OSError:
                    pass
            self._bytes_since = 0
            self._records_since = 0
        m = get_tracer().metrics
        m.inc("log.compactions")
        # mark the compaction point so health rules can alert on WAL bytes
        # written since (counter_delta("log.bytes", "log.last_compaction_bytes"))
        m.set("log.last_compaction_bytes",
              getattr(m.counter("log.bytes"), "value", 0.0))

    def compact_if_due(self, session=None) -> bool:
        if self.compact_due:
            self.compact(session)
            return True
        return False

    # -------------------------------------------------------------- close
    def close(self, compact: bool = False) -> None:
        """Stop recording and release the lock.  ``compact=True`` folds
        the tail into a final snapshot first (fastest next restart)."""
        with self._lock:
            if self._writer is None:
                return
            if compact:
                self.compact()
            self._recording = False
            self._remove_hooks()
            self._writer.close()
            self._writer = None
            self._release_lock()

    def abandon(self) -> None:
        """Simulate a crash (tests): drop the writer mid-flight without
        hooks cleanup or compaction, releasing only the OS-level lock the
        dead process would no longer hold."""
        with self._lock:
            if self._writer is None:
                return
            self._recording = False
            self._remove_hooks()
            self._writer.close()
            self._writer = None
            self._release_lock()


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True
