"""Theoretical analysis (paper §3.2): Bernstein sampling-without-replacement
bounds connecting the sample ratio ξ to the user error tolerance ε.

All formulas follow the paper exactly:

Lemma 3.2 (Bernstein): Pr[|mu_hat - mu| >= eps]
    <= 2 exp( -k eps^2 / (2 sigma_hat^2 + 2 R eps / 3) * (n-k)/(n-1) )

Theorem 3.3 (UniVote): vote errs with prob <= max(lb+eps, 1-(ub-eps)) w.p.
    >= 1 - 2 l^n, provided
    xi >= 1/2 - sqrt(1/4 + ln(l) (2 sigma^2/eps^2 + 2/(3 eps)))

Theorem 3.6 (SimVote): same guarantee with
    xi >= 1/2 - sqrt(1/4 + v ln(l) (6 sigma^2 + 2 eps) / (3 eps^2))
"""
from __future__ import annotations

import math


def bernstein_tail(k: float, n: float, eps: float, sigma2: float,
                   R: float = 1.0) -> float:
    """Lemma 3.2 tail probability for k of n samples without replacement."""
    if k <= 0 or n <= 1:
        return 1.0
    fpc = (n - k) / (n - 1)  # finite population correction
    expo = -k * eps * eps / (2 * sigma2 + 2 * R * eps / 3) * fpc
    return min(1.0, 2 * math.exp(expo))


def xi_for_epsilon_univote(eps: float, sigma2: float, l: float = 0.9996) -> float:
    """Theorem 3.3 minimum sample ratio for tolerance eps (UniVote).

    l in (0,1): per-tuple failure scale (failure prob <= 2 l^n).  ln(l) < 0,
    so the sqrt argument is < 1/4 and xi lands in (0, 1/2].
    """
    assert 0 < l < 1 and eps > 0
    inner = 0.25 + math.log(l) * (2 * sigma2 / (eps * eps) + 2 / (3 * eps))
    if inner <= 0:
        return 1.0  # tolerance unreachable by sampling; fall back to full scan
    return max(0.0, 0.5 - math.sqrt(inner))


def xi_for_epsilon_simvote(eps: float, sigma2: float, l: float = 0.9996,
                           v: float = 2.0) -> float:
    """Theorem 3.6 minimum sample ratio (SimVote); v bounds max_i w_i <= v/k."""
    assert 0 < l < 1 and eps > 0 and v >= 1.0
    inner = 0.25 + v * math.log(l) * (6 * sigma2 + 2 * eps) / (3 * eps * eps)
    if inner <= 0:
        return 1.0
    return max(0.0, 0.5 - math.sqrt(inner))


def epsilon_for_xi(xi: float, n: int, sigma2: float, l: float = 0.9996,
                   weighted: bool = False, v: float = 2.0) -> float:
    """Inverse: the tolerance eps achieved by sample ratio xi on a size-n
    cluster (tightest eps with tail <= 2 l^n).  Solves the quadratic in eps.
    """
    k = max(1.0, xi * n)
    if k >= n:
        return 0.0
    target = -n * math.log(l)  # want k eps^2 fpc / (2 s + 2 eps/3) >= target
    fpc = (n - k) / (n - 1)
    if weighted:
        # k eps^2 fpc * 3 / (v (6 s^2 + 2 eps)) = target
        a = 3 * k * fpc
        b = -2 * v * target
        c = -6 * sigma2 * v * target
    else:
        a = k * fpc
        b = -2 * target / 3
        c = -2 * sigma2 * target
    disc = b * b - 4 * a * c
    return (-b + math.sqrt(max(0.0, disc))) / (2 * a)


def vote_error_bound(lb: float, ub: float, eps: float) -> float:
    """Theorem 3.3/3.6 final per-tuple error bound when the vote commits."""
    return max(lb + eps, 1 - (ub - eps))


def choose_sample_size(n: int, xi: float, min_sample: int = 101) -> int:
    """Paper §4.1: per-cluster sample count = max(ceil(xi*n), min_sample), <= n."""
    return min(n, max(min_sample, math.ceil(xi * n)))
