"""The paper's contribution: Clustering-Sampling-Voting semantic filtering.

Public API:
    SemanticTable.sem_filter(predicate, method="csv", ...)  — operator form
    semantic_filter(...)                                    — Algorithm 1
    uni_vote / sim_vote                                     — Algorithms 2/3
    xi_for_epsilon_*                                        — Theorems 3.3/3.6
"""
from repro.core.theory import (xi_for_epsilon_univote, xi_for_epsilon_simvote,
                               vote_error_bound, epsilon_for_xi,
                               bernstein_tail, choose_sample_size)
from repro.core.clustering import kmeans, kmeans_predict, minibatch_kmeans_update
from repro.core.voting import (uni_vote, sim_vote, uni_vote_batch,
                               sim_vote_batch, vote_clusters)
from repro.core.csv_filter import (CSVConfig, FilterResult, RoundPlan,
                                   RoundResult, plan_round, semantic_filter)
from repro.core.oracle import (SyntheticOracle, ModelOracle, OracleStats,
                               ProxyModel, StatsScope, SyncOracleDispatcher,
                               AsyncOracleDispatcher)
from repro.core.baselines import reference_filter, lotus_filter, bargain_filter
from repro.core.operators import SemanticTable
