"""Algorithm 1: SemanticFilter(T, e, M, k, xi) — the CSV driver.

Host-side orchestration (cluster queue, recursive re-clustering, fallback)
around device-side batched math (k-means assignment, voting kernels) and
batched oracle invocations.  The driver is *restartable*: its state is the
oracle memo plus the deterministic RNG seed, so a preempted run resumes by
replaying decisions against cached LLM calls (no re-invocation).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.core.clustering import kmeans
from repro.core.voting import sim_vote, uni_vote


@dataclasses.dataclass
class CSVConfig:
    n_clusters: int = 4
    xi: float = 0.005
    min_sample: int = 101
    lb: float = 0.15
    ub: Optional[float] = None  # default 1 - lb
    max_recluster: int = 3
    vote: str = "uni"  # "uni" | "sim"
    epsilon: Optional[float] = None  # if set, xi is derived from Thm 3.3/3.6
    theory_l: float = 0.9996
    sim_v: float = 2.0
    sim_bandwidth: Optional[float] = None
    kmeans_iters: int = 50
    seed: int = 0

    @property
    def ub_(self) -> float:
        return self.ub if self.ub is not None else 1.0 - self.lb


@dataclasses.dataclass
class FilterResult:
    mask: np.ndarray  # (N,) bool — tuples passing the filter
    n_llm_calls: int
    input_tokens: int
    output_tokens: int
    n_voted: int  # tuples decided by voting (no LLM call)
    n_fallback: int  # tuples decided by the final linear fallback
    recluster_rounds: int
    recluster_time_s: float
    total_time_s: float
    cluster_log: list  # per-cluster (size, sample, score stats) records
    xi_used: float


def _derive_xi(cfg: CSVConfig, sigma2: float) -> float:
    if cfg.epsilon is None:
        return cfg.xi
    if cfg.vote == "sim":
        return theory.xi_for_epsilon_simvote(cfg.epsilon, sigma2, cfg.theory_l,
                                             cfg.sim_v)
    return theory.xi_for_epsilon_univote(cfg.epsilon, sigma2, cfg.theory_l)


def semantic_filter(embeddings: np.ndarray, oracle, cfg: CSVConfig = None,
                    precomputed_assign: Optional[np.ndarray] = None
                    ) -> FilterResult:
    """Run CSV over a table represented by its tuple embeddings.

    embeddings: (N, D) — generated offline (paper phase 1).
    oracle: callable(ids)->bool array with .stats (see repro.core.oracle).
    """
    cfg = cfg or CSVConfig()
    t0 = time.time()
    rng = np.random.default_rng(cfg.seed)
    n = embeddings.shape[0]
    emb = np.asarray(embeddings, dtype=np.float32)
    result = np.zeros(n, dtype=bool)
    decided = np.zeros(n, dtype=bool)
    calls_before = oracle.stats.n_calls
    lb, ub = cfg.lb, cfg.ub_
    xi = _derive_xi(cfg, sigma2=0.25)  # worst-case sigma before seeing data
    cluster_log = []
    recluster_time = 0.0
    n_voted = 0
    n_fallback = 0
    rounds_used = 0

    # ---- initial clustering (offline phase; query-agnostic) ----
    if precomputed_assign is not None:
        assign = np.asarray(precomputed_assign)
    else:
        key = jax.random.key(cfg.seed)
        _, assign, _ = kmeans(key, jnp.asarray(emb), cfg.n_clusters,
                              max_iters=cfg.kmeans_iters)
        assign = np.asarray(assign)

    queue = [np.nonzero(assign == c)[0] for c in range(int(assign.max()) + 1)]
    queue = [c for c in queue if len(c)]

    depth = 0
    while queue and depth <= cfg.max_recluster:
        undetermined: list[np.ndarray] = []
        for cluster in queue:
            m = len(cluster)
            n_sample = theory.choose_sample_size(m, xi, cfg.min_sample)
            sample_local = rng.choice(m, size=n_sample, replace=False)
            sample_ids = cluster[sample_local]
            labels = oracle(sample_ids)
            result[sample_ids] = labels
            decided[sample_ids] = True

            rest_mask = np.ones(m, dtype=bool)
            rest_mask[sample_local] = False
            rest_ids = cluster[rest_mask]
            if len(rest_ids) == 0:
                cluster_log.append({"size": m, "sampled": n_sample,
                                    "score": float(np.mean(labels)),
                                    "depth": depth, "outcome": "exhausted"})
                continue

            if cfg.vote == "sim":
                vr = sim_vote(emb[rest_ids], emb[sample_ids],
                              labels.astype(np.float32), lb, ub,
                              cfg.sim_bandwidth)
            else:
                vr = uni_vote(labels.astype(np.float32), len(rest_ids), lb, ub)

            result[rest_ids[vr.decided_true]] = True
            decided[rest_ids[vr.decided_true]] = True
            result[rest_ids[vr.decided_false]] = False
            decided[rest_ids[vr.decided_false]] = True
            n_voted += len(vr.decided_true) + len(vr.decided_false)
            if len(vr.undetermined):
                undetermined.append(rest_ids[vr.undetermined])
            cluster_log.append({
                "size": m, "sampled": n_sample,
                "score": float(np.mean(labels)),
                "voted": int(len(vr.decided_true) + len(vr.decided_false)),
                "undetermined": int(len(vr.undetermined)),
                "depth": depth,
                "outcome": "vote" if not len(vr.undetermined) else "recluster",
            })

        if not undetermined:
            break
        pending = np.concatenate(undetermined)
        depth += 1
        rounds_used = depth
        if depth > cfg.max_recluster:
            # final fallback: direct LLM evaluation (bounded error by design)
            labels = oracle(pending)
            result[pending] = labels
            decided[pending] = True
            n_fallback += len(pending)
            queue = []
        else:
            t_rc = time.time()
            key = jax.random.key(cfg.seed + depth)
            k = min(cfg.n_clusters, len(pending))
            if len(pending) <= cfg.min_sample:
                labels = oracle(pending)
                result[pending] = labels
                decided[pending] = True
                n_fallback += len(pending)
                queue = []
            else:
                _, sub_assign, _ = kmeans(key, jnp.asarray(emb[pending]), k,
                                          max_iters=cfg.kmeans_iters)
                sub_assign = np.asarray(sub_assign)
                queue = [pending[sub_assign == c] for c in range(k)]
                queue = [c for c in queue if len(c)]
            recluster_time += time.time() - t_rc

    assert decided.all(), "driver must decide every tuple"
    st = oracle.stats
    return FilterResult(
        mask=result,
        n_llm_calls=st.n_calls - calls_before,
        input_tokens=st.input_tokens,
        output_tokens=st.output_tokens,
        n_voted=n_voted,
        n_fallback=n_fallback,
        recluster_rounds=rounds_used,
        recluster_time_s=recluster_time,
        total_time_s=time.time() - t0,
        cluster_log=cluster_log,
        xi_used=xi,
    )
