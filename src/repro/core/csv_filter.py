"""Algorithm 1: SemanticFilter(T, e, M, k, xi) — the CSV driver.

Host-side orchestration (cluster queue, recursive re-clustering, fallback)
around device-side batched math (k-means assignment, voting kernels) and
batched oracle invocations.  The driver is *restartable*: its state is the
oracle memo plus the deterministic RNG seed, so a preempted run resumes by
replaying decisions against cached LLM calls (no re-invocation).

Two executors share the same decision semantics (bit-identical masks and
call counts under a fixed seed — see tests/test_round_executor.py):

- ``executor="round"`` (default): a round-vectorized pipeline
  plan → sample → oracle → vote → partition.  Within each re-clustering
  round the sample ids of ALL live clusters are gathered into a single
  cross-cluster oracle call (one large prompt batch that actually fills the
  serving engine's buckets) and voting for all clusters runs in one
  segmented device dispatch.  ``pipeline_depth > 1`` splits a round into
  that many waves and submits wave k+1's oracle batch (async, strict FIFO)
  before voting wave k — oracle prefill overlaps device voting.
- ``executor="sequential"``: the original one-cluster-at-a-time loop, kept
  as the regression baseline.

Bit-identity argument: the planner draws each cluster's sample with the same
``rng.choice`` in the same cluster order as the sequential loop (the driver
RNG and the oracle's flip RNG are separate streams), and a numpy Generator
produces the same values whether drawn as one batch or consecutively —
so the concatenated oracle batch consumes the flip stream exactly as C
per-cluster calls would.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.core.clustering import kmeans
from repro.core.oracle import AsyncOracleDispatcher, SyncOracleDispatcher
from repro.core.voting import sim_vote, uni_vote, vote_clusters
from repro.obs.trace import get_tracer
from repro.utils.timing import monotonic


@dataclasses.dataclass
class CSVConfig:
    n_clusters: int = 4
    xi: float = 0.005
    min_sample: int = 101
    lb: float = 0.15
    ub: Optional[float] = None  # default 1 - lb
    max_recluster: int = 3
    vote: str = "uni"  # "uni" | "sim"
    epsilon: Optional[float] = None  # if set, xi is derived from Thm 3.3/3.6
    theory_l: float = 0.9996
    sim_v: float = 2.0
    sim_bandwidth: Optional[float] = None
    kmeans_iters: int = 50
    seed: int = 0
    executor: str = "round"  # "round" | "sequential"
    pipeline_depth: int = 1  # oracle waves per round (>1 overlaps prefill
    #                          of the next wave with voting of the current)
    shards: int = 1  # >1 partitions each round's clusters across shards
    #                  (repro.distributed.round) — bit-identical masks,
    #                  call counts, and memo state to shards=1

    @property
    def ub_(self) -> float:
        return self.ub if self.ub is not None else 1.0 - self.lb


@dataclasses.dataclass
class FilterResult:
    mask: np.ndarray  # (N,) bool — tuples passing the filter
    n_llm_calls: int
    input_tokens: int  # delta for THIS run (oracle may be shared/reused)
    output_tokens: int
    n_voted: int  # tuples decided by voting (no LLM call)
    n_fallback: int  # tuples decided by the final linear fallback
    recluster_rounds: int
    recluster_time_s: float
    total_time_s: float
    cluster_log: list  # per-cluster (size, sample, score stats) records
    xi_used: float
    round_log: list = dataclasses.field(default_factory=list)
    oracle_batch_sizes: list = dataclasses.field(default_factory=list)
    # tuples the driver was asked to decide: the full table, or the live
    # subset when a plan cascade masks out already-rejected tuples
    n_input: int = -1
    # tuples decided by replaying a session-memoized earlier run (zero
    # oracle cost); > 0 only on the repro.api reuse path
    n_replayed: int = 0


def replay_result(mask: np.ndarray, n_input: int, n_replayed: int,
                  rerun: Optional[FilterResult] = None,
                  total_time_s: float = 0.0) -> FilterResult:
    """FilterResult for a (possibly partial) memo replay.

    ``mask`` is the merged full-length decision mask; ``rerun`` is the
    driver result for the dirty subset that had to be re-voted (None when
    the whole live set replayed).  Replayed tuples cost zero oracle calls,
    so every count not covered by ``rerun`` is zero.
    """
    if rerun is None:
        return FilterResult(
            mask=mask, n_llm_calls=0, input_tokens=0, output_tokens=0,
            n_voted=0, n_fallback=0, recluster_rounds=0,
            recluster_time_s=0.0, total_time_s=total_time_s, cluster_log=[],
            xi_used=0.0, n_input=int(n_input), n_replayed=int(n_replayed))
    return dataclasses.replace(
        rerun, mask=mask, n_input=int(n_input), n_replayed=int(n_replayed),
        total_time_s=total_time_s or rerun.total_time_s)


# ---------------------------------------------------------------- round plan
@dataclasses.dataclass
class ClusterPlan:
    ids: np.ndarray         # global tuple ids of the cluster
    sample_ids: np.ndarray  # ids submitted to the oracle
    rest_ids: np.ndarray    # ids decided by voting
    size: int
    n_sample: int


@dataclasses.dataclass
class RoundPlan:
    depth: int
    clusters: list

    @property
    def n_sampled(self) -> int:
        return int(sum(c.n_sample for c in self.clusters))


@dataclasses.dataclass
class RoundResult:
    depth: int
    n_clusters: int
    n_sampled: int
    n_voted: int
    n_undetermined: int
    waves: int
    oracle_batches: list  # submitted batch size per wave
    shards: int = 1  # shards that executed this round (1 = single-host)


def plan_round(queue: list, rng: np.random.Generator, xi: float,
               cfg: CSVConfig, depth: int) -> RoundPlan:
    """Draw every cluster's sample (same RNG order as the sequential loop)."""
    clusters = []
    for cluster in queue:
        m = len(cluster)
        n_sample = theory.choose_sample_size(m, xi, cfg.min_sample)
        sample_local = rng.choice(m, size=n_sample, replace=False)
        rest_mask = np.ones(m, dtype=bool)
        rest_mask[sample_local] = False
        clusters.append(ClusterPlan(
            ids=cluster, sample_ids=cluster[sample_local],
            rest_ids=cluster[rest_mask], size=m, n_sample=n_sample))
    return RoundPlan(depth=depth, clusters=clusters)


def _observe_vote_margin(score: float, lb: float, ub: float) -> None:
    """Export how close a cluster's vote score sat to its decision band.

    A collapsing margin (scores hugging lb/ub) means votes are barely
    decided — the health monitor alerts on the distribution
    (``quality.vote_margin``).  Observation-only: the ambient registry is a
    no-op ``NullRegistry`` unless a tracer is installed.
    """
    get_tracer().metrics.observe("quality.vote_margin",
                                 min(abs(score - lb), abs(ub - score)))


def _vote_wave(wave: list, labels_by_cluster: list, emb: np.ndarray,
               cfg: CSVConfig, lb: float, ub: float):
    """One segmented voting dispatch for every non-exhausted wave cluster."""
    live = [i for i, cp in enumerate(wave) if len(cp.rest_ids)]
    if not live:
        return {}
    sim = cfg.vote == "sim"
    votes = vote_clusters(
        cfg.vote, [labels_by_cluster[i] for i in live],
        [len(wave[i].rest_ids) for i in live], lb, ub,
        emb_unsampled=[emb[wave[i].rest_ids] for i in live] if sim else None,
        emb_sampled=[emb[wave[i].sample_ids] for i in live] if sim else None,
        bandwidth=cfg.sim_bandwidth)
    return dict(zip(live, votes))


def _recluster_or_fallback(emb, oracle, cfg, pending, depth, result, decided):
    """Shared round tail: route undetermined tuples to the linear fallback
    or a k-means re-split.  Both executors MUST share this — the
    bit-identity contract depends on identical key/fallback derivation.
    Returns (next_queue, n_fallback_added, recluster_seconds)."""
    tr = get_tracer()
    with tr.span("partition", kind="partition", depth=depth,
                 n_pending=int(len(pending))) as sp:
        if depth > cfg.max_recluster:
            # final fallback: direct LLM evaluation (bounded error by design)
            labels = oracle(pending)
            result[pending] = labels
            decided[pending] = True
            sp.set(outcome="fallback")
            return [], len(pending), 0.0
        t_rc = monotonic()
        key = jax.random.key(cfg.seed + depth)
        k = min(cfg.n_clusters, len(pending))
        if len(pending) <= cfg.min_sample:
            labels = oracle(pending)
            result[pending] = labels
            decided[pending] = True
            sp.set(outcome="small_fallback")
            return [], len(pending), monotonic() - t_rc
        _, sub_assign, _ = kmeans(key, jnp.asarray(emb[pending]), k,
                                  max_iters=cfg.kmeans_iters)
        sub_assign = np.asarray(sub_assign)
        queue = [pending[sub_assign == c] for c in range(k)]
        queue = [c for c in queue if len(c)]
        sp.set(outcome="recluster", n_children=len(queue))
        return queue, 0, monotonic() - t_rc


def _run_round_executor(emb, oracle, cfg, rng, xi, result, decided,
                        cluster_log, round_log, queue):
    """plan → sample → oracle → vote → partition, one round per iteration."""
    tr = get_tracer()
    lb, ub = cfg.lb, cfg.ub_
    n_voted = n_fallback = 0
    rounds_used = 0
    recluster_time = 0.0
    depth = 0
    while queue and depth <= cfg.max_recluster:
        with tr.span("round", kind="round", depth=depth,
                     n_clusters=len(queue), executor="round") as rsp:
            t_round = monotonic()
            with tr.span("plan", kind="plan"):
                plan = plan_round(queue, rng, xi, cfg, depth)
            n_waves = max(1, min(int(cfg.pipeline_depth),
                                 len(plan.clusters)))
            bounds = np.linspace(0, len(plan.clusters),
                                 n_waves + 1).astype(int)
            waves = [plan.clusters[bounds[k]:bounds[k + 1]]
                     for k in range(n_waves)]
            waves = [w for w in waves if w]

            dispatcher = (AsyncOracleDispatcher(oracle) if len(waves) > 1
                          else SyncOracleDispatcher(oracle))
            handles = []
            undetermined = []
            round_voted = 0
            oracle_batches = []
            try:
                for k, wave in enumerate(waves):
                    with tr.span("oracle", kind="oracle", wave=k) as osp:
                        if k == 0:
                            # submitting wave 0 here (not before the loop)
                            # keeps submission order — submit(0), submit(1),
                            # result(0) — with submit+wait inside the span
                            handles.append(dispatcher.submit(
                                np.concatenate([cp.sample_ids
                                                for cp in waves[0]])))
                        if k + 1 < len(waves):
                            # overlap: next wave's oracle prefill starts
                            # before this wave's voting touches the device
                            handles.append(dispatcher.submit(
                                np.concatenate([cp.sample_ids
                                                for cp in waves[k + 1]])))
                        flat_labels = handles[k].result()
                        osp.set(batch=int(len(flat_labels)))
                    oracle_batches.append(int(len(flat_labels)))
                    offsets = np.cumsum([cp.n_sample for cp in wave])[:-1]
                    labels_by_cluster = np.split(flat_labels, offsets)

                    for cp, labels in zip(wave, labels_by_cluster):
                        result[cp.sample_ids] = labels
                        decided[cp.sample_ids] = True

                    with tr.span("vote", kind="vote", wave=k,
                                 n_clusters=len(wave)):
                        votes = _vote_wave(wave, labels_by_cluster, emb,
                                           cfg, lb, ub)
                        for i, cp in enumerate(wave):
                            labels = labels_by_cluster[i]
                            if len(cp.rest_ids) == 0:
                                cluster_log.append({
                                    "size": cp.size, "sampled": cp.n_sample,
                                    "score": float(np.mean(labels)),
                                    "depth": depth, "outcome": "exhausted"})
                                continue
                            vr = votes[i]
                            result[cp.rest_ids[vr.decided_true]] = True
                            decided[cp.rest_ids[vr.decided_true]] = True
                            result[cp.rest_ids[vr.decided_false]] = False
                            decided[cp.rest_ids[vr.decided_false]] = True
                            voted = (len(vr.decided_true)
                                     + len(vr.decided_false))
                            n_voted += voted
                            round_voted += voted
                            if len(vr.undetermined):
                                undetermined.append(
                                    cp.rest_ids[vr.undetermined])
                            score = float(np.mean(labels))
                            _observe_vote_margin(score, lb, ub)
                            cluster_log.append({
                                "size": cp.size, "sampled": cp.n_sample,
                                "score": score,
                                "voted": int(voted),
                                "undetermined": int(len(vr.undetermined)),
                                "depth": depth,
                                "outcome": ("vote"
                                            if not len(vr.undetermined)
                                            else "recluster"),
                            })
            finally:
                dispatcher.close()

            n_undet = int(sum(len(u) for u in undetermined))
            round_log.append(RoundResult(
                depth=depth, n_clusters=len(plan.clusters),
                n_sampled=plan.n_sampled, n_voted=round_voted,
                n_undetermined=n_undet, waves=len(waves),
                oracle_batches=oracle_batches))
            rsp.set(n_sampled=plan.n_sampled, n_voted=round_voted,
                    n_undetermined=n_undet, waves=len(waves))
            tr.metrics.inc("driver.rounds")
            tr.metrics.observe("round.wall_s", monotonic() - t_round)

            if not undetermined:
                break
            pending = np.concatenate(undetermined)
            depth += 1
            rounds_used = depth
            queue, fb, dt = _recluster_or_fallback(
                emb, oracle, cfg, pending, depth, result, decided)
            n_fallback += fb
            recluster_time += dt
    return n_voted, n_fallback, rounds_used, recluster_time


def _run_sequential_executor(emb, oracle, cfg, rng, xi, result, decided,
                             cluster_log, round_log, queue):
    """The pre-refactor cluster-at-a-time loop (regression baseline)."""
    tr = get_tracer()
    lb, ub = cfg.lb, cfg.ub_
    n_voted = n_fallback = 0
    rounds_used = 0
    recluster_time = 0.0
    depth = 0
    while queue and depth <= cfg.max_recluster:
        with tr.span("round", kind="round", depth=depth,
                     n_clusters=len(queue), executor="sequential"):
            undetermined = []
            for cluster in queue:
                m = len(cluster)
                n_sample = theory.choose_sample_size(m, xi, cfg.min_sample)
                sample_local = rng.choice(m, size=n_sample, replace=False)
                sample_ids = cluster[sample_local]
                labels = oracle(sample_ids)
                result[sample_ids] = labels
                decided[sample_ids] = True

                rest_mask = np.ones(m, dtype=bool)
                rest_mask[sample_local] = False
                rest_ids = cluster[rest_mask]
                if len(rest_ids) == 0:
                    cluster_log.append({
                        "size": m, "sampled": n_sample,
                        "score": float(np.mean(labels)),
                        "depth": depth, "outcome": "exhausted"})
                    continue

                if cfg.vote == "sim":
                    vr = sim_vote(emb[rest_ids], emb[sample_ids],
                                  labels.astype(np.float32), lb, ub,
                                  cfg.sim_bandwidth)
                else:
                    vr = uni_vote(labels.astype(np.float32), len(rest_ids),
                                  lb, ub)

                result[rest_ids[vr.decided_true]] = True
                decided[rest_ids[vr.decided_true]] = True
                result[rest_ids[vr.decided_false]] = False
                decided[rest_ids[vr.decided_false]] = True
                n_voted += len(vr.decided_true) + len(vr.decided_false)
                if len(vr.undetermined):
                    undetermined.append(rest_ids[vr.undetermined])
                score = float(np.mean(labels))
                _observe_vote_margin(score, lb, ub)
                cluster_log.append({
                    "size": m, "sampled": n_sample,
                    "score": score,
                    "voted": int(len(vr.decided_true)
                                 + len(vr.decided_false)),
                    "undetermined": int(len(vr.undetermined)),
                    "depth": depth,
                    "outcome": ("vote" if not len(vr.undetermined)
                                else "recluster"),
                })

            if not undetermined:
                break
            pending = np.concatenate(undetermined)
            depth += 1
            rounds_used = depth
            queue, fb, dt = _recluster_or_fallback(
                emb, oracle, cfg, pending, depth, result, decided)
            n_fallback += fb
            recluster_time += dt
    return n_voted, n_fallback, rounds_used, recluster_time


def _derive_xi(cfg: CSVConfig, sigma2: float) -> float:
    if cfg.epsilon is None:
        return cfg.xi
    if cfg.vote == "sim":
        return theory.xi_for_epsilon_simvote(cfg.epsilon, sigma2, cfg.theory_l,
                                             cfg.sim_v)
    return theory.xi_for_epsilon_univote(cfg.epsilon, sigma2, cfg.theory_l)


def semantic_filter(embeddings: np.ndarray, oracle, cfg: CSVConfig = None,
                    precomputed_assign: Optional[np.ndarray] = None,
                    subset_ids: Optional[np.ndarray] = None
                    ) -> FilterResult:
    """Run CSV over a table represented by its tuple embeddings.

    embeddings: (N, D) — generated offline (paper phase 1).
    oracle: callable(ids)->bool array with .stats (see repro.core.oracle).
    subset_ids: restrict the filter to these tuple ids (plan-cascade entry
    point: conjuncts after the first only see tuples still alive).  The
    returned mask stays full-length with False outside the subset; a
    full-table ``precomputed_assign`` is restricted to the subset, so the
    offline clustering is reused rather than recomputed per conjunct.
    """
    cfg = cfg or CSVConfig()
    if cfg.executor not in ("round", "sequential"):
        raise ValueError(f"unknown executor {cfg.executor!r}; "
                         "expected 'round' or 'sequential'")
    if cfg.shards < 1:
        raise ValueError(f"shards must be >= 1, got {cfg.shards}")
    if cfg.shards > 1 and cfg.executor != "round":
        raise ValueError("shards > 1 requires executor='round'")
    t0 = monotonic()
    rng = np.random.default_rng(cfg.seed)
    n = embeddings.shape[0]
    emb = np.asarray(embeddings, dtype=np.float32)
    result = np.zeros(n, dtype=bool)
    decided = np.zeros(n, dtype=bool)
    stats_before = oracle.stats.clone()
    xi = _derive_xi(cfg, sigma2=0.25)  # worst-case sigma before seeing data
    cluster_log: list = []
    round_log: list = []
    subset = (None if subset_ids is None
              else np.unique(np.asarray(subset_ids, dtype=np.int64)))

    # ---- initial clustering (offline phase; query-agnostic) ----
    if subset is not None and len(subset) == 0:
        queue = []
    elif precomputed_assign is not None:
        assign = np.asarray(precomputed_assign)
        if subset is not None:
            sub_assign = assign[subset]
            queue = [subset[sub_assign == c]
                     for c in range(int(sub_assign.max()) + 1)]
        else:
            queue = [np.nonzero(assign == c)[0]
                     for c in range(int(assign.max()) + 1)]
        queue = [c for c in queue if len(c)]
    else:
        rows = subset if subset is not None else np.arange(n)
        key = jax.random.key(cfg.seed)
        k = min(cfg.n_clusters, len(rows))
        _, assign, _ = kmeans(key, jnp.asarray(emb[rows]), k,
                              max_iters=cfg.kmeans_iters)
        assign = np.asarray(assign)
        queue = [rows[assign == c] for c in range(int(assign.max()) + 1)]
        queue = [c for c in queue if len(c)]

    if cfg.executor == "sequential":
        run = _run_sequential_executor
    elif cfg.shards > 1:
        # lazy import: repro.distributed.round imports this module's round
        # primitives (plan_round, _vote_wave, _recluster_or_fallback)
        from repro.distributed.round import run_sharded_executor
        run = run_sharded_executor
    else:
        run = _run_round_executor
    n_voted, n_fallback, rounds_used, recluster_time = run(
        emb, oracle, cfg, rng, xi, result, decided, cluster_log, round_log,
        queue)

    # survives python -O: this postcondition guards the paper's completeness
    # contract (every tuple decided), not a debug assumption
    undecided = (~decided if subset is None else ~decided[subset])
    if undecided.any():
        raise RuntimeError(
            f"driver left {int(undecided.sum())} tuple(s) undecided — "
            "executor invariant violated")
    delta = oracle.stats.delta(stats_before)
    metrics = get_tracer().metrics
    metrics.inc("oracle.calls", delta.n_calls)
    metrics.inc("oracle.input_tokens", delta.input_tokens)
    metrics.inc("oracle.output_tokens", delta.output_tokens)
    metrics.inc("driver.voted", n_voted)
    metrics.inc("driver.fallback", n_fallback)
    return FilterResult(
        mask=result,
        n_llm_calls=delta.n_calls,
        input_tokens=delta.input_tokens,
        output_tokens=delta.output_tokens,
        n_voted=n_voted,
        n_fallback=n_fallback,
        recluster_rounds=rounds_used,
        recluster_time_s=recluster_time,
        total_time_s=monotonic() - t0,
        cluster_log=cluster_log,
        xi_used=xi,
        round_log=round_log,
        oracle_batch_sizes=delta.batch_sizes,
        n_input=int(n if subset is None else len(subset)),
    )
