"""BM25 lexical features + hybrid clustering distance (paper §4.1).

The paper mixes lambda * L2(embedding) + (1-lambda) * BM25 distance for
lexically-anchored predicates.  K-means needs a vector space, so we embed
BM25 as a hashed tf-idf-weighted bag-of-words vector and cluster in the
*concatenated* space  [sqrt(lambda) * emb ; sqrt(1-lambda) * bm25_vec]:
squared L2 there equals the weighted sum of the two squared distances —
the same monotone combination the paper uses (adaptation noted in
DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import List, Sequence

import numpy as np

from repro.data.tokenizer import HashTokenizer


def bm25_vectors(texts: Sequence[str], dim: int = 256, k1: float = 1.5,
                 b: float = 0.75, tokenizer: HashTokenizer = None
                 ) -> np.ndarray:
    """Hashed BM25-weighted term vectors, L2-normalized. (N, dim)."""
    tok = tokenizer or HashTokenizer()
    docs = [tok.words(t) for t in texts]
    n = len(docs)
    avgdl = max(1.0, float(np.mean([len(d) for d in docs])))
    # document frequency per hashed slot
    df = np.zeros(dim, np.float64)
    hashed_docs = []
    for d in docs:
        ids = np.asarray([tok.token_id(w) % dim for w in d], np.int64) \
            if d else np.zeros(0, np.int64)
        hashed_docs.append(ids)
        if len(ids):
            df[np.unique(ids)] += 1
    idf = np.log(1 + (n - df + 0.5) / (df + 0.5))

    out = np.zeros((n, dim), np.float32)
    for i, ids in enumerate(hashed_docs):
        if not len(ids):
            continue
        tf = np.bincount(ids, minlength=dim).astype(np.float64)
        dl = len(ids)
        w = idf * tf * (k1 + 1) / (tf + k1 * (1 - b + b * dl / avgdl))
        norm = math.sqrt(float(np.sum(w * w)))
        out[i] = (w / max(norm, 1e-9)).astype(np.float32)
    return out


def hybrid_features(embeddings: np.ndarray, texts: Sequence[str],
                    lam: float = 1.0, bm25_dim: int = 256) -> np.ndarray:
    """Concatenated feature space realizing lambda*L2 + (1-lambda)*BM25."""
    emb = np.asarray(embeddings, np.float32)
    if lam >= 1.0:
        return emb
    # scale embedding part to unit-ish norm so lambda weights are meaningful
    emb_n = emb / max(1e-9, float(np.median(np.linalg.norm(emb, axis=1))))
    bv = bm25_vectors(texts, dim=bm25_dim)
    return np.concatenate([math.sqrt(lam) * emb_n,
                           math.sqrt(1.0 - lam) * bv], axis=1)
