"""Baseline semantic-filter algorithms (paper §2.2) for comparison.

- Reference: one oracle call per tuple (Eq. 1) — O(|T|).
- Lotus: proxy-score cascade with learned (tau-, tau+) thresholds.
- BARGAIN: region-wise adaptive sampling with an accuracy target.

Both cascades invoke the *proxy* LLM on every tuple (the linear pass the
paper criticizes); our accounting separates proxy calls from oracle calls
so Fig. 4 analogues can weight them by model cost.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BaselineResult:
    mask: np.ndarray
    n_oracle_calls: int
    n_proxy_calls: int
    input_tokens: int
    output_tokens: int
    thresholds: tuple = ()
    extra: dict = dataclasses.field(default_factory=dict)


def reference_filter(n: int, oracle) -> BaselineResult:
    before = oracle.stats.n_calls
    labels = oracle(np.arange(n))
    st = oracle.stats
    return BaselineResult(mask=labels, n_oracle_calls=st.n_calls - before,
                          n_proxy_calls=0, input_tokens=st.input_tokens,
                          output_tokens=st.output_tokens)


def lotus_filter(n: int, proxy, oracle, sample_size: int = 200,
                 recall_target: float = 0.9, precision_target: float = 0.9,
                 seed: int = 0) -> BaselineResult:
    """Lotus-style cascade.

    1. proxy scores for ALL tuples (linear proxy pass);
    2. oracle-label a small sample; learn tau+ (precision) and tau-
       (recall) on the sample;
    3. score > tau+ -> True, score < tau- -> False, else oracle.
    Degenerate thresholds (overlapping score bands — the paper's Fig. 1(a)
    pathology) route (almost) everything to the oracle.
    """
    rng = np.random.default_rng(seed)
    ids = np.arange(n)
    _, scores = proxy(ids)

    sample = rng.choice(n, size=min(sample_size, n), replace=False)
    sample_labels = oracle(sample)
    s_scores = scores[sample]

    # tau+: smallest threshold with precision >= target on the sample
    order = np.argsort(-s_scores)
    sorted_lab = sample_labels[order]
    prec = np.cumsum(sorted_lab) / (np.arange(len(order)) + 1)
    ok = np.nonzero(prec >= precision_target)[0]
    tau_plus = s_scores[order][ok[-1]] if len(ok) else np.inf
    # tau-: largest threshold keeping recall >= target (few positives below)
    order2 = np.argsort(s_scores)
    sorted_lab2 = sample_labels[order2]
    pos_total = max(1, int(sample_labels.sum()))
    lost = np.cumsum(sorted_lab2) / pos_total
    ok2 = np.nonzero(lost <= 1 - recall_target)[0]
    tau_minus = s_scores[order2][ok2[-1]] if len(ok2) else -np.inf

    mask = np.zeros(n, dtype=bool)
    mask[scores > tau_plus] = True
    uncertain = ids[(scores <= tau_plus) & (scores >= tau_minus)]
    uncertain = np.setdiff1d(uncertain, sample, assume_unique=False)
    if len(uncertain):
        mask[uncertain] = oracle(uncertain)
    mask[sample] = sample_labels

    st, pt = oracle.stats, proxy.stats
    return BaselineResult(
        mask=mask, n_oracle_calls=st.n_calls, n_proxy_calls=pt.n_calls,
        input_tokens=st.input_tokens + pt.input_tokens,
        output_tokens=st.output_tokens + pt.output_tokens,
        thresholds=(float(tau_minus), float(tau_plus)),
        extra={"n_uncertain": int(len(uncertain))})


def bargain_filter(n: int, proxy, oracle, accuracy_target: float = 0.85,
                   tolerance: float = 0.05, n_regions: int = 20,
                   samples_per_region: int = 30, seed: int = 0
                   ) -> BaselineResult:
    """BARGAIN-style region-wise adaptive cascade.

    Partition tuples into proxy-score regions; from the highest region down,
    sample + oracle-test whether trusting the proxy in that region meets the
    accuracy target (one-sided binomial check with tolerance); stop at the
    first failing region; everything below the stop threshold goes to the
    oracle.
    """
    rng = np.random.default_rng(seed)
    ids = np.arange(n)
    proxy_labels, scores = proxy(ids)

    edges = np.quantile(scores, np.linspace(0, 1, n_regions + 1))
    region = np.clip(np.searchsorted(edges, scores, side="right") - 1,
                     0, n_regions - 1)

    mask = np.zeros(n, dtype=bool)
    trusted = np.zeros(n, dtype=bool)
    stop_region = n_regions  # regions >= stop trusted
    for r in range(n_regions - 1, -1, -1):
        members = ids[region == r]
        if len(members) == 0:
            continue
        take = min(samples_per_region, len(members))
        s = rng.choice(members, size=take, replace=False)
        lab = oracle(s)
        agree = float(np.mean(lab == proxy_labels[s]))
        # one-sided check with tolerance
        if agree + tolerance >= accuracy_target:
            stop_region = r
            mask[s] = lab
            trusted[members] = True
            mask[np.setdiff1d(members, s)] = proxy_labels[np.setdiff1d(members, s)]
        else:
            mask[s] = lab
            break
    rest = ids[(~trusted) & (region < stop_region)]
    # exclude already-sampled (oracle memo makes re-calls free, but be exact)
    if len(rest):
        mask[rest] = oracle(rest)

    st, pt = oracle.stats, proxy.stats
    return BaselineResult(
        mask=mask, n_oracle_calls=st.n_calls, n_proxy_calls=pt.n_calls,
        input_tokens=st.input_tokens + pt.input_tokens,
        output_tokens=st.output_tokens + pt.output_tokens,
        thresholds=(int(stop_region),),
        extra={"n_rest": int(len(rest))})
