"""K-means over tuple embeddings (paper phase 1, query-agnostic, offline).

Pure JAX: kmeans++ seeding, Lloyd iterations under lax.while_loop with an
on-device convergence test, and a mini-batch update path for incremental
table maintenance (paper §3.1 update handling).  The assignment step (the
compute hot-spot: N x K pairwise distances + argmin) goes through
``repro.kernels.kmeans.ops``, which dispatches to the Pallas TPU kernel on
TPU and the jnp reference elsewhere.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels.kmeans.ops import assign_clusters


def _plusplus_init(key, x, k: int):
    """kmeans++ seeding (host loop over k; k is small)."""
    n = x.shape[0]
    keys = jax.random.split(key, k)
    first = jax.random.randint(keys[0], (), 0, n)
    cents = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    d2 = jnp.sum(jnp.square(x - cents[0]), axis=-1)
    for i in range(1, k):
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        idx = jax.random.choice(keys[i], n, p=probs)
        cents = cents.at[i].set(x[idx])
        d2 = jnp.minimum(d2, jnp.sum(jnp.square(x - cents[i]), axis=-1))
    return cents


@partial(jax.jit, static_argnames=("k", "max_iters"))
def kmeans(key, x, k: int, max_iters: int = 50, tol: float = 1e-4):
    """Lloyd's algorithm.  x (N,D) -> (centroids (k,D), assign (N,), inertia).

    Empty clusters are re-seeded to the point farthest from its centroid.
    """
    n, d = x.shape
    cents0 = _plusplus_init(key, x, k)

    def step(state):
        cents, _, it, _ = state
        assign, dmin = assign_clusters(x, cents)
        counts = jnp.zeros((k,), x.dtype).at[assign].add(1.0)
        sums = jnp.zeros((k, d), x.dtype).at[assign].add(x)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0),
                        cents)
        # re-seed empties with the worst-fit point
        worst = jnp.argmax(dmin)
        new = jnp.where((counts[:, None] == 0), x[worst][None, :], new)
        shift = jnp.max(jnp.sum(jnp.square(new - cents), axis=-1))
        return new, assign, it + 1, shift

    def cond(state):
        _, _, it, shift = state
        return jnp.logical_and(it < max_iters, shift > tol)

    state = (cents0, jnp.zeros((n,), jnp.int32), jnp.int32(0), jnp.float32(jnp.inf))
    cents, _, _, _ = lax.while_loop(cond, step, state)
    assign, dmin = assign_clusters(x, cents)
    inertia = jnp.sum(dmin)
    return cents, assign, inertia


@jax.jit
def kmeans_predict(x, cents):
    assign, _ = assign_clusters(x, cents)
    return assign


def assign_to_nearest(embeddings, centroids) -> np.ndarray:
    """Host-side incremental assignment (paper §3.1 update handling).

    New or updated rows join the nearest *existing* centroid — no re-fit —
    so a table ``append``/``update`` patches the precluster cache instead of
    invalidating it.  Centroid drift accumulates across patches; callers
    that care can force a fresh ``kmeans`` fit under a new seed.
    """
    emb = jnp.asarray(np.asarray(embeddings, dtype=np.float32))
    return np.asarray(kmeans_predict(emb, jnp.asarray(centroids)))


@jax.jit
def minibatch_kmeans_update(cents, counts, batch):
    """Mini-batch K-means (Sculley'10) single step for incremental updates.

    counts (k,): running per-cluster sample counts.  Returns (cents, counts).
    """
    assign, _ = assign_clusters(batch, cents)
    ones = jnp.ones((batch.shape[0],), cents.dtype)
    counts = counts.at[assign].add(ones)
    lr = 1.0 / jnp.maximum(counts[assign], 1.0)  # per-sample rate
    # sequential-equivalent batched update: move each centroid toward the
    # mean of its new points scaled by accumulated count
    k = cents.shape[0]
    sums = jnp.zeros_like(cents).at[assign].add(batch * lr[:, None])
    hits = jnp.zeros((k,), cents.dtype).at[assign].add(lr)
    cents = cents * (1 - hits[:, None]) + sums + cents * 0.0
    return cents, counts


def distributed_kmeans_step(x_local, cents, mesh_axis: str = "data"):
    """One Lloyd step under shard_map: local partial sums + psum (multi-pod).

    Call inside shard_map with x sharded over ``mesh_axis``; centroids are
    replicated.  Returns updated centroids (replicated).
    """
    k, d = cents.shape
    assign, _ = assign_clusters(x_local, cents)
    sums = jnp.zeros((k, d), x_local.dtype).at[assign].add(x_local)
    counts = jnp.zeros((k,), x_local.dtype).at[assign].add(1.0)
    sums = lax.psum(sums, mesh_axis)
    counts = lax.psum(counts, mesh_axis)
    return jnp.where(counts[:, None] > 0,
                     sums / jnp.maximum(counts[:, None], 1.0), cents)
