"""LLM oracle interfaces: M(t, e) -> {True, False} plus accounting.

Two families:
- SyntheticOracle: ground-truth labels + a calibrated Bernoulli flip channel
  modelling LLM non-determinism (the paper runs temperature 0.7).  Used for
  statistically controlled benchmarks (Tables 2-5 analogues).
- ModelOracle: a real JAX backbone served through repro.serving; the binary
  decision is the yes/no logit margin at the first generated position —
  the TPU-friendly equivalent of the paper's output-token parse.

All oracles count calls and tokens (the paper's efficiency metrics) and
memoize by tuple id — the memo doubles as the §3.1 update cache and makes
the CSV driver restartable (fault tolerance).
"""
from __future__ import annotations

import contextlib
import dataclasses
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class OracleStats:
    n_calls: int = 0
    n_cached: int = 0
    input_tokens: int = 0
    output_tokens: int = 0
    # size of every *evaluated* batch (memo hits excluded) — the round
    # executor's key efficiency signal: one entry per model invocation
    batch_sizes: list = dataclasses.field(default_factory=list)

    def clone(self):
        return dataclasses.replace(self, batch_sizes=list(self.batch_sizes))

    def delta(self, before: "OracleStats") -> "OracleStats":
        """Accounting attributable to work since ``before`` (a clone)."""
        return OracleStats(
            n_calls=self.n_calls - before.n_calls,
            n_cached=self.n_cached - before.n_cached,
            input_tokens=self.input_tokens - before.input_tokens,
            output_tokens=self.output_tokens - before.output_tokens,
            batch_sizes=self.batch_sizes[len(before.batch_sizes):],
        )

    def merge(self, other: "OracleStats") -> "OracleStats":
        """Fold another stats object (typically a delta) into this one —
        the session-level run aggregate in ``repro.api``."""
        self.n_calls += other.n_calls
        self.n_cached += other.n_cached
        self.input_tokens += other.input_tokens
        self.output_tokens += other.output_tokens
        self.batch_sizes.extend(other.batch_sizes)
        return self

    @property
    def mean_batch_size(self) -> float:
        return (float(np.mean(self.batch_sizes))
                if self.batch_sizes else 0.0)

    def metrics_view(self) -> dict:
        """Unified-name view for ``MetricsRegistry.sync_from`` (this
        dataclass stays the per-oracle accounting of record; the view is
        read-only — see docs/observability.md)."""
        return {
            "oracle.calls": self.n_calls,
            "oracle.cached": self.n_cached,
            "oracle.input_tokens": self.input_tokens,
            "oracle.output_tokens": self.output_tokens,
            "oracle.mean_batch_size": self.mean_batch_size,
        }


@dataclasses.dataclass
class StatsScope:
    """Holder filled at ``BaseOracle.scope()`` exit with the block's delta."""
    delta: Optional[OracleStats] = None


class BaseOracle:
    """Batched, memoized oracle."""

    def __init__(self):
        self.stats = OracleStats()
        self._memo: dict[int, bool] = {}
        # durability hook: called as memo_hook(ids, labels) after every
        # fresh-evaluation commit (repro.service.log records the entries
        # so a restarted session replays them at zero oracle cost)
        self.memo_hook = None

    @contextlib.contextmanager
    def scope(self):
        """Attribute accounting to one plan node / pilot probe.

        Yields a ``StatsScope`` whose ``.delta`` is set on exit to the calls
        and tokens spent inside the with-block — the plan executor uses one
        scope per expression node so a shared or memoized oracle never
        inflates another node's efficiency metrics.
        """
        before = self.stats.clone()
        holder = StatsScope()
        try:
            yield holder
        finally:
            holder.delta = self.stats.delta(before)

    def _evaluate(self, ids: np.ndarray) -> np.ndarray:  # -> bool array
        raise NotImplementedError

    def _tokens_of(self, ids: np.ndarray) -> int:
        return int(len(ids)) * 64  # overridden where real text exists

    def _memo_split(self, ids):
        """Resolve memo hits; return (out, missing, missing_pos).

        ``out`` has hits filled in (misses still False); ``missing`` are the
        ids needing a model evaluation, ``missing_pos`` their positions.
        Counts cache hits exactly as ``__call__`` always has.
        """
        ids = np.asarray(ids, dtype=np.int64)
        out = np.zeros(len(ids), dtype=bool)
        missing, missing_pos = [], []
        for pos, i in enumerate(ids):
            if int(i) in self._memo:
                out[pos] = self._memo[int(i)]
                self.stats.n_cached += 1
            else:
                missing.append(int(i))
                missing_pos.append(pos)
        return out, missing, missing_pos

    def _memo_commit(self, out, missing, missing_pos, labels) -> np.ndarray:
        """Fold evaluated labels back: memo writes + stats, as ``__call__``."""
        mids = np.asarray(missing, dtype=np.int64)
        for i, lab in zip(missing, labels):
            self._memo[i] = bool(lab)
        out[missing_pos] = labels
        self.stats.n_calls += len(missing)
        self.stats.input_tokens += self._tokens_of(mids)
        self.stats.output_tokens += len(missing)  # 1 decision token each
        self.stats.batch_sizes.append(len(missing))
        if self.memo_hook is not None and len(missing):
            self.memo_hook(mids, np.asarray(labels, dtype=bool))
        return out

    def __call__(self, ids) -> np.ndarray:
        out, missing, missing_pos = self._memo_split(ids)
        if missing:
            labels = self._evaluate(np.asarray(missing, dtype=np.int64))
            out = self._memo_commit(out, missing, missing_pos, labels)
        return out

    # --- persistence (fault tolerance / §3.1 update cache) ---
    def memo_snapshot(self) -> dict:
        return dict(self._memo)

    def memo_restore(self, snap: dict):
        self._memo.update({int(k): bool(v) for k, v in snap.items()})

    def memo_invalidate(self, ids) -> int:
        """Drop per-id memo entries whose tuple *content* changed (§3.1
        updates): a memo keyed by tuple id is only valid while the tuple's
        payload is.  ``TableHandle.update`` calls this for every oracle the
        session has seen touch the table.  Returns entries dropped."""
        dropped = 0
        for i in np.asarray(ids, dtype=np.int64):
            if self._memo.pop(int(i), None) is not None:
                dropped += 1
        return dropped

    def memo_clear(self) -> int:
        """Drop the whole per-id memo.  Needed for *pair* oracles after a
        table mutation: pair ids ``i * len(right) + j`` reindex when the
        right table grows, so no per-id invalidation can be correct."""
        n = len(self._memo)
        self._memo.clear()
        return n


class SyntheticOracle(BaseOracle):
    def __init__(self, labels: np.ndarray, flip_prob: float = 0.0,
                 seed: int = 0, token_lens: Optional[np.ndarray] = None):
        super().__init__()
        self.labels = np.asarray(labels, dtype=bool)
        self.flip_prob = float(flip_prob)
        self.rng = np.random.default_rng(seed)
        self.token_lens = token_lens

    def _evaluate(self, ids):
        lab = self.labels[ids].copy()
        if self.flip_prob > 0:
            flips = self.rng.random(len(ids)) < self.flip_prob
            lab ^= flips
        return lab

    def _tokens_of(self, ids):
        if self.token_lens is None:
            return super()._tokens_of(ids)
        return int(np.sum(self.token_lens[ids]))


class ProxyModel:
    """Cascade proxy (Lotus/BARGAIN baselines): label + confidence score.

    Synthetic variant: score = calibated-or-miscalibrated sigmoid of the
    true margin.  ``concentration`` < 1 reproduces the paper's Fig. 1(a)
    pathology (scores bunched in a narrow band, weak label separation).
    """

    def __init__(self, labels: np.ndarray, quality: float = 1.5,
                 center: float = 0.5, concentration: float = 1.0,
                 seed: int = 1, token_lens: Optional[np.ndarray] = None):
        self.labels = np.asarray(labels, dtype=bool)
        rng = np.random.default_rng(seed)
        margin = (self.labels.astype(np.float64) * 2 - 1) * quality
        noise = rng.normal(0, 1.0, len(self.labels))
        raw = 1.0 / (1.0 + np.exp(-(margin + noise)))
        self.scores = center + (raw - 0.5) * concentration
        self.scores = np.clip(self.scores, 0.0, 1.0)
        self.stats = OracleStats()
        self.token_lens = token_lens

    def __call__(self, ids) -> tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids, dtype=np.int64)
        self.stats.n_calls += len(ids)
        if self.token_lens is not None:
            self.stats.input_tokens += int(np.sum(self.token_lens[ids]))
        else:
            self.stats.input_tokens += len(ids) * 64
        self.stats.output_tokens += len(ids)
        return self.scores[ids] > 0.5, self.scores[ids]


class ModelOracle(BaseOracle):
    """Oracle backed by a JAX backbone via the serving engine.

    decision(t) = logit("yes") > logit("no") at the first generated position
    for the prompt [instruction; predicate; tuple-text].
    """

    def __init__(self, engine, tokenizer, predicate: str,
                 texts: Sequence[str], yes_id: Optional[int] = None,
                 no_id: Optional[int] = None,
                 instruction: str = "Answer yes or no: does the text satisfy "
                                    "the condition?"):
        super().__init__()
        self.engine = engine
        self.tok = tokenizer
        self.predicate = predicate
        self.texts = texts
        self.instruction = instruction
        self.yes_id = yes_id if yes_id is not None else tokenizer.token_id("yes")
        self.no_id = no_id if no_id is not None else tokenizer.token_id("no")
        self._tok_cache: dict[int, list[int]] = {}

    def _prompt_ids(self, i: int):
        if i not in self._tok_cache:
            text = f"{self.instruction}\ncondition: {self.predicate}\ntext: {self.texts[i]}\nanswer:"
            self._tok_cache[i] = self.tok.encode(text)
        return self._tok_cache[i]

    def _evaluate(self, ids):
        # narrow fast path: only the (yes, no) logit pair leaves the
        # device.  Per-prompt (B, 2) token ids — the same einsum shape the
        # packed cross-oracle wave uses, so packed and per-oracle dispatch
        # produce bit-identical logits.
        pair = self.engine.first_token_logits(
            self.pack_prompts(ids), token_ids=self.pack_token_ids(len(ids)))
        return self.pack_labels(pair)

    def _tokens_of(self, ids):
        return int(sum(len(self._prompt_ids(int(i))) for i in ids))

    # --- cross-oracle packing protocol (service scheduler) ---
    # Oracles sharing ``pack_engine`` can have their prompts evaluated in
    # one engine wave: the scheduler concatenates ``pack_prompts`` outputs,
    # calls ``pack_engine.first_token_logits(prompts, token_ids=(B, 2))``
    # once, and hands each oracle its slice back through ``pack_labels``.
    @property
    def pack_engine(self):
        return self.engine

    def pack_prompts(self, ids):
        return [self._prompt_ids(int(i)) for i in ids]

    def pack_token_ids(self, n: int) -> np.ndarray:
        return np.tile(np.asarray([self.yes_id, self.no_id], np.int32),
                       (n, 1))

    def pack_labels(self, pair_logits) -> np.ndarray:
        return np.asarray(pair_logits[:, 0] > pair_logits[:, 1])


# --------------------------------------------------------------------------
# Cross-oracle packed evaluation: one engine wave per (tick, length-bucket)
# across every oracle sharing an engine — the service scheduler's fused
# serving path.  Per-oracle memo/stats accounting is byte-identical to
# calling each oracle directly (same _memo_split/_memo_commit helpers).
# --------------------------------------------------------------------------
def evaluate_packed(requests, pack: bool = True):
    """Evaluate ``[(oracle, ids), ...]`` with cross-oracle prompt packing.

    Oracles exposing the pack protocol (``pack_engine``/``pack_prompts``/
    ``pack_labels`` — ``ModelOracle``) and sharing an engine contribute
    their memo-missing prompts to ONE ``first_token_logits`` wave; the
    engine's bucket batcher length-buckets them across oracles and results
    scatter back per ``(oracle, ids)`` slice.  Other oracles evaluate
    normally, in request order.  A request whose oracle appears more than
    once in the wave defers its later occurrences to a follow-up pass, so
    memoization sees the same order a serial drain would produce.

    Returns ``(outcomes, info)``: ``outcomes[i]`` is the label array or the
    exception that request hit; ``info`` holds ``tokens`` (oracle input +
    decision tokens spent) and ``truncated`` (prompts the engine batcher
    left-truncated during this call).
    """
    outcomes: list = [None] * len(requests)
    info = {"tokens": 0, "truncated": 0}
    remaining = list(enumerate(requests))
    while remaining:
        seen_oracles: set = set()
        next_pass = []
        packable: dict = {}   # id(engine) -> [(idx, oracle, split), ...]
        engines: dict = {}
        for idx, (oracle, ids) in remaining:
            if id(oracle) in seen_oracles:
                next_pass.append((idx, (oracle, ids)))
                continue
            seen_oracles.add(id(oracle))
            engine = getattr(oracle, "pack_engine", None) if pack else None
            if engine is None:
                try:
                    before = oracle.stats.clone()
                    outcomes[idx] = oracle(np.asarray(ids))
                    d = oracle.stats.delta(before)
                    info["tokens"] += d.input_tokens + d.output_tokens
                except BaseException as e:
                    outcomes[idx] = e
                continue
            split = oracle._memo_split(ids)
            engines[id(engine)] = engine
            packable.setdefault(id(engine), []).append((idx, oracle, split))
        for ekey, group in packable.items():
            engine = engines[ekey]
            prompts, tok_rows = [], []
            for _, oracle, (_, missing, _) in group:
                prompts.extend(oracle.pack_prompts(missing))
                tok_rows.append(oracle.pack_token_ids(len(missing)))
            if prompts:
                trunc0 = engine.batcher.stats["truncated_prompts"]
                try:
                    pair = engine.first_token_logits(
                        prompts, token_ids=np.concatenate(tok_rows))
                except BaseException as e:
                    for idx, _, _ in group:
                        outcomes[idx] = e
                    continue
                info["truncated"] += (
                    engine.batcher.stats["truncated_prompts"] - trunc0)
            k = 0
            for idx, oracle, (out, missing, missing_pos) in group:
                if missing:
                    labels = oracle.pack_labels(pair[k:k + len(missing)])
                    k += len(missing)
                    out = oracle._memo_commit(out, missing, missing_pos,
                                              labels)
                    info["tokens"] += (oracle._tokens_of(
                        np.asarray(missing, np.int64)) + len(missing))
                outcomes[idx] = out
        remaining = next_pass
    return outcomes, info


# --------------------------------------------------------------------------
# Round dispatch: the executor submits one cross-cluster batch per wave and
# collects the labels later, so oracle prefill for wave k+1 can overlap the
# device voting of wave k (``pipeline_depth`` > 1 in the CSV driver).
# Both dispatchers return a concurrent.futures.Future.
# --------------------------------------------------------------------------
class SyncOracleDispatcher:
    """Evaluates at submit time — the zero-overlap default (depth 1)."""

    def __init__(self, oracle):
        self.oracle = oracle

    def submit(self, ids) -> Future:
        f = Future()
        try:
            f.set_result(self.oracle(ids))
        except BaseException as e:  # propagate at result()
            f.set_exception(e)
        return f

    def close(self):
        pass


class AsyncOracleDispatcher:
    """Single worker thread, strict FIFO: batches are evaluated in submission
    order, so memoization and any stateful oracle RNG (SyntheticOracle's flip
    stream) behave bit-identically to synchronous dispatch.

    ``oracle`` may be omitted when every ``submit`` names its own — the
    multi-oracle form the service scheduler uses to drive one merged
    cross-query dispatch through a single FIFO lane (per-oracle evaluation
    order is then exactly submission order, preserving each query's
    memo/flip-stream state)."""

    def __init__(self, oracle=None):
        self.oracle = oracle
        self._pool = ThreadPoolExecutor(max_workers=1)

    def submit(self, ids, oracle=None) -> Future:
        target = oracle if oracle is not None else self.oracle
        if target is None:
            raise ValueError("dispatcher built without a default oracle; "
                             "pass oracle= to submit()")
        return self._pool.submit(target, np.asarray(ids))

    def submit_call(self, fn, *args) -> Future:
        """Queue an arbitrary callable on the same FIFO lane — the service
        scheduler submits one packed *wave* per call so prefill of wave
        k+1 can overlap host-side voting on wave k's parked tasks."""
        return self._pool.submit(fn, *args)

    def close(self):
        self._pool.shutdown(wait=True)
