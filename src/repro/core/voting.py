"""Voting strategies (paper Algorithms 2 & 3).

UniVote: one cluster-level score |O+|/|O| compared to (lb, ub).
SimVote: per-tuple similarity-weighted score; the (N_unsampled x M_sampled)
similarity matrix is streamed through the Pallas simvote kernel on TPU
(never materialized in HBM) and through the jnp reference elsewhere.

Similarity: Gaussian kernel sim(ei,ej) = exp(-||ei-ej||^2 / (2 tau^2)) with
a self-tuning bandwidth (median sampled-pair distance) unless given.  The
paper leaves sim() unspecified; a monotone-decreasing function of L2
distance matches its Fig. 2 analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.simvote.ops import simvote_scores


@dataclasses.dataclass
class VoteResult:
    decided_true: np.ndarray  # indices (into the cluster) voted True
    decided_false: np.ndarray
    undetermined: np.ndarray
    scores: np.ndarray  # per unsampled tuple (SimVote) or scalar (UniVote)


def uni_vote(sample_labels: np.ndarray, n_unsampled: int, lb: float,
             ub: float) -> VoteResult:
    """Algorithm 2: every unsampled tuple gets the same cluster-level vote."""
    score = float(np.mean(sample_labels)) if len(sample_labels) else 0.0
    idx = np.arange(n_unsampled)
    empty = np.array([], dtype=np.int64)
    if score >= ub:
        return VoteResult(idx, empty, empty, np.full(n_unsampled, score))
    if score <= lb:
        return VoteResult(empty, idx, empty, np.full(n_unsampled, score))
    return VoteResult(empty, empty, idx, np.full(n_unsampled, score))


def default_bandwidth(emb_sampled: np.ndarray) -> float:
    """Self-tuning tau: median pairwise distance over (a subset of) samples."""
    m = emb_sampled.shape[0]
    if m < 2:
        return 1.0
    sub = emb_sampled[: min(m, 256)]
    d2 = np.sum((sub[:, None, :] - sub[None, :, :]) ** 2, axis=-1)
    med = float(np.median(np.sqrt(d2[np.triu_indices(len(sub), 1)])))
    return max(med, 1e-6)


def sim_vote(emb_unsampled: np.ndarray, emb_sampled: np.ndarray,
             sample_labels: np.ndarray, lb: float, ub: float,
             bandwidth: Optional[float] = None) -> VoteResult:
    """Algorithm 3: per-tuple similarity-weighted voting."""
    n = emb_unsampled.shape[0]
    idx = np.arange(n)
    empty = np.array([], dtype=np.int64)
    if n == 0:
        z = np.zeros(0)
        return VoteResult(empty, empty, empty, z)
    tau = bandwidth or default_bandwidth(emb_sampled)
    scores = np.asarray(simvote_scores(
        jnp.asarray(emb_unsampled, jnp.float32),
        jnp.asarray(emb_sampled, jnp.float32),
        jnp.asarray(sample_labels, jnp.float32), tau))
    dec_t = idx[scores >= ub]
    dec_f = idx[scores <= lb]
    und = idx[(scores > lb) & (scores < ub)]
    return VoteResult(dec_t, dec_f, und, scores)
