"""Voting strategies (paper Algorithms 2 & 3).

UniVote: one cluster-level score |O+|/|O| compared to (lb, ub).
SimVote: per-tuple similarity-weighted score; the (N_unsampled x M_sampled)
similarity matrix is streamed through the Pallas simvote kernel on TPU
(never materialized in HBM) and through the jnp reference elsewhere.

Similarity: Gaussian kernel sim(ei,ej) = exp(-||ei-ej||^2 / (2 tau^2)) with
a self-tuning bandwidth (median sampled-pair distance) unless given.  The
paper leaves sim() unspecified; a monotone-decreasing function of L2
distance matches its Fig. 2 analysis.

Batch entry points (``uni_vote_batch`` / ``sim_vote_batch``) vote ALL
clusters of a re-clustering round at once: one segmented device dispatch for
SimVote, one vectorized reduction for UniVote, with decisions identical to
the per-cluster calls.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.kernels.simvote.ops import simvote_scores, simvote_scores_segmented


@dataclasses.dataclass
class VoteResult:
    decided_true: np.ndarray  # indices (into the cluster) voted True
    decided_false: np.ndarray
    undetermined: np.ndarray
    scores: np.ndarray  # per unsampled tuple (SimVote) or scalar (UniVote)


def _partition_by_score(scores: np.ndarray, lb: float, ub: float
                        ) -> VoteResult:
    idx = np.arange(len(scores))
    return VoteResult(idx[scores >= ub], idx[scores <= lb],
                      idx[(scores > lb) & (scores < ub)], scores)


def uni_vote(sample_labels: np.ndarray, n_unsampled: int, lb: float,
             ub: float) -> VoteResult:
    """Algorithm 2: every unsampled tuple gets the same cluster-level vote.

    An empty sample carries no evidence: everything is undetermined (a 0.0
    default score would silently vote False whenever lb >= 0).
    """
    idx = np.arange(n_unsampled)
    empty = np.array([], dtype=np.int64)
    if len(sample_labels) == 0:
        return VoteResult(empty, empty, idx, np.full(n_unsampled, np.nan))
    score = float(np.mean(sample_labels))
    if score >= ub:
        return VoteResult(idx, empty, empty, np.full(n_unsampled, score))
    if score <= lb:
        return VoteResult(empty, idx, empty, np.full(n_unsampled, score))
    return VoteResult(empty, empty, idx, np.full(n_unsampled, score))


def uni_vote_batch(sample_labels: Sequence[np.ndarray],
                   n_unsampled: Sequence[int], lb: float, ub: float
                   ) -> list[VoteResult]:
    """Algorithm 2 over every cluster of a round in one call.

    ``sample_labels[c]`` votes for ``n_unsampled[c]`` tuples.  Each cluster's
    score is computed by the exact ``uni_vote`` expression — UniVote has no
    device work to batch (one scalar mean per cluster), and reproducing
    ``np.mean``'s input-dtype arithmetic is what keeps round-executor
    decisions bit-identical to the sequential driver even when a score lands
    exactly on a threshold (float32 1/10 != float64 1/10).
    """
    return [uni_vote(np.asarray(s), int(n_c), lb, ub)
            for s, n_c in zip(sample_labels, n_unsampled)]


def vote_clusters(kind: str, sample_labels: Sequence[np.ndarray],
                  n_unsampled: Sequence[int], lb: float, ub: float,
                  emb_unsampled: Optional[Sequence[np.ndarray]] = None,
                  emb_sampled: Optional[Sequence[np.ndarray]] = None,
                  bandwidth: Optional[float] = None) -> list[VoteResult]:
    """One segmented voting dispatch for a round, either strategy.

    The CSV round executor and the semantic join share this entry point:
    ``kind="uni"`` needs only per-cluster sample labels and unsampled counts;
    ``kind="sim"`` additionally takes the per-cluster embedding lists (for a
    join these are lazily built pair embeddings).  Decisions are identical to
    the per-cluster ``uni_vote`` / ``sim_vote`` calls.
    """
    labels = [np.asarray(s, np.float32) for s in sample_labels]
    if kind == "sim":
        assert emb_unsampled is not None and emb_sampled is not None
        return sim_vote_batch(emb_unsampled, emb_sampled, labels, lb, ub,
                              bandwidth)
    if kind != "uni":
        raise ValueError(f"unknown vote kind {kind!r}; expected 'uni' or 'sim'")
    return uni_vote_batch(labels, [int(c) for c in n_unsampled], lb, ub)


def default_bandwidth(emb_sampled: np.ndarray) -> float:
    """Self-tuning tau: median pairwise distance over (a subset of) samples."""
    m = emb_sampled.shape[0]
    if m < 2:
        return 1.0
    sub = emb_sampled[: min(m, 256)]
    d2 = np.sum((sub[:, None, :] - sub[None, :, :]) ** 2, axis=-1)
    med = float(np.median(np.sqrt(d2[np.triu_indices(len(sub), 1)])))
    return max(med, 1e-6)


def sim_vote(emb_unsampled: np.ndarray, emb_sampled: np.ndarray,
             sample_labels: np.ndarray, lb: float, ub: float,
             bandwidth: Optional[float] = None) -> VoteResult:
    """Algorithm 3: per-tuple similarity-weighted voting.

    As with ``uni_vote``, an empty sample carries no evidence — everything
    is undetermined (a zero denominator would otherwise score 0.0 and
    silently vote False whenever lb >= 0).
    """
    n = emb_unsampled.shape[0]
    idx = np.arange(n)
    empty = np.array([], dtype=np.int64)
    if n == 0:
        z = np.zeros(0)
        return VoteResult(empty, empty, empty, z)
    if len(sample_labels) == 0:
        return VoteResult(empty, empty, idx, np.full(n, np.nan))
    tau = bandwidth or default_bandwidth(emb_sampled)
    scores = np.asarray(simvote_scores(
        jnp.asarray(emb_unsampled, jnp.float32),
        jnp.asarray(emb_sampled, jnp.float32),
        jnp.asarray(sample_labels, jnp.float32), tau))
    return _partition_by_score(scores, lb, ub)


def sim_vote_batch(emb_unsampled: Sequence[np.ndarray],
                   emb_sampled: Sequence[np.ndarray],
                   sample_labels: Sequence[np.ndarray], lb: float, ub: float,
                   bandwidth: Optional[float] = None) -> list[VoteResult]:
    """Algorithm 3 for every cluster of a round in ONE device dispatch.

    Per-cluster (x_c, s_c, y_c) ragged inputs are packed into a padded
    (C, max_m, D) sample tensor plus a concatenated unsampled matrix and
    scored by the segmented simvote kernel; bandwidths stay per-cluster
    (``default_bandwidth`` of each cluster's own sample, matching the
    sequential path).
    """
    c = len(emb_unsampled)
    counts = np.array([len(x) for x in emb_unsampled], np.int64)
    out: list[Optional[VoteResult]] = [None] * c
    empty = np.array([], dtype=np.int64)
    # clusters with no unsampled rows have nothing to vote on; clusters with
    # an empty sample have no evidence (undetermined, matching sim_vote)
    live = [ci for ci in range(c)
            if counts[ci] > 0 and len(sample_labels[ci]) > 0]
    for ci in range(c):
        if counts[ci] == 0:
            out[ci] = VoteResult(empty, empty, empty, np.zeros(0))
        elif len(sample_labels[ci]) == 0:
            out[ci] = VoteResult(empty, empty, np.arange(counts[ci]),
                                 np.full(int(counts[ci]), np.nan))
    if not live:
        return out  # type: ignore[return-value]

    d = np.asarray(emb_unsampled[live[0]]).shape[1]
    max_m = max(len(emb_sampled[ci]) for ci in live)
    s_pad = np.zeros((len(live), max_m, d), np.float32)
    y_pad = -np.ones((len(live), max_m), np.float32)
    taus = np.empty(len(live), np.float64)
    for r, ci in enumerate(live):
        m_c = len(emb_sampled[ci])
        s_pad[r, :m_c] = emb_sampled[ci]
        y_pad[r, :m_c] = sample_labels[ci]
        taus[r] = bandwidth or default_bandwidth(np.asarray(emb_sampled[ci]))
    x_all = np.concatenate([np.asarray(emb_unsampled[ci], np.float32)
                            for ci in live])
    scores_all = np.asarray(simvote_scores_segmented(
        jnp.asarray(x_all), counts[live], jnp.asarray(s_pad),
        jnp.asarray(y_pad), taus))
    stop = np.cumsum(counts[live])
    for r, ci in enumerate(live):
        seg = scores_all[stop[r] - counts[ci]:stop[r]]
        out[ci] = _partition_by_score(seg, lb, ub)
    return out  # type: ignore[return-value]
