"""Semantic-operator API: the user-facing declarative layer (Lotus-style).

``SemanticTable`` holds texts + (lazily computed) embeddings and exposes
``sem_filter`` with selectable execution methods.  The planner derives the
sample ratio from a user error tolerance via the paper's theorems and keeps
per-predicate call caches (restart-safe, update-safe).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.baselines import bargain_filter, lotus_filter, reference_filter
from repro.core.csv_filter import CSVConfig, FilterResult, semantic_filter


class SemanticTable:
    """A table of tuples with text payloads and a semantic-filter operator."""

    def __init__(self, texts: Sequence[str] = None, embeddings=None,
                 embedder: Callable = None):
        assert texts is not None or embeddings is not None
        self.texts = list(texts) if texts is not None else None
        self._embeddings = (np.asarray(embeddings, np.float32)
                            if embeddings is not None else None)
        self._embedder = embedder
        # keyed by (n_clusters, seed); shared by sem_filter, the plan
        # executor's cascade subsets, and each side of a semantic join
        self._assign_cache: dict[tuple[int, int], np.ndarray] = {}

    def __len__(self):
        if self.texts is not None:
            return len(self.texts)
        return len(self._embeddings)

    @property
    def embeddings(self) -> np.ndarray:
        if self._embeddings is None:
            assert self._embedder is not None, "no embeddings and no embedder"
            self._embeddings = np.asarray(self._embedder(self.texts), np.float32)
        return self._embeddings

    def precluster(self, n_clusters: int, seed: int = 0) -> np.ndarray:
        """Offline phase: cluster once, reuse across predicates."""
        key = (n_clusters, seed)
        if key not in self._assign_cache:
            import jax
            import jax.numpy as jnp
            from repro.core.clustering import kmeans
            _, assign, _ = kmeans(jax.random.key(seed),
                                  jnp.asarray(self.embeddings), n_clusters)
            self._assign_cache[key] = np.asarray(assign)
        return self._assign_cache[key]

    def sem_filter(self, oracle, method: str = "csv",
                   cfg: Optional[CSVConfig] = None, proxy=None,
                   reuse_clustering: bool = True,
                   executor: Optional[str] = None,
                   pipeline_depth: Optional[int] = None, **kw):
        """Evaluate a semantic predicate.

        method: "csv" (UniVote), "csv-sim" (SimVote), "reference",
                "lotus", "bargain".
        executor / pipeline_depth: physical-plan knobs forwarded to
        ``CSVConfig`` — "round" (default) batches every live cluster's
        sample into one oracle call per round and votes all clusters in one
        segmented dispatch; pipeline_depth > 1 overlaps oracle prefill of
        the next wave with voting of the current one.
        """
        n = len(self)
        if method == "reference":
            return reference_filter(n, oracle)
        if method == "lotus":
            assert proxy is not None
            return lotus_filter(n, proxy, oracle, **kw)
        if method == "bargain":
            assert proxy is not None
            return bargain_filter(n, proxy, oracle, **kw)
        cfg = cfg or CSVConfig()
        if method == "csv-sim":
            cfg = dataclasses.replace(cfg, vote="sim")
        overrides = {}
        if executor is not None:
            overrides["executor"] = executor
        if pipeline_depth is not None:
            overrides["pipeline_depth"] = pipeline_depth
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        assign = (self.precluster(cfg.n_clusters, cfg.seed)
                  if reuse_clustering else None)
        return semantic_filter(self.embeddings, oracle, cfg,
                               precomputed_assign=assign)

    def sem_filter_expr(self, expr, cfg: Optional[CSVConfig] = None,
                        optimize: bool = True, pilot_size: int = 32,
                        reuse_clustering: bool = True, **kw):
        """Evaluate a composed predicate expression (``repro.plan`` AST).

        expr: ``Pred`` / ``And`` / ``Or`` / ``Not`` tree; each leaf carries
        its own oracle.  Conjuncts/disjuncts are cost-ordered from a pilot
        sample (``optimize=True``) and evaluated as a short-circuit cascade:
        tuples decided by an earlier node are masked out of later CSV runs.
        Returns a ``PlanResult``.
        """
        from repro.plan.executor import PlanExecutor
        return PlanExecutor(self, cfg=cfg, optimize=optimize,
                            pilot_size=pilot_size,
                            reuse_clustering=reuse_clustering, **kw).run(expr)

    def sem_join(self, right: "SemanticTable", oracle, cfg=None,
                 reuse_clustering: bool = True):
        """CSV-backed semantic join against another table.

        oracle: callable over *pair ids* ``i * len(right) + j`` (see
        ``repro.plan.join.pair_ids``).  Both sides' offline clusterings come
        from the tables' precluster caches.  Returns a ``JoinResult``.
        """
        from repro.plan.join import JoinConfig, sem_join
        cfg = cfg or JoinConfig()
        assign_l = assign_r = None
        if reuse_clustering:
            assign_l = self.precluster(cfg.n_clusters_left, cfg.seed)
            assign_r = right.precluster(cfg.n_clusters_right, cfg.seed)
        return sem_join(self.embeddings, right.embeddings, oracle, cfg,
                        assign_left=assign_l, assign_right=assign_r)


def accuracy_f1(pred: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """The paper's quality metrics."""
    pred = np.asarray(pred, bool)
    truth = np.asarray(truth, bool)
    acc = float(np.mean(pred == truth))
    tp = float(np.sum(pred & truth))
    fp = float(np.sum(pred & ~truth))
    fn = float(np.sum(~pred & truth))
    prec = tp / max(tp + fp, 1e-9)
    rec = tp / max(tp + fn, 1e-9)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return acc, f1
