"""Semantic-operator API: the legacy user-facing layer (Lotus-style).

``SemanticTable`` holds texts + (lazily computed) embeddings.  Its query
methods — ``sem_filter``, ``sem_filter_expr``, ``sem_join`` — are now thin
**deprecated shims** over the canonical lazy Session/Query API in
``repro.api``: each call builds a one-shot query and collects it
immediately, producing bit-identical masks and oracle call counts (asserted
in tests/test_api.py).  New code should use ``repro.api.Session`` directly;
see docs/api.md for the migration table.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.csv_filter import CSVConfig

_FILTER_METHODS = ("csv", "csv-sim", "reference", "lotus", "bargain")


def _deprecation_msg(old: str, new: str) -> str:
    """Shims warn with ``stacklevel=2`` at their own top line so the warning
    is attributed to the *caller* of the public method.  (The previous
    helper-issued warning hardcoded the helper's stack depth — correct only
    for one exact nesting, and silently wrong the moment the shim body moved
    the call; tests/test_api.py now asserts the reported location.)"""
    return f"{old} is deprecated; use {new} (see docs/api.md)"


class SemanticTable:
    """A table of tuples with text payloads and a semantic-filter operator."""

    def __init__(self, texts: Optional[Sequence[str]] = None, embeddings=None,
                 embedder: Optional[Callable] = None):
        if texts is None and embeddings is None:
            raise ValueError("SemanticTable needs texts and/or embeddings")
        self.texts = list(texts) if texts is not None else None
        self._embeddings = (np.asarray(embeddings, np.float32)
                            if embeddings is not None else None)
        self._embedder = embedder
        # legacy per-instance clustering cache keyed by (n_clusters, seed),
        # holding (assignment, centroids): centroids stay around so table
        # mutations can patch the assignment incrementally (nearest-centroid)
        # instead of re-running k-means.  The session layer keys its cache by
        # (table id, n_clusters, seed) and delegates computation here, so
        # both stay coherent.
        self._assign_cache: dict[tuple[int, int],
                                 tuple[np.ndarray, np.ndarray]] = {}
        self._api_handle = None  # lazily-created repro.api handle (shims)

    def __len__(self):
        if self.texts is not None:
            return len(self.texts)
        return len(self._embeddings)

    @property
    def embeddings(self) -> np.ndarray:
        if self._embeddings is None:
            if self._embedder is None:
                raise ValueError("table has no embeddings and no embedder")
            self._embeddings = np.asarray(self._embedder(self.texts), np.float32)
        return self._embeddings

    def precluster(self, n_clusters: int, seed: int = 0) -> np.ndarray:
        """Offline phase: cluster once, reuse across predicates."""
        return self.precluster_full(n_clusters, seed)[0]

    def precluster_full(self, n_clusters: int, seed: int = 0
                        ) -> tuple[np.ndarray, np.ndarray]:
        """(assignment, centroids) — centroids power incremental updates."""
        key = (n_clusters, seed)
        if key not in self._assign_cache:
            import jax
            import jax.numpy as jnp
            from repro.core.clustering import kmeans
            cents, assign, _ = kmeans(jax.random.key(seed),
                                      jnp.asarray(self.embeddings), n_clusters)
            self._assign_cache[key] = (np.asarray(assign), np.asarray(cents))
        return self._assign_cache[key]

    # --------------------------------------------------- incremental updates
    # Plumbing for ``repro.api.TableHandle.append``/``update``: mutate the
    # payload in place and PATCH every cached clustering (new/changed rows
    # join the nearest existing centroid) instead of dropping it.  Returns
    # {(n_clusters, seed): (patched assignment, touched cluster ids)} so the
    # session layer can refresh its own cache and mark clusters dirty.

    def _append_rows(self, texts: Optional[Sequence[str]],
                     embeddings: Optional[np.ndarray]) -> dict:
        # validate EVERYTHING before mutating: a partial append (texts
        # extended, embeddings not) would corrupt the table invariant
        new = (np.asarray(embeddings, np.float32)
               if embeddings is not None else None)
        if self.texts is not None and texts is None:
            raise ValueError("table holds texts; append needs texts=")
        if self.texts is None and texts is not None:
            # mirror of _update_rows' "no texts to update": silently
            # dropping the payloads would orphan the appended rows
            raise ValueError("table has no texts; append embeddings only")
        if texts is not None and new is not None and len(texts) != len(new):
            raise ValueError(f"append got {len(texts)} texts but "
                             f"{len(new)} embedding rows")
        if self._embeddings is None:
            if new is not None:
                # silently dropping them would re-embed these rows from
                # text later, diverging from what the caller supplied
                raise ValueError(
                    "table embeddings are still lazy; materialize them "
                    "first (access .embeddings) or append texts only")
            self.texts.extend(texts)
            return {}  # embeddings still lazy: nothing clustered yet
        if new is None:
            raise ValueError("table has materialized embeddings; append "
                             "needs embeddings (or an embedder)")
        if new.ndim != 2 or new.shape[1] != self._embeddings.shape[1]:
            raise ValueError(f"append embeddings have shape {new.shape}; "
                             f"expected (*, {self._embeddings.shape[1]})")
        if self.texts is not None:
            self.texts.extend(texts)
        from repro.core.clustering import assign_to_nearest
        touched: dict = {}
        for key, (assign, cents) in self._assign_cache.items():
            add = assign_to_nearest(new, cents)
            patched = np.concatenate([assign, add])
            self._assign_cache[key] = (patched, cents)
            touched[key] = (patched, np.unique(add))
        self._embeddings = np.concatenate([self._embeddings, new])
        return touched

    def _update_rows(self, ids: np.ndarray, texts: Optional[Sequence[str]],
                     embeddings: Optional[np.ndarray]) -> dict:
        # validate EVERYTHING before mutating (same rule as _append_rows):
        # a partial update would leave new texts against old embeddings
        ids = np.asarray(ids, dtype=np.int64)
        new = (np.asarray(embeddings, np.float32)
               if embeddings is not None else None)
        if texts is not None and self.texts is None:
            raise ValueError("table has no texts to update")
        if texts is not None and len(texts) != len(ids):
            raise ValueError(f"update got {len(ids)} ids but "
                             f"{len(texts)} texts")
        if new is not None and len(new) != len(ids):
            # numpy would silently broadcast/partially assign otherwise
            raise ValueError(f"update got {len(ids)} ids but "
                             f"{len(new)} embedding rows")
        if new is not None and self._embeddings is None:
            raise ValueError(
                "table embeddings are still lazy; materialize them first "
                "(access .embeddings) or update texts only")
        if new is not None and (new.ndim != 2
                                or new.shape[1] != self._embeddings.shape[1]):
            raise ValueError(f"update embeddings have shape {new.shape}; "
                             f"expected (*, {self._embeddings.shape[1]})")
        if len(ids) and (ids.min() < 0 or ids.max() >= len(self)):
            raise IndexError(f"update ids out of range for table of "
                             f"{len(self)} rows")
        if texts is not None:
            for i, t in zip(ids, texts):
                self.texts[int(i)] = t
        if new is None:
            return {}
        from repro.core.clustering import assign_to_nearest
        touched: dict = {}
        for key, (assign, cents) in self._assign_cache.items():
            old_clusters = np.unique(assign[ids])
            add = assign_to_nearest(new, cents)
            patched = assign.copy()
            patched[ids] = add
            self._assign_cache[key] = (patched, cents)
            touched[key] = (patched,
                            np.unique(np.concatenate([old_clusters, add])))
        self._embeddings[ids] = new
        return touched

    def _handle(self):
        """The session-layer handle backing the deprecation shims (one
        private Session per table, created on first legacy call)."""
        if self._api_handle is None:
            from repro.api import Session
            self._api_handle = Session().table(table=self)
        return self._api_handle

    def sem_filter(self, oracle, method: str = "csv",
                   cfg: Optional[CSVConfig] = None, proxy=None,
                   reuse_clustering: bool = True,
                   executor: Optional[str] = None,
                   pipeline_depth: Optional[int] = None, **kw):
        """Deprecated: use ``repro.api.Session``.  Evaluate one predicate.

        method: "csv" (UniVote), "csv-sim" (SimVote), "reference",
                "lotus", "bargain".
        executor / pipeline_depth: physical-plan knobs ("round" batches every
        live cluster's sample into one oracle call per round; depth > 1
        overlaps oracle prefill with voting).  Baseline ``**kw`` (e.g.
        ``sample_size``) rides along unchanged.
        """
        warnings.warn(_deprecation_msg(
            "SemanticTable.sem_filter",
            "Session.table(...).filter(...).collect()"),
            DeprecationWarning, stacklevel=2)
        if method not in _FILTER_METHODS:
            raise ValueError(f"unknown method {method!r}; "
                             f"expected one of {_FILTER_METHODS}")
        if method in ("lotus", "bargain") and proxy is None:
            raise ValueError(f"method {method!r} requires a proxy model")
        from repro.api import ExecutionPolicy
        # reuse_memo/reuse_stats off: the legacy surface promises
        # run-by-run bit-identity with the direct machinery, so the shim's
        # private session must never replay across calls
        pol = ExecutionPolicy.from_csv_config(
            cfg or CSVConfig(), method=method,
            reuse_clustering=reuse_clustering, baseline=dict(kw),
            reuse_memo=False, reuse_stats=False)
        if executor is not None:
            pol = pol.replace(executor=executor)
        if pipeline_depth is not None:
            pol = pol.replace(pipeline_depth=pipeline_depth)
        q = self._handle().filter(oracle, name="pred", proxy=proxy,
                                  policy=pol)
        res = q.collect()
        if method in ("reference", "lotus", "bargain"):
            return res.raw                    # BaselineResult, as before
        return res.raw.results["pred"]        # the node's FilterResult

    def sem_filter_expr(self, expr, cfg: Optional[CSVConfig] = None,
                        optimize: bool = True, pilot_size: int = 32,
                        reuse_clustering: bool = True):
        """Deprecated: use ``Session.table(...).filter(expr)``.  Evaluate a
        composed predicate expression (``repro.plan`` AST) as a cost-ordered
        short-circuit cascade.  Returns a ``PlanResult``.
        """
        warnings.warn(_deprecation_msg(
            "SemanticTable.sem_filter_expr",
            "Session.table(...).filter(expr).collect()"),
            DeprecationWarning, stacklevel=2)
        from repro.api import ExecutionPolicy
        pol = ExecutionPolicy.from_csv_config(
            cfg or CSVConfig(), optimize=optimize, pilot_size=pilot_size,
            reuse_clustering=reuse_clustering,
            reuse_memo=False, reuse_stats=False)
        return self._handle().filter(expr, policy=pol).collect().raw

    def sem_join(self, right: "SemanticTable", oracle, cfg=None,
                 reuse_clustering: bool = True):
        """Deprecated: use ``Session.table(...).join(...)``.  CSV-backed
        semantic join; oracle is called over *pair ids*
        ``i * len(right) + j`` (see ``repro.plan.join.pair_ids``).  Returns
        a ``JoinResult``.
        """
        warnings.warn(_deprecation_msg(
            "SemanticTable.sem_join",
            "Session.table(...).join(right, oracle).collect()"),
            DeprecationWarning, stacklevel=2)
        from repro.api import ExecutionPolicy
        from repro.plan.join import JoinConfig
        pol = ExecutionPolicy.from_join_config(
            cfg or JoinConfig(), reuse_clustering=reuse_clustering)
        handle = self._handle()
        return handle.join(right, oracle, policy=pol).collect().raw


def accuracy_f1(pred: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """The paper's quality metrics."""
    pred = np.asarray(pred, bool)
    truth = np.asarray(truth, bool)
    acc = float(np.mean(pred == truth))
    tp = float(np.sum(pred & truth))
    fp = float(np.sum(pred & ~truth))
    fn = float(np.sum(~pred & truth))
    prec = tp / max(tp + fp, 1e-9)
    rec = tp / max(tp + fn, 1e-9)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return acc, f1
