"""Semantic-operator API: the legacy user-facing layer (Lotus-style).

``SemanticTable`` holds texts + (lazily computed) embeddings.  Its query
methods — ``sem_filter``, ``sem_filter_expr``, ``sem_join`` — are now thin
**deprecated shims** over the canonical lazy Session/Query API in
``repro.api``: each call builds a one-shot query and collects it
immediately, producing bit-identical masks and oracle call counts (asserted
in tests/test_api.py).  New code should use ``repro.api.Session`` directly;
see docs/api.md for the migration table.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.csv_filter import CSVConfig

_FILTER_METHODS = ("csv", "csv-sim", "reference", "lotus", "bargain")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new} (see docs/api.md)",
                  DeprecationWarning, stacklevel=3)


class SemanticTable:
    """A table of tuples with text payloads and a semantic-filter operator."""

    def __init__(self, texts: Optional[Sequence[str]] = None, embeddings=None,
                 embedder: Optional[Callable] = None):
        if texts is None and embeddings is None:
            raise ValueError("SemanticTable needs texts and/or embeddings")
        self.texts = list(texts) if texts is not None else None
        self._embeddings = (np.asarray(embeddings, np.float32)
                            if embeddings is not None else None)
        self._embedder = embedder
        # legacy per-instance clustering cache keyed by (n_clusters, seed);
        # the session layer keys its cache by (table id, n_clusters, seed)
        # and delegates computation here, so both stay coherent
        self._assign_cache: dict[tuple[int, int], np.ndarray] = {}
        self._api_handle = None  # lazily-created repro.api handle (shims)

    def __len__(self):
        if self.texts is not None:
            return len(self.texts)
        return len(self._embeddings)

    @property
    def embeddings(self) -> np.ndarray:
        if self._embeddings is None:
            if self._embedder is None:
                raise ValueError("table has no embeddings and no embedder")
            self._embeddings = np.asarray(self._embedder(self.texts), np.float32)
        return self._embeddings

    def precluster(self, n_clusters: int, seed: int = 0) -> np.ndarray:
        """Offline phase: cluster once, reuse across predicates."""
        key = (n_clusters, seed)
        if key not in self._assign_cache:
            import jax
            import jax.numpy as jnp
            from repro.core.clustering import kmeans
            _, assign, _ = kmeans(jax.random.key(seed),
                                  jnp.asarray(self.embeddings), n_clusters)
            self._assign_cache[key] = np.asarray(assign)
        return self._assign_cache[key]

    def _handle(self):
        """The session-layer handle backing the deprecation shims (one
        private Session per table, created on first legacy call)."""
        if self._api_handle is None:
            from repro.api import Session
            self._api_handle = Session().table(table=self)
        return self._api_handle

    def sem_filter(self, oracle, method: str = "csv",
                   cfg: Optional[CSVConfig] = None, proxy=None,
                   reuse_clustering: bool = True,
                   executor: Optional[str] = None,
                   pipeline_depth: Optional[int] = None, **kw):
        """Deprecated: use ``repro.api.Session``.  Evaluate one predicate.

        method: "csv" (UniVote), "csv-sim" (SimVote), "reference",
                "lotus", "bargain".
        executor / pipeline_depth: physical-plan knobs ("round" batches every
        live cluster's sample into one oracle call per round; depth > 1
        overlaps oracle prefill with voting).  Baseline ``**kw`` (e.g.
        ``sample_size``) rides along unchanged.
        """
        _deprecated("SemanticTable.sem_filter",
                    "Session.table(...).filter(...).collect()")
        if method not in _FILTER_METHODS:
            raise ValueError(f"unknown method {method!r}; "
                             f"expected one of {_FILTER_METHODS}")
        if method in ("lotus", "bargain") and proxy is None:
            raise ValueError(f"method {method!r} requires a proxy model")
        from repro.api import ExecutionPolicy
        pol = ExecutionPolicy.from_csv_config(
            cfg or CSVConfig(), method=method,
            reuse_clustering=reuse_clustering, baseline=dict(kw))
        if executor is not None:
            pol = pol.replace(executor=executor)
        if pipeline_depth is not None:
            pol = pol.replace(pipeline_depth=pipeline_depth)
        q = self._handle().filter(oracle, name="pred", proxy=proxy,
                                  policy=pol)
        res = q.collect()
        if method in ("reference", "lotus", "bargain"):
            return res.raw                    # BaselineResult, as before
        return res.raw.results["pred"]        # the node's FilterResult

    def sem_filter_expr(self, expr, cfg: Optional[CSVConfig] = None,
                        optimize: bool = True, pilot_size: int = 32,
                        reuse_clustering: bool = True):
        """Deprecated: use ``Session.table(...).filter(expr)``.  Evaluate a
        composed predicate expression (``repro.plan`` AST) as a cost-ordered
        short-circuit cascade.  Returns a ``PlanResult``.
        """
        _deprecated("SemanticTable.sem_filter_expr",
                    "Session.table(...).filter(expr).collect()")
        from repro.api import ExecutionPolicy
        pol = ExecutionPolicy.from_csv_config(
            cfg or CSVConfig(), optimize=optimize, pilot_size=pilot_size,
            reuse_clustering=reuse_clustering)
        return self._handle().filter(expr, policy=pol).collect().raw

    def sem_join(self, right: "SemanticTable", oracle, cfg=None,
                 reuse_clustering: bool = True):
        """Deprecated: use ``Session.table(...).join(...)``.  CSV-backed
        semantic join; oracle is called over *pair ids*
        ``i * len(right) + j`` (see ``repro.plan.join.pair_ids``).  Returns
        a ``JoinResult``.
        """
        _deprecated("SemanticTable.sem_join",
                    "Session.table(...).join(right, oracle).collect()")
        from repro.api import ExecutionPolicy
        from repro.plan.join import JoinConfig
        pol = ExecutionPolicy.from_join_config(
            cfg or JoinConfig(), reuse_clustering=reuse_clustering)
        handle = self._handle()
        return handle.join(right, oracle, policy=pol).collect().raw


def accuracy_f1(pred: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """The paper's quality metrics."""
    pred = np.asarray(pred, bool)
    truth = np.asarray(truth, bool)
    acc = float(np.mean(pred == truth))
    tp = float(np.sum(pred & truth))
    fp = float(np.sum(pred & ~truth))
    fn = float(np.sum(~pred & truth))
    prec = tp / max(tp + fp, 1e-9)
    rec = tp / max(tp + fn, 1e-9)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return acc, f1
