"""Sharding-context API.

Model code annotates activations with *logical* axis names via ``shard_act``.
When a ``sharding_context`` is active (the launcher / dry-run install one),
the names resolve through the mesh rules to ``NamedSharding`` constraints;
outside any context (CPU unit tests) the calls are no-ops, so the same model
code runs single-device and on the production mesh unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_context(rules):
    """rules: a MeshRules instance (see repro.distributed.rules)."""
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard_act(x, logical_axes: tuple):
    """Constrain activation x to the sharding implied by logical axis names.

    ``logical_axes`` length must equal x.ndim; entries are logical names
    (resolved via the active MeshRules) or None (replicated / unconstrained).
    No-op when no sharding context is active.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.activation_spec(logical_axes, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, spec))
