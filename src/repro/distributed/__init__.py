from repro.distributed.api import shard_act, sharding_context, current_rules
from repro.distributed.coordinator import (CoordinatedLane,
                                           DispatchCoordinator, LaneStats)
from repro.distributed.round import (ShardRoundOutput, run_sharded_executor,
                                     shard_clusters)
from repro.distributed.rules import MeshRules, resolve_spec, DEFAULT_LOGICAL_RULES
