from repro.distributed.api import shard_act, sharding_context, current_rules
from repro.distributed.rules import MeshRules, resolve_spec, DEFAULT_LOGICAL_RULES
