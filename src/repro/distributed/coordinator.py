"""Cross-process scheduling: several ``QueryScheduler``s, one dispatch lane.

A ``DispatchCoordinator`` owns a single strict-FIFO
``AsyncOracleDispatcher`` worker and hands out ``CoordinatedLane``s.  A
``QueryScheduler`` constructed with ``coordinator=`` (or a ``Session``
built with one — see ``repro.api.Session``) routes every merged dispatch
wave through its lane instead of a private dispatcher, so all attached
schedulers' waves drain through ONE serving lane:

- **per-scheduler determinism is untouched** — a lane forwards waves in
  the order its scheduler submits them, and the shared worker is strict
  FIFO, so within one scheduler the evaluation order is exactly what a
  private dispatcher would produce (bit-identity per query holds);
- **cross-scheduler waves interleave at wave granularity** — distinct
  sessions share no oracle objects or RNG state, so interleaving whole
  waves is observable only as bigger engine utilization, never as a
  result change;
- **lifecycle is decoupled** — ``lane.close()`` detaches the scheduler
  (after its in-flight waves drain) without stopping the shared worker;
  ``coordinator.close()`` shuts the worker down once every scheduler has
  detached (or force-closes remaining lanes).

In-process stand-in for the multi-host arrangement: one coordinator per
serving host, one scheduler per tenant process, the lane boundary being
where an RPC hop would slot in.  See docs/distributed.md.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

from repro.core.oracle import AsyncOracleDispatcher
from repro.obs.trace import get_tracer


@dataclasses.dataclass
class LaneStats:
    """Per-attached-scheduler accounting, kept after detach."""
    label: str
    n_waves: int = 0
    n_calls: int = 0     # submit_call invocations (waves + direct calls)
    attached: bool = True


class CoordinatedLane:
    """The dispatcher-shaped handle a scheduler drives.

    Implements the subset of the ``AsyncOracleDispatcher`` surface the
    scheduler uses (``submit_call``/``close``); ``close()`` detaches from
    the coordinator instead of stopping the shared worker.
    """

    def __init__(self, coordinator: "DispatchCoordinator", lane_id: int,
                 label: str):
        self._coordinator = coordinator
        self.lane_id = lane_id
        self.label = label
        self._detached = False

    def submit_call(self, fn, *args):
        """Queue ``fn(*args)`` on the shared FIFO worker."""
        if self._detached:
            raise RuntimeError(f"lane {self.label!r} is detached")
        return self._coordinator._submit_call(self.lane_id, fn, *args)

    def close(self) -> None:
        """Detach: wait for this lane's queued waves to drain, then drop
        the attachment.  The shared worker keeps serving other lanes."""
        if self._detached:
            return
        self._detached = True
        self._coordinator._detach(self.lane_id)

    def __repr__(self):
        state = "detached" if self._detached else "attached"
        return f"CoordinatedLane({self.label!r}, {state})"


class DispatchCoordinator:
    """One merged dispatch lane shared by several schedulers."""

    def __init__(self):
        self._lock = threading.Lock()
        self._worker = AsyncOracleDispatcher()
        self._lanes: Dict[int, LaneStats] = {}
        self._next_id = 0
        self._closed = False
        self.n_waves = 0

    # ----------------------------------------------------------- attach
    def attach(self, label: Optional[str] = None) -> CoordinatedLane:
        """Create a lane for one scheduler (``QueryScheduler`` calls this
        when constructed with ``coordinator=``)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("coordinator is closed")
            lane_id = self._next_id
            self._next_id += 1
            self._lanes[lane_id] = LaneStats(
                label=label or f"lane{lane_id}")
            get_tracer().metrics.set("coordinator.lanes",
                                     self.n_attached)
        return CoordinatedLane(self, lane_id, self._lanes[lane_id].label)

    def _submit_call(self, lane_id: int, fn, *args):
        with self._lock:
            if self._closed:
                raise RuntimeError("coordinator is closed")
            st = self._lanes[lane_id]
            st.n_calls += 1
            st.n_waves += 1
            self.n_waves += 1
        get_tracer().metrics.inc("coordinator.waves")
        return self._worker.submit_call(fn, *args)

    def _detach(self, lane_id: int) -> None:
        # barrier: everything this lane queued has been evaluated before
        # detach returns, mirroring AsyncOracleDispatcher.close() semantics
        # (the scheduler relies on close() meaning "drained")
        self._worker.submit_call(lambda: None).result()
        with self._lock:
            self._lanes[lane_id].attached = False
            get_tracer().metrics.set("coordinator.lanes", self.n_attached)

    # ------------------------------------------------------------ status
    @property
    def n_attached(self) -> int:
        return sum(1 for st in self._lanes.values() if st.attached)

    def stats(self) -> Dict[str, LaneStats]:
        """Per-lane wave counts keyed by label (detached lanes included)."""
        with self._lock:
            return {st.label: dataclasses.replace(st)
                    for st in self._lanes.values()}

    def close(self) -> None:
        """Stop the shared worker after draining queued waves.  Lanes
        still attached are force-detached (their next submit raises)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for st in self._lanes.values():
                st.attached = False
        self._worker.close()
