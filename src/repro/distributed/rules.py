"""Logical-axis -> mesh-axis resolution (MaxText-style, with divisibility fallback).

Parameters and activations are annotated with *logical* axis names
("vocab", "heads", "ffn", "embed", "experts", ...).  ``MeshRules`` maps each
logical name to an ordered list of candidate mesh axes; resolution walks a
leaf's logical axes and greedily assigns the first candidate mesh axis that
(a) is not already used by another dim of the same leaf and (b) evenly
divides the dim size.  Rules that do not fit are *dropped with a recorded
warning* instead of failing — e.g. whisper-base's 8 heads cannot be sharded
over a 16-way "model" axis and fall back to replication.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> ordered candidate mesh-axis tuples.  Each candidate is a
# tuple of mesh axes (sharding one dim over multiple mesh axes is allowed,
# e.g. kv_seq over ("data","model") for 500k decode).
DEFAULT_LOGICAL_RULES: Dict[str, List[Tuple[str, ...]]] = {
    # weights
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "ffn": [("model",)],
    "experts": [("model",)],
    "inner": [("model",)],  # mamba d_inner
    "embed": [("data",)],  # FSDP / ZeRO-3 axis
    # activations
    "batch": [("pod", "data"), ("data",)],
    "act_embed": [],
    "seq": [],
    "kv_seq": [("model",)],
    "kv_seq_long": [("data", "model"), ("model",)],
    "kv_batch": [("pod", "data"), ("data",)],
}


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    rules: Dict[str, List[Tuple[str, ...]]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LOGICAL_RULES))
    warnings: List[str] = dataclasses.field(default_factory=list)

    def _axis_size(self, axes: Tuple[str, ...]) -> Optional[int]:
        try:
            return int(math.prod(self.mesh.shape[a] for a in axes))
        except KeyError:
            return None  # mesh lacks one of the axes (e.g. "pod" on single pod)

    def _resolve_dim(self, name: Optional[str], dim: int, used: set):
        if name is None or name not in self.rules:
            return None
        for cand in self.rules[name]:
            size = self._axis_size(cand)
            if size is None:
                continue
            if any(a in used for a in cand):
                continue
            if dim % size != 0:
                self.warnings.append(
                    f"drop {name}->{cand}: dim {dim} % {size} != 0")
                continue
            used.update(cand)
            return cand if len(cand) > 1 else cand[0]
        return None

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set = set()
        parts = [self._resolve_dim(n, d, used)
                 for n, d in zip(logical_axes, shape)]
        return P(*parts)

    # activations may carry fewer constraints; identical mechanics
    activation_spec = spec

    def named_sharding(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def resolve_spec(mesh: Mesh, logical_axes, shape) -> NamedSharding:
    return MeshRules(mesh).named_sharding(logical_axes, shape)
