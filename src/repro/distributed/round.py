"""Sharded CSV rounds: partition each round across mesh hosts.

``run_sharded_executor`` is the ``cfg.shards > 1`` execution path of
``repro.core.csv_filter.semantic_filter`` (same signature as the
single-host ``_run_round_executor``).  Each round:

1. **plan** — the round plan (sample draws) is computed once, replicated:
   every shard sees the identical plan because the driver RNG is
   deterministic and sampling happens before partitioning.
2. **shard** — the round's clusters are partitioned into ``cfg.shards``
   *contiguous* slices, balanced by sample count (``shard_clusters``).
   Contiguity in cluster order is what makes sharding invisible to the
   oracle: concatenating the shard batches in shard order reproduces the
   single-host cross-cluster batch byte for byte.
3. **oracle** — every shard's sample batch is dispatched through ONE
   shared strict-FIFO ``AsyncOracleDispatcher`` lane in shard order, so
   shard s+1's oracle prefill overlaps shard s's voting while the flip
   stream and memo commit order stay identical to single-host.
4. **vote** — each shard votes its own clusters (one segmented device
   dispatch per shard) and buffers its outputs locally.
5. **all-gather** — shard outputs are merged in shard order (== round
   cluster order) into the replicated result/decided arrays.  This is the
   collective point: on a real mesh this merge is an all-gather of
   ``(sample labels, vote outcomes)`` per shard; here shards share memory
   so the gather is a deterministic ordered write-back.
6. **partition** — the shared ``_recluster_or_fallback`` tail runs on the
   gathered state, replicated, so every shard derives the identical next
   queue.

Bit-identity contract (asserted in tests/test_distributed_round.py):
masks, oracle call counts, cluster logs, and memo state equal the
``shards=1`` run on the same seed.  Only the per-invocation batch sizes
differ — one batch per shard instead of one per wave.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.csv_filter import (RoundResult, _recluster_or_fallback,
                                   _vote_wave, plan_round)
from repro.core.oracle import AsyncOracleDispatcher, SyncOracleDispatcher
from repro.obs.trace import get_tracer
from repro.utils.timing import monotonic


def shard_clusters(clusters: list, n_shards: int) -> list:
    """Contiguous, sample-count-balanced partition of a round's clusters.

    Contiguous slices (never an interleave) so that concatenating shard
    batches in shard order equals the single-host concatenation — the
    bit-identity contract depends on this.  Balanced on ``n_sample``
    because oracle cost, not cluster size, is what each shard pays.
    """
    n_shards = max(1, min(int(n_shards), len(clusters)))
    if n_shards == 1:
        return [list(clusters)]
    weights = np.array([cp.n_sample for cp in clusters], dtype=np.float64)
    cum = np.cumsum(weights)
    total = float(cum[-1])
    bounds = [0]
    for s in range(1, n_shards):
        cut = int(np.searchsorted(cum, total * s / n_shards, side="left")) + 1
        cut = max(bounds[-1], min(cut, len(clusters)))
        bounds.append(cut)
    bounds.append(len(clusters))
    shards = [list(clusters[bounds[s]:bounds[s + 1]])
              for s in range(n_shards)]
    return [s for s in shards if s]


@dataclasses.dataclass
class ShardRoundOutput:
    """One shard's buffered round output, merged at the all-gather point."""
    shard: int
    clusters: list           # this shard's ClusterPlans, in round order
    labels_by_cluster: list  # oracle labels, parallel to ``clusters``
    votes: dict              # local cluster index -> VoteResult
    batch: int               # oracle batch size this shard submitted


def run_sharded_executor(emb, oracle, cfg, rng, xi, result, decided,
                         cluster_log, round_log, queue):
    """Drop-in for ``_run_round_executor`` with cluster-sharded rounds."""
    tr = get_tracer()
    lb, ub = cfg.lb, cfg.ub_
    n_voted = n_fallback = 0
    rounds_used = 0
    recluster_time = 0.0
    depth = 0
    while queue and depth <= cfg.max_recluster:
        with tr.span("round", kind="round", depth=depth,
                     n_clusters=len(queue), executor="round",
                     shards=int(cfg.shards)) as rsp:
            t_round = monotonic()
            with tr.span("plan", kind="plan"):
                plan = plan_round(queue, rng, xi, cfg, depth)
            shards = shard_clusters(plan.clusters, cfg.shards)

            dispatcher = (AsyncOracleDispatcher(oracle) if len(shards) > 1
                          else SyncOracleDispatcher(oracle))
            handles = []
            outputs = []
            try:
                for s, shard in enumerate(shards):
                    with tr.span("oracle", kind="oracle", shard=s,
                                 n_clusters=len(shard)) as osp:
                        if s == 0:
                            # submit inside the span to keep submission
                            # order submit(0), submit(1), result(0): the
                            # shared FIFO lane evaluates shard batches in
                            # shard order, so the flip stream and memo
                            # commits match the single-host concatenation
                            handles.append(dispatcher.submit(
                                np.concatenate([cp.sample_ids
                                                for cp in shards[0]])))
                        if s + 1 < len(shards):
                            # overlap: the next shard's oracle prefill is
                            # in flight while this shard votes
                            handles.append(dispatcher.submit(
                                np.concatenate([cp.sample_ids
                                                for cp in shards[s + 1]])))
                        flat_labels = handles[s].result()
                        osp.set(batch=int(len(flat_labels)))
                    offsets = np.cumsum([cp.n_sample for cp in shard])[:-1]
                    labels_by_cluster = np.split(flat_labels, offsets)
                    with tr.span("vote", kind="vote", shard=s,
                                 n_clusters=len(shard)):
                        votes = _vote_wave(shard, labels_by_cluster, emb,
                                           cfg, lb, ub)
                    outputs.append(ShardRoundOutput(
                        shard=s, clusters=shard,
                        labels_by_cluster=labels_by_cluster, votes=votes,
                        batch=int(len(flat_labels))))
            finally:
                dispatcher.close()

            # ---- all-gather: merge every shard's sample labels and vote
            # outcomes in shard order (== round cluster order) before the
            # replicated partition step sees any of them ----
            undetermined = []
            round_voted = 0
            with tr.span("gather", kind="gather", depth=depth,
                         shards=len(outputs)):
                for out in outputs:
                    for i, cp in enumerate(out.clusters):
                        labels = out.labels_by_cluster[i]
                        result[cp.sample_ids] = labels
                        decided[cp.sample_ids] = True
                        if len(cp.rest_ids) == 0:
                            cluster_log.append({
                                "size": cp.size, "sampled": cp.n_sample,
                                "score": float(np.mean(labels)),
                                "depth": depth, "outcome": "exhausted"})
                            continue
                        vr = out.votes[i]
                        result[cp.rest_ids[vr.decided_true]] = True
                        decided[cp.rest_ids[vr.decided_true]] = True
                        result[cp.rest_ids[vr.decided_false]] = False
                        decided[cp.rest_ids[vr.decided_false]] = True
                        voted = (len(vr.decided_true)
                                 + len(vr.decided_false))
                        n_voted += voted
                        round_voted += voted
                        if len(vr.undetermined):
                            undetermined.append(
                                cp.rest_ids[vr.undetermined])
                        cluster_log.append({
                            "size": cp.size, "sampled": cp.n_sample,
                            "score": float(np.mean(labels)),
                            "voted": int(voted),
                            "undetermined": int(len(vr.undetermined)),
                            "depth": depth,
                            "outcome": ("vote"
                                        if not len(vr.undetermined)
                                        else "recluster"),
                        })

            n_undet = int(sum(len(u) for u in undetermined))
            round_log.append(RoundResult(
                depth=depth, n_clusters=len(plan.clusters),
                n_sampled=plan.n_sampled, n_voted=round_voted,
                n_undetermined=n_undet, waves=len(outputs),
                oracle_batches=[o.batch for o in outputs],
                shards=len(outputs)))
            rsp.set(n_sampled=plan.n_sampled, n_voted=round_voted,
                    n_undetermined=n_undet, shards=len(outputs))
            tr.metrics.inc("driver.rounds")
            tr.metrics.inc("distributed.sharded_rounds")
            tr.metrics.observe("distributed.shards_per_round",
                               len(outputs))
            tr.metrics.observe("round.wall_s", monotonic() - t_round)

            if not undetermined:
                break
            pending = np.concatenate(undetermined)
            depth += 1
            rounds_used = depth
            queue, fb, dt = _recluster_or_fallback(
                emb, oracle, cfg, pending, depth, result, decided)
            n_fallback += fb
            recluster_time += dt
    return n_voted, n_fallback, rounds_used, recluster_time
