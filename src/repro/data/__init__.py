from repro.data.synthetic import make_dataset, DATASETS, SynthDataset
from repro.data.tokenizer import HashTokenizer
from repro.data.loader import PackedLoader
