"""Hashing word tokenizer (nothing pretrained ships offline).

Deterministic: token id = (stable word hash) % (vocab - n_special) + n_special.
Good enough for LM training on synthetic corpora and for prompt length
accounting; reserves ids for special tokens and the yes/no answer tokens so
ModelOracle can read a stable logit position.
"""
from __future__ import annotations

import hashlib
import re
from typing import List

_WORD_RE = re.compile(r"[a-z0-9']+|[^\sa-z0-9']")

PAD, BOS, EOS, YES, NO = 0, 1, 2, 3, 4
N_SPECIAL = 8


def _stable_hash(word: str) -> int:
    return int.from_bytes(hashlib.md5(word.encode()).digest()[:8], "little")


class HashTokenizer:
    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > N_SPECIAL
        self.vocab_size = vocab_size

    def token_id(self, word: str) -> int:
        w = word.lower()
        if w == "yes":
            return YES
        if w == "no":
            return NO
        return _stable_hash(w) % (self.vocab_size - N_SPECIAL) + N_SPECIAL

    def encode(self, text: str, bos: bool = True) -> List[int]:
        ids = [self.token_id(w) for w in _WORD_RE.findall(text.lower())]
        return ([BOS] + ids) if bos else ids

    def words(self, text: str) -> List[str]:
        return _WORD_RE.findall(text.lower())
